// Hot-path micro-profile: break the SAM step into components.
use sam::prelude::*;
use sam::util::timer::Timer;

fn main() {
    let n = 65536;
    let cfg = CoreConfig {
        x_dim: 8, y_dim: 8, hidden: 100, heads: 4, word: 32,
        mem_words: n, k: 4, ann: AnnKind::KdForest, seed: 1,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(1);
    let mut core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let x = vec![0.5f32; 8];
    let dy = vec![0.1f32; 8];
    // fwd-only vs fwd+bwd to split costs
    for label in ["fwd", "fwd+bwd"] {
        let t = Timer::start();
        let reps = 20;
        for _ in 0..reps {
            core.reset();
            for _ in 0..10 { core.forward(&x); }
            if label == "fwd+bwd" {
                for _ in 0..10 { core.backward(&dy); }
            } else {
                core.rollback();
            }
            core.end_episode();
        }
        println!("{label}: {:.1} µs/step", t.elapsed_s() / (reps * 10) as f64 * 1e6);
    }
    // isolate ANN cost
    use sam::ann::{AnnIndex, KdForest};
    let mut ann = KdForest::with_defaults(n, 32, 2);
    let mut r2 = Rng::new(3);
    for i in 0..n {
        let v: Vec<f32> = (0..32).map(|_| r2.normal()).collect();
        ann.insert(i, &v);
    }
    let q: Vec<f32> = (0..32).map(|_| r2.normal()).collect();
    let t = Timer::start();
    for _ in 0..1000 { std::hint::black_box(ann.query(&q, 4)); }
    println!("ann query: {:.1} µs", t.elapsed_s() * 1e3);
    let v: Vec<f32> = (0..32).map(|_| r2.normal()).collect();
    let t = Timer::start();
    for _ in 0..1000 { ann.update(7, &v); }
    println!("ann update: {:.1} µs", t.elapsed_s() * 1e3);
    // controller LSTM cost
    use sam::nn::lstm::Lstm;
    let mut lstm = Lstm::new("p", 8 + 4*32, 100, &mut rng);
    let xin = vec![0.1f32; 8 + 4*32];
    let t = Timer::start();
    for _ in 0..1000 { std::hint::black_box(lstm.step(&xin)); }
    println!("lstm step: {:.1} µs (tape {} entries)", t.elapsed_s() * 1e3, 1000);
}
