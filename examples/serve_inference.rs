//! Shared-weight serving demo: one `Arc<SamCore>` of trained parameters
//! drives many concurrent memory sessions through the serving runtime —
//! the deployment story the paper's 1,000×-faster / 3,000×-smaller
//! numbers enable.
//!
//! What it shows:
//!   1. the parameters/state split: N sessions, ONE copy of the weights
//!      (printed from the manager's heap accounting);
//!   2. forward-only stepping: zero tape bytes while serving;
//!   3. the batched tick: all sessions' controller steps coalesce into one
//!      GEMM per projection, vs. the per-session step path.
//!
//! Offline-native (no PJRT artifacts needed):
//!
//!     cargo run --release --example serve_inference [-- --sessions 64 --steps 200]

use sam::bench::fmt_bytes;
use sam::cores::{CoreConfig, CoreKind};
use sam::prelude::*;
use sam::serving::{build_infer_model, SessionConfig, SessionManager};
use sam::util::timer::Timer;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let i = ((sorted.len() - 1) as f64 * p) as usize;
    sorted[i]
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sessions = args.usize_or("sessions", 64);
    let steps = args.usize_or("steps", 200);
    let cfg = CoreConfig {
        x_dim: 16,
        y_dim: 16,
        hidden: args.usize_or("hidden", 100),
        heads: 4,
        word: 32,
        mem_words: args.usize_or("memory", 1 << 14),
        k: 4,
        ann: AnnKind::Linear,
        seed: 11,
        ..CoreConfig::default()
    };

    let mut rng = Rng::new(11);
    // A checkpoint would be loaded here via coordinator::read_checkpoint;
    // the demo serves the fresh init.
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let mgr = SessionManager::new(model, SessionConfig::default());
    let ids: Vec<u64> = (0..sessions).map(|_| mgr.open()).collect();

    println!(
        "serving {} sessions · ONE weight copy {} · episodic state {} ({} /session)",
        ids.len(),
        fmt_bytes(mgr.params_heap_bytes()),
        fmt_bytes(mgr.state_heap_bytes()),
        fmt_bytes(mgr.state_heap_bytes() / ids.len().max(1)),
    );

    // ---- path A: per-session steps (the request-at-a-time shape) --------
    let mut xrng = Rng::new(17);
    let mut y = Vec::new();
    let mut lat = Vec::with_capacity(steps);
    for _ in 0..steps {
        let id = ids[xrng.below(ids.len())];
        let x: Vec<f32> = (0..cfg.x_dim).map(|_| xrng.normal()).collect();
        let t = Timer::start();
        mgr.step(id, &x, &mut y).expect("step");
        lat.push(t.elapsed_s());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "single-step: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
        percentile(&lat, 0.5) * 1e3,
        percentile(&lat, 0.95) * 1e3,
        percentile(&lat, 0.99) * 1e3,
    );

    // ---- path B: batched ticks (all sessions per tick, coalesced GEMMs) --
    let ticks = (steps / ids.len()).max(4);
    let mut outs = Vec::new();
    let t = Timer::start();
    for _ in 0..ticks {
        let reqs: Vec<(u64, Vec<f32>)> = ids
            .iter()
            .map(|&id| (id, (0..cfg.x_dim).map(|_| xrng.normal()).collect()))
            .collect();
        mgr.step_many(&reqs, &mut outs);
    }
    let el = t.elapsed_s();
    let total_steps = ticks * ids.len();
    println!(
        "batched tick: {} ticks × {} sessions = {} steps in {:.1} ms → {:.0} session-steps/s",
        ticks,
        ids.len(),
        total_steps,
        el * 1e3,
        total_steps as f64 / el,
    );
    println!(
        "tape bytes while serving: 0 by construction (journal-free infer mode)"
    );
    println!("serving OK — one weight copy, {} private memories", ids.len());
    Ok(())
}
