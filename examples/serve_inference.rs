//! End-to-end AOT serving driver: Rust drives the JAX/Pallas-compiled HLO
//! cells through PJRT on a real request stream — Python nowhere in sight.
//!
//! Pipeline per step (batch of episodes):
//!   1. L3 (rust): ANN index selects the K nearest memory rows per query.
//!   2. L2/L1 (AOT HLO): the fused `sam_read_softmax` Pallas kernel
//!      computes softmax(β·cos) over those rows and the read word.
//!   3. L3: the DAM full-step cell (`dam_step`) runs the controller,
//!      write, dense read and output — state (h, c, M, usage) lives in
//!      rust between calls.
//!
//! Prints latency percentiles and throughput, then serves a few episodes
//! end-to-end. Requires `make artifacts`.
//!
//!     cargo run --release --example serve_inference [-- --requests 200]

use sam::ann::{AnnIndex, KdForest};
use sam::runtime::{artifacts_dir, Runtime, Tensor};
use sam::util::args::Args;
use sam::util::rng::Rng;
use sam::util::timer::Timer;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let i = ((sorted.len() - 1) as f64 * p) as usize;
    sorted[i]
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.usize_or("requests", 200);
    let dir = artifacts_dir();
    let mut rt = Runtime::cpu()?;
    let loaded = match rt.load_dir(&dir) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("artifacts not found ({e:#}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("loaded artifacts {loaded:?} on {}", rt.platform());

    // Shapes must match the manifest the artifacts were lowered for.
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))?;
    let mj = sam::util::json::Json::parse(&manifest).map_err(|e| anyhow::anyhow!(e))?;
    let cfgj = mj.get("config").unwrap();
    let dim = |k: &str| cfgj.get(k).unwrap().as_f64().unwrap() as usize;
    let (i_dim, h_dim, n, w, k) =
        (dim("x_dim"), dim("hidden"), dim("mem_words"), dim("word"), dim("k"));

    let mut rng = Rng::new(11);
    // Random "trained" weights for the serving demo (a checkpoint would be
    // loaded the same way — flat f32 buffers).
    let rand = |len: usize, rng: &mut Rng, s: f32| -> Vec<f32> {
        (0..len).map(|_| rng.normal() * s).collect()
    };

    // ---------------- path A: SAM sparse read (ANN + fused kernel) -------
    println!("\n== SAM sparse-read path: rust ANN -> Pallas gather/softmax HLO ==");
    let mem: Vec<f32> = rand(n * w, &mut rng, 1.0);
    let mut ann = KdForest::with_defaults(n, w, 3);
    for i in 0..n {
        ann.insert(i, &mem[i * w..(i + 1) * w]);
    }
    let mut lat = Vec::with_capacity(requests);
    let mut checksum = 0.0f32;
    for r in 0..requests {
        let q: Vec<f32> = rand(w, &mut rng, 1.0);
        let t = Timer::start();
        let neighbors = ann.query(&q, k); // L3: O(log N) candidate selection
        let idx: Vec<i32> = neighbors.iter().map(|&(i, _)| i as i32).collect();
        let out = rt.exec_tensors(
            "sam_read_softmax",
            &[
                Tensor::F32(&mem, &[n, w]),
                Tensor::I32(&idx, &[1, k]),
                Tensor::F32(&q, &[1, w]),
                Tensor::F32(&[0.5f32], &[1]),
            ],
        )?;
        lat.push(t.elapsed_s());
        checksum += out[0][r % w];
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{requests} requests: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  throughput {:.0} req/s  (checksum {checksum:.3})",
        percentile(&lat, 0.5) * 1e3,
        percentile(&lat, 0.95) * 1e3,
        percentile(&lat, 0.99) * 1e3,
        1.0 / (lat.iter().sum::<f64>() / lat.len() as f64),
    );

    // ---------------- path B: full DAM step cell, stateful episode -------
    println!("\n== DAM full-step cell: stateful episodes through `dam_step` ==");
    let fan = |f: usize| 1.0 / (f as f32).sqrt();
    let wx = rand(4 * h_dim * (i_dim + w), &mut rng, fan(i_dim + w));
    let wh = rand(4 * h_dim * h_dim, &mut rng, fan(h_dim));
    let b = vec![0.0f32; 4 * h_dim];
    let w_head = rand((2 * w + 3) * h_dim, &mut rng, fan(h_dim));
    let b_head = vec![0.0f32; 2 * w + 3];
    let w_out = rand(w * (h_dim + w), &mut rng, fan(h_dim + w));
    let b_out = vec![0.0f32; w];

    let episodes = 5;
    let steps = 20;
    let mut step_lat = Vec::new();
    for ep in 0..episodes {
        // episode state, owned by rust
        let mut h = vec![0.0f32; h_dim];
        let mut c = vec![0.0f32; h_dim];
        let mut m = rand(n * w, &mut rng, 0.05);
        let mut usage = vec![0.0f32; n];
        let mut w_read = vec![0.0f32; n];
        let mut r_prev = vec![0.0f32; w];
        let mut y_last = vec![0.0f32; w];
        for _ in 0..steps {
            let x: Vec<f32> = rand(i_dim, &mut rng, 1.0);
            let t = Timer::start();
            let dims: Vec<Vec<usize>> = vec![
                vec![i_dim], vec![h_dim], vec![h_dim], vec![n, w], vec![n], vec![n], vec![w],
                vec![4 * h_dim, i_dim + w], vec![4 * h_dim, h_dim], vec![4 * h_dim],
                vec![2 * w + 3, h_dim], vec![2 * w + 3], vec![w, h_dim + w], vec![w],
            ];
            let data: Vec<&[f32]> = vec![
                &x, &h, &c, &m, &usage, &w_read, &r_prev, &wx, &wh, &b, &w_head, &b_head,
                &w_out, &b_out,
            ];
            let inputs: Vec<(&[f32], &[usize])> =
                data.into_iter().zip(dims.iter().map(|d| d.as_slice())).collect();
            let out = rt.exec("dam_step", &inputs)?;
            step_lat.push(t.elapsed_s());
            // carry state
            y_last = out[0].clone();
            h = out[1].clone();
            c = out[2].clone();
            m = out[3].clone();
            usage = out[4].clone();
            w_read = out[5].clone();
            r_prev = out[6].clone();
        }
        println!(
            "episode {ep}: {steps} steps, y[0..4] = {:?}",
            &y_last[..4.min(y_last.len())]
        );
    }
    step_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "dam_step latency: p50 {:.2} ms  p95 {:.2} ms  ({} steps total)",
        percentile(&step_lat, 0.5) * 1e3,
        percentile(&step_lat, 0.95) * 1e3,
        step_lat.len()
    );
    println!("\nserving OK — python was never on the request path");
    Ok(())
}
