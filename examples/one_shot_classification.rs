//! One-shot classification (paper §4.5): train SAM on synthetic-Omniglot
//! episodes — bind novel "character" embeddings to shuffled labels in one
//! presentation, recall them for the rest of the episode — then test on
//! episodes with more classes than ever seen in training.
//!
//!     cargo run --release --example one_shot_classification -- --updates 600

use sam::prelude::*;

fn main() {
    let args = Args::from_env();
    let updates = args.usize_or("updates", 600);
    let seed = args.u64_or("seed", 13);
    let max_classes = args.usize_or("max-classes", 12);

    let task = OmniglotTask::new(16, max_classes);
    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 64,
        heads: 2,
        word: 16,
        mem_words: 4096,
        k: 4,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(seed);
    let core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig {
            batch: 4,
            updates,
            log_every: (updates / 15).max(1),
            seed,
            verbose: true,
            ..TrainConfig::default()
        },
    );
    // Curriculum doubles the class count as accuracy improves; training
    // never goes past half the eval ceiling.
    let train_max = (max_classes / 2).max(2);
    let mut curriculum = Curriculum::exponential(2, train_max, 1.0);
    curriculum.patience = 10;
    trainer.run(&task, &mut curriculum);

    println!("\ntest errors (fraction wrong on 2nd+ presentations; chance ≈ {:.2}):", 1.0 - 1.0 / max_classes as f64);
    for classes in [2, train_max, max_classes] {
        let err = trainer.evaluate(&task, classes, 10, seed ^ 77);
        let tag = if classes > train_max { "  <- beyond training" } else { "" };
        println!("  {classes:>3} classes: {err:.3}{tag}");
    }
}
