//! Quickstart: train a small SAM on the copy task and watch the loss fall.
//!
//!     cargo run --release --example quickstart [-- --updates 400 --level 4]
//!
//! This is the 60-second end-to-end check that the public API composes:
//! task → core → trainer → optimizer → metrics.

use sam::prelude::*;

fn main() {
    let args = Args::from_env();
    let updates = args.usize_or("updates", 400);
    let level = args.usize_or("level", 4);
    let seed = args.u64_or("seed", 7);

    let task = CopyTask::new(6);
    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 64,
        heads: 2,
        word: 16,
        mem_words: 64,
        k: 4,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(seed);
    let core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(args.f32_or("lr", 3e-3))),
        TrainConfig {
            batch: 4,
            updates,
            log_every: (updates / 20).max(1),
            seed,
            verbose: true,
            ..TrainConfig::default()
        },
    );
    let mut curriculum = Curriculum::fixed(level);
    let log = trainer.run(&task, &mut curriculum);

    let errs = trainer.evaluate(&task, level, 20, seed ^ 1);
    println!("\nfinal: best loss/step {:.4}, eval {errs:.2} bit-errors/episode", log.best_loss());
    println!(
        "loss curve: {}",
        log.points
            .iter()
            .map(|p| format!("{:.3}", p.loss))
            .collect::<Vec<_>>()
            .join(" → ")
    );
}
