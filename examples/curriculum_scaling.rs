//! Curriculum scaling demo (the paper's §4.3 workflow): train SAM on
//! associative recall with an exponentially-increasing difficulty ceiling
//! and a memory far larger than any dense model could train with, and
//! watch the level climb. With `--workers N` the batch runs on N
//! data-parallel threads (Supp C) — less wall-clock, and with
//! `--ann linear` the same seed gives the same learning trajectory at any
//! worker count (the approximate kd/LSH indexes carry per-replica history,
//! so they are deterministic per count but can diverge across counts —
//! see DESIGN.md).
//!
//!     cargo run --release --example curriculum_scaling -- --updates 800 --memory 16384
//!     cargo run --release --example curriculum_scaling -- --workers 4

use sam::prelude::*;

fn main() {
    let args = Args::from_env();
    let updates = args.usize_or("updates", 800);
    let memory = args.usize_or("memory", 1 << 14);
    let seed = args.u64_or("seed", 3);
    let workers = args.usize_or("workers", 1).max(1);

    let task = AssociativeRecall::new(6);
    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 64,
        heads: 2,
        word: 16,
        mem_words: memory,
        k: 4,
        ann: args.str_or("ann", "kdtree").parse().unwrap(),
        seed,
        ..CoreConfig::default()
    };
    println!(
        "SAM on associative recall, N={} words ({}), exponential curriculum, {} worker(s)",
        memory,
        args.str_or("ann", "kdtree"),
        workers
    );
    let train_cfg = TrainConfig {
        batch: 4,
        updates,
        log_every: (updates / 20).max(1),
        seed,
        verbose: true,
        ..TrainConfig::default()
    };
    let lr = args.f32_or("lr", 1e-3);
    let mut curriculum = Curriculum::exponential(2, 1 << 16, 0.15);
    curriculum.patience = 10;

    // Identical replicas per worker: fresh seeded Rng every factory call.
    let mut factory = |_i: usize| {
        let mut rng = Rng::new(seed);
        build_core(CoreKind::Sam, &cfg, &mut rng)
    };
    let mut pt = ParallelTrainer::new(
        &mut factory,
        workers,
        Box::new(RmsProp::new(lr)),
        train_cfg.clone(),
    );
    let log = pt.run(&task, &mut curriculum);
    println!(
        "\nreached difficulty level {} after {} episodes ({} doublings)",
        log.final_level, log.total_episodes, curriculum.advances
    );
    // Show generalization one level beyond the curriculum (Fig 8 flavor),
    // evaluating on the primary replica through the serial trainer.
    let (core, opt) = pt.into_primary();
    let mut trainer = Trainer::new(core, opt, train_cfg);
    let beyond = log.final_level * 2;
    let errs = trainer.evaluate(&task, beyond, 5, seed ^ 9);
    println!("eval at {}x difficulty ({beyond}): {errs:.2} bit-errors/episode (chance 3.0)", 2);
}
