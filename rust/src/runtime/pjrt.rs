//! Real PJRT runtime backend (feature `pjrt`): loads the JAX/Pallas AOT
//! artifacts (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and
//! executes them from Rust. Python is never on this path — the interchange
//! format is HLO *text* (see `python/compile/aot.py` and DESIGN.md;
//! serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1).
//!
//! Each artifact is compiled once at load and cached; execution takes and
//! returns flat `f32` buffers. Compiling this module requires the external
//! `xla` crate, which the offline build image cannot fetch — hence the
//! feature gate; the default build uses the stub in `runtime/mod.rs` with
//! the identical public API.

use super::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A compiled HLO program plus its human-readable name.
pub struct CompiledCell {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU client with a registry of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    cells: HashMap<String, CompiledCell>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, cells: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile a single HLO-text artifact under `name`.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.cells
            .insert(name.to_string(), CompiledCell { name: name.to_string(), exe });
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; the artifact name is the file
    /// stem (e.g. `lstm_cell.hlo.txt` → "lstm_cell").
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        for (stem, path) in super::discover_artifacts(dir)? {
            self.load(&stem, &path)?;
            loaded.push(stem);
        }
        Ok(loaded)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.cells.values().map(|c| c.name.as_str()).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.cells.contains_key(name)
    }

    /// Execute `name` with f32 tensor inputs given as (data, dims) pairs.
    /// The artifact returns a tuple (aot.py lowers with return_tuple=True);
    /// each tuple element comes back as a flat f32 vector.
    pub fn exec(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let tensors: Vec<Tensor> =
            inputs.iter().map(|(d, s)| Tensor::F32(d, s)).collect();
        self.exec_tensors(name, &tensors)
    }

    /// Execute with mixed-dtype inputs (f32 data + i32 index tensors, e.g.
    /// the sparse-read cell whose row indices come from the Rust ANN).
    pub fn exec_tensors(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let cell = self
            .cells
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (loaded: {:?})", self.names()))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let (lit, dims) = match t {
                Tensor::F32(data, dims) => (xla::Literal::vec1(data), *dims),
                Tensor::I32(data, dims) => (xla::Literal::vec1(data), *dims),
            };
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = lit
                .reshape(&dims_i64)
                .map_err(|e| anyhow!("reshape input to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = cell
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            vecs.push(p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(vecs)
    }
}
