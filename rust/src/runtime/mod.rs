//! PJRT runtime seam: executes the JAX/Pallas AOT artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) from Rust.
//!
//! Two backends share one public API:
//!
//! * **feature `pjrt`** — the real XLA/PJRT client ([`pjrt`]), which needs
//!   the external `xla` crate (not vendorable in the offline build image).
//! * **default** — a stub that constructs fine, reports the platform as
//!   `"cpu-stub"`, refuses to *compile* artifacts with a clear error, and
//!   reports unknown artifacts on `exec`. Everything that merely probes the
//!   runtime (the `sam info` subcommand, the parity tests' skip path, the
//!   serving example's graceful bail-out) behaves identically.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

/// One runtime input tensor: flat data + dims.
pub enum Tensor<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// Default artifacts directory (repo-relative), overridable via env.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SAM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Enumerate `*.hlo.txt` artifacts in `dir` as (stem, path), sorted by path.
pub(crate) fn discover_artifacts(dir: &Path) -> Result<Vec<(String, PathBuf)>> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("read artifacts dir {dir:?}"))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|s| s.to_str())
                .is_some_and(|s| s.ends_with(".hlo.txt"))
        })
        .collect();
    paths.sort();
    Ok(paths
        .into_iter()
        .map(|p| {
            let stem = p
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap()
                .trim_end_matches(".hlo.txt")
                .to_string();
            (stem, p)
        })
        .collect())
}

/// Stub runtime used when the `pjrt` feature is off: constructs fine,
/// never loads an artifact, and reports every `exec` target as unknown.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime;

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Create the stub "client" (always succeeds).
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime)
    }

    pub fn platform(&self) -> String {
        "cpu-stub".to_string()
    }

    /// The stub cannot compile HLO; report why instead of pretending.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        Err(anyhow!(
            "cannot compile artifact {name:?} from {path:?}: \
             sam was built without the `pjrt` feature (xla backend unavailable)"
        ))
    }

    /// Load every `*.hlo.txt` in a directory. Errors on a missing directory
    /// (same as the real backend) and on the first artifact otherwise.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        for (stem, path) in discover_artifacts(dir)? {
            self.load(&stem, &path)?;
            loaded.push(stem);
        }
        Ok(loaded)
    }

    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }

    /// Execute `name` with f32 tensor inputs given as (data, dims) pairs.
    pub fn exec(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let tensors: Vec<Tensor> =
            inputs.iter().map(|(d, s)| Tensor::F32(d, s)).collect();
        self.exec_tensors(name, &tensors)
    }

    /// Execute with mixed-dtype inputs. No artifact can be loaded in the
    /// stub, so this always reports the artifact as unknown.
    pub fn exec_tensors(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("unknown artifact {name:?} (loaded: {:?})", self.names()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need built artifacts live in rust/tests/;
    // here we only check graceful failure paths that need no artifacts.

    #[test]
    fn missing_artifact_is_reported() {
        let rt = Runtime::cpu().expect("cpu client");
        let err = rt.exec("nope", &[]).unwrap_err().to_string();
        assert!(err.contains("unknown artifact"), "{err}");
    }

    #[test]
    fn load_dir_missing_errors() {
        let mut rt = Runtime::cpu().expect("cpu client");
        assert!(rt.load_dir(Path::new("/definitely/missing")).is_err());
    }

    #[test]
    fn artifacts_dir_defaults_to_relative() {
        // Avoid asserting on the env var (other tests run in parallel);
        // the default path is what matters for the repo layout.
        if std::env::var("SAM_ARTIFACTS").is_err() {
            assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
        }
    }
}
