//! Asynchronous data-parallel workers (paper Supp C: "8 asynchronous
//! workers to speed up training").
//!
//! Each worker owns a full replica of the core (memory, ANN, ring are
//! per-replica state; parameters are what's shared). Before each round the
//! replicas load the current parameter vector; each runs a slice of the
//! batch; gradients are summed into the primary and the optimizer steps.
//! This is synchronous data parallelism — on the paper's 6-core Xeon the
//! asynchrony bought wall-clock speed, not a different algorithm; on this
//! 1-core container the worker count is a fidelity knob, not a speedup.

use crate::cores::Core;
use crate::curriculum::Curriculum;
use crate::optim::Optimizer;
use crate::tasks::Task;
use crate::training::{train_episode, TrainConfig, TrainLog, LogPoint};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Multi-worker trainer. `factory(i)` builds worker i's core replica.
pub struct ParallelTrainer {
    pub workers: Vec<Box<dyn Core>>,
    pub opt: Box<dyn Optimizer>,
    pub cfg: TrainConfig,
}

impl ParallelTrainer {
    pub fn new(
        factory: &mut dyn FnMut(usize) -> Box<dyn Core>,
        n_workers: usize,
        opt: Box<dyn Optimizer>,
        cfg: TrainConfig,
    ) -> ParallelTrainer {
        assert!(n_workers >= 1);
        let workers = (0..n_workers).map(|i| factory(i)).collect();
        ParallelTrainer { workers, opt, cfg }
    }

    pub fn run(&mut self, task: &(dyn Task + Sync), curriculum: &mut Curriculum) -> TrainLog {
        let n_workers = self.workers.len();
        let mut log = TrainLog::default();
        let timer = Timer::start();
        let mut window_loss = 0.0f64;
        let mut window_scored = 0usize;
        let mut window_errors = 0.0f64;
        let mut window_eps = 0usize;
        let mut rng = Rng::new(self.cfg.seed);

        for update in 1..=self.cfg.updates {
            // Broadcast parameters from worker 0.
            let flat = self.workers[0].save_values();
            for wi in 1..n_workers {
                self.workers[wi].load_values(&flat);
                self.workers[wi].zero_grads();
            }
            // Pre-sample episodes (levels drawn on the main thread so the
            // curriculum stays deterministic).
            let per_worker = self.cfg.batch.div_ceil(n_workers);
            let episodes: Vec<Vec<_>> = (0..n_workers)
                .map(|_| {
                    (0..per_worker)
                        .map(|_| {
                            let level = curriculum.sample_level(&mut rng);
                            task.sample(level, &mut rng)
                        })
                        .collect()
                })
                .collect();

            // Run workers in parallel over their episode slices.
            let results: Vec<Vec<(f64, usize, f64)>> = std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .zip(episodes.iter())
                    .map(|(core, eps)| {
                        scope.spawn(move || {
                            eps.iter()
                                .map(|ep| {
                                    let (loss, scored, outputs) =
                                        train_episode(core.as_mut(), ep);
                                    (loss, scored, crate::tasks::default_errors(ep, &outputs))
                                })
                                .collect()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            // Reduce gradients into worker 0 and report to the curriculum.
            for wi in 1..n_workers {
                let mut grads: Vec<f32> = Vec::new();
                self.workers[wi].visit_params(&mut |p| grads.extend_from_slice(&p.g.data));
                let mut off = 0;
                self.workers[0].visit_params(&mut |p| {
                    for v in p.g.data.iter_mut() {
                        *v += grads[off];
                        off += 1;
                    }
                });
            }
            for per in &results {
                for &(loss, scored, errors) in per {
                    let scored = scored.max(1);
                    curriculum.report(loss / scored as f64);
                    window_loss += loss;
                    window_scored += scored;
                    window_errors += errors;
                    window_eps += 1;
                    log.total_episodes += 1;
                }
            }
            self.opt.step(self.workers[0].as_mut());

            if update % self.cfg.log_every == 0 || update == self.cfg.updates {
                let point = LogPoint {
                    update,
                    loss: window_loss / window_scored.max(1) as f64,
                    errors: window_errors / window_eps.max(1) as f64,
                    level: curriculum.h,
                    wall_s: timer.elapsed_s(),
                };
                if self.cfg.verbose {
                    println!(
                        "[{}x{}] update {:>5} loss/step {:.4} errors/ep {:.3} level {}",
                        self.workers[0].name(),
                        n_workers,
                        point.update,
                        point.loss,
                        point.errors,
                        point.level
                    );
                }
                log.points.push(point);
                window_loss = 0.0;
                window_scored = 0;
                window_errors = 0.0;
                window_eps = 0;
            }
        }
        log.final_level = curriculum.h;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::{build_core, CoreConfig, CoreKind};
    use crate::optim::RmsProp;
    use crate::tasks::copy::CopyTask;

    #[test]
    fn parallel_matches_learning_signal() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 12,
            heads: 1,
            word: 6,
            mem_words: 12,
            k: 2,
            seed: 5,
            ..CoreConfig::default()
        };
        let mut seed_rng = Rng::new(5);
        let mut factory = |_i: usize| build_core(CoreKind::Sam, &core_cfg, &mut seed_rng);
        let mut pt = ParallelTrainer::new(
            &mut factory,
            2,
            Box::new(RmsProp::new(3e-3)),
            TrainConfig { batch: 4, updates: 30, log_every: 5, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(2);
        let log = pt.run(&task, &mut cur);
        assert_eq!(log.total_episodes, 30 * 4);
        assert!(log.best_loss() < log.points[0].loss * 1.05);
    }

    #[test]
    fn single_worker_is_degenerate_case() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 6,
            ..CoreConfig::default()
        };
        let mut seed_rng = Rng::new(6);
        let mut factory = |_i: usize| build_core(CoreKind::Lstm, &core_cfg, &mut seed_rng);
        let mut pt = ParallelTrainer::new(
            &mut factory,
            1,
            Box::new(RmsProp::new(1e-3)),
            TrainConfig { batch: 2, updates: 5, log_every: 5, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(2);
        let log = pt.run(&task, &mut cur);
        assert_eq!(log.total_episodes, 10);
    }
}
