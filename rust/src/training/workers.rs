//! Multi-threaded data-parallel workers (paper Supp C: "8 asynchronous
//! workers to speed up training").
//!
//! Each worker owns a full core replica (memory, ANN and ring are
//! per-replica state; parameters are what's shared) and runs on its own OS
//! thread inside `std::thread::scope`. Per update:
//!
//! 1. the primary replica's parameters are broadcast to every worker;
//! 2. the whole batch is sampled on the main thread in episode order
//!    (curriculum + RNG stay single-threaded and deterministic);
//! 3. episodes are dealt round-robin (episode e → worker e mod W) and each
//!    worker computes *per-episode* gradients for its slice in parallel;
//! 4. the main thread reduces the per-episode gradients **in episode
//!    order** into the primary and the optimizer steps.
//!
//! Because every episode's gradient is computed from zeroed accumulators
//! against the same broadcast parameters, and the reduction is one fixed
//! left-to-right summation over episode indices, a given seed produces
//! bit-identical parameters, losses and curriculum decisions at any worker
//! count — and identical to the serial [`crate::training::Trainer`], which
//! follows the same protocol. (Cores whose ANN index is history-dependent across episodes —
//! `AnnKind::KdForest` / `AnnKind::Lsh` — are deterministic per worker
//! count but can diverge *across* counts because each replica's index sees
//! a different episode subsequence; with `AnnKind::Linear` and all dense
//! cores the guarantee is exact. See DESIGN.md.)
//!
//! Worker count therefore buys wall-clock speed, never a different
//! algorithm — the synchronous analogue of the paper's asynchrony.

use crate::cores::Core;
use crate::curriculum::Curriculum;
use crate::optim::Optimizer;
use crate::tasks::Task;
use crate::training::{
    episode_grad, reduce_episode_grads, sample_batch, EpisodeGrad, LogPoint, TrainConfig,
    TrainLog,
};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Multi-worker trainer. `factory(i)` builds worker i's core replica.
pub struct ParallelTrainer {
    pub workers: Vec<Box<dyn Core>>,
    pub opt: Box<dyn Optimizer>,
    pub cfg: TrainConfig,
}

impl ParallelTrainer {
    /// Build `n_workers` replicas. The factory **must** return identical
    /// replicas (same parameters and same internal seeds — e.g. construct
    /// from a fresh `Rng::new(seed)` on every call); parameter equality is
    /// asserted here, and parameters are re-broadcast every update anyway.
    pub fn new(
        factory: &mut dyn FnMut(usize) -> Box<dyn Core>,
        n_workers: usize,
        opt: Box<dyn Optimizer>,
        cfg: TrainConfig,
    ) -> ParallelTrainer {
        assert!(n_workers >= 1);
        let mut workers: Vec<Box<dyn Core>> = (0..n_workers).map(|i| factory(i)).collect();
        let reference = workers[0].save_values();
        for (i, w) in workers.iter_mut().enumerate().skip(1) {
            assert_eq!(
                w.save_values(),
                reference,
                "worker {i} replica differs from the primary — the factory must \
                 build identical replicas (fresh Rng::new(seed) per call)"
            );
        }
        ParallelTrainer { workers, opt, cfg }
    }

    /// Hand back the primary replica and optimizer (for checkpointing or
    /// wrapping in a serial [`crate::training::Trainer`] after training).
    pub fn into_primary(mut self) -> (Box<dyn Core>, Box<dyn Optimizer>) {
        (self.workers.swap_remove(0), self.opt)
    }

    pub fn run(&mut self, task: &dyn Task, curriculum: &mut Curriculum) -> TrainLog {
        // `Task: Send + Sync` are supertraits, so `&dyn Task` crosses the
        // scoped-thread boundary without an explicit `+ Sync` in the type.
        let n_workers = self.workers.len();
        let mut log = TrainLog::default();
        let timer = Timer::start();
        let mut window_loss = 0.0f64;
        let mut window_scored = 0usize;
        let mut window_errors = 0.0f64;
        let mut window_eps = 0usize;
        let mut rng = Rng::new(self.cfg.seed);

        for update in 1..=self.cfg.updates {
            // Broadcast parameters from the primary replica.
            if n_workers > 1 {
                let flat = self.workers[0].save_values();
                for wi in 1..n_workers {
                    self.workers[wi].load_values(&flat);
                }
            }
            // Pre-sample the batch on the main thread, in episode order.
            let episodes = sample_batch(task, curriculum, &mut rng, self.cfg.batch);

            // Deal episodes round-robin and run the slices in parallel,
            // tagging each result with its global episode index.
            let mut results: Vec<(usize, EpisodeGrad)> = std::thread::scope(|scope| {
                let eps = &episodes;
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .enumerate()
                    .map(|(w, core)| {
                        scope.spawn(move || {
                            let mut out = Vec::new();
                            let mut e = w;
                            while e < eps.len() {
                                out.push((e, episode_grad(core.as_mut(), task, &eps[e])));
                                e += n_workers;
                            }
                            out
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });

            // Deterministic fixed-order reduction: episode order, on this
            // thread, regardless of which worker produced what when.
            results.sort_by_key(|&(e, _)| e);
            let ordered: Vec<EpisodeGrad> = results.into_iter().map(|(_, r)| r).collect();
            let reduce_start = std::time::Instant::now();
            reduce_episode_grads(self.workers[0].as_mut(), &ordered);
            for r in &ordered {
                let scored = r.scored.max(1);
                curriculum.report(r.loss / scored as f64);
                window_loss += r.loss;
                window_scored += scored;
                window_errors += r.errors;
                window_eps += 1;
                log.total_episodes += 1;
            }
            crate::util::metrics::TRAIN_EPISODES.add(ordered.len() as u64);
            self.opt.step(self.workers[0].as_mut());
            crate::util::metrics::TRAIN_GRAD_REDUCE_US.observe_since(reduce_start);

            if update % self.cfg.log_every == 0 || update == self.cfg.updates {
                let point = LogPoint {
                    update,
                    loss: window_loss / window_scored.max(1) as f64,
                    errors: window_errors / window_eps.max(1) as f64,
                    level: curriculum.h,
                    wall_s: timer.elapsed_s(),
                };
                if self.cfg.verbose {
                    println!(
                        "[{}x{}] update {:>5} loss/step {:.4} errors/ep {:.3} level {}",
                        self.workers[0].name(),
                        n_workers,
                        point.update,
                        point.loss,
                        point.errors,
                        point.level
                    );
                }
                log.points.push(point);
                window_loss = 0.0;
                window_scored = 0;
                window_errors = 0.0;
                window_eps = 0;
            }
        }
        log.final_level = curriculum.h;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::{build_core, CoreConfig, CoreKind};
    use crate::optim::RmsProp;
    use crate::tasks::copy::CopyTask;

    #[test]
    fn parallel_matches_learning_signal() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 12,
            heads: 1,
            word: 6,
            mem_words: 12,
            k: 2,
            seed: 5,
            ..CoreConfig::default()
        };
        // Identical replicas: a fresh seeded Rng per factory call.
        let mut factory = |_i: usize| {
            let mut rng = Rng::new(5);
            build_core(CoreKind::Sam, &core_cfg, &mut rng)
        };
        let mut pt = ParallelTrainer::new(
            &mut factory,
            2,
            Box::new(RmsProp::new(3e-3)),
            TrainConfig { batch: 4, updates: 30, log_every: 5, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(2);
        let log = pt.run(&task, &mut cur);
        assert_eq!(log.total_episodes, 30 * 4);
        assert!(log.best_loss() < log.points[0].loss * 1.05);
    }

    #[test]
    fn single_worker_is_degenerate_case() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 6,
            ..CoreConfig::default()
        };
        let mut factory = |_i: usize| {
            let mut rng = Rng::new(6);
            build_core(CoreKind::Lstm, &core_cfg, &mut rng)
        };
        let mut pt = ParallelTrainer::new(
            &mut factory,
            1,
            Box::new(RmsProp::new(1e-3)),
            TrainConfig { batch: 2, updates: 5, log_every: 5, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(2);
        let log = pt.run(&task, &mut cur);
        assert_eq!(log.total_episodes, 10);
    }

    #[test]
    fn more_workers_than_batch_is_fine() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 8,
            ..CoreConfig::default()
        };
        let mut factory = |_i: usize| {
            let mut rng = Rng::new(8);
            build_core(CoreKind::Lstm, &core_cfg, &mut rng)
        };
        let mut pt = ParallelTrainer::new(
            &mut factory,
            4,
            Box::new(RmsProp::new(1e-3)),
            TrainConfig { batch: 2, updates: 3, log_every: 3, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(2);
        let log = pt.run(&task, &mut cur);
        assert_eq!(log.total_episodes, 6, "exactly `batch` episodes per update");
    }

    #[test]
    #[should_panic(expected = "replica differs")]
    fn mismatched_replicas_rejected() {
        let task = CopyTask::new(4);
        let core_cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 9,
            ..CoreConfig::default()
        };
        // A shared Rng across factory calls produces different replicas.
        let mut shared = Rng::new(9);
        let mut factory = |_i: usize| build_core(CoreKind::Lstm, &core_cfg, &mut shared);
        let _ = ParallelTrainer::new(
            &mut factory,
            2,
            Box::new(RmsProp::new(1e-3)),
            TrainConfig::default(),
        );
    }
}
