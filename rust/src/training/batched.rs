//! Batched-episode training (`--batch-fuse B`): each worker drives B
//! episode lanes in lockstep through the fused training ticks
//! ([`crate::cores::train_tick_forward`] / [`crate::cores::train_tick_backward`]),
//! so every controller projection runs as ONE lane-fused kernel per step
//! and the lanes' ANN lookups merge into a single `ShardPool` dispatch.
//!
//! The trainer follows the exact canonical batch protocol of
//! [`crate::training::Trainer`] and [`super::workers::ParallelTrainer`]:
//!
//! 1. the primary lane's parameters are broadcast to every lane of every
//!    worker;
//! 2. the whole batch is sampled on the main thread in episode order;
//! 3. episodes are dealt round-robin (episode e → worker e mod W, exactly
//!    as `ParallelTrainer`) and each worker runs its slice in consecutive
//!    groups of ≤ B lanes;
//! 4. the main thread reduces the per-episode gradients in episode order
//!    and the optimizer steps.
//!
//! Each lane is a full core replica (private memory, ANN, journals, tape)
//! holding identical parameters; only the controller's dense projections
//! fuse across lanes, via the order-preserving kernels (`gemv_many` /
//! `gemm_rowsweep`). Every lane therefore replays the serial float-op
//! sequence exactly, per-episode gradients are computed from zeroed
//! accumulators as always, and the reduction is the same fixed-order sum —
//! so a given seed is **bit-identical at any (workers, batch_fuse)
//! combination**, including (1, 1) = the serial trainer, for `ann=linear`
//! (the same caveat as worker count: history-dependent ANN indices can
//! diverge across lane counts; see `workers`). Pinned by
//! rust/tests/batch_parity.rs, documented in DESIGN.md "Batched training".
//!
//! Cores without a batched seam (`ntm` / `dam` / `dnc`) fall back to the
//! per-episode serial path inside the same worker/reduction harness, so
//! `--batch-fuse` is accepted — and deterministic — for every model.

use crate::cores::lstm_core::LstmCore;
use crate::cores::sam::SamCore;
use crate::cores::sdnc::SdncCore;
use crate::cores::{
    build_core, train_tick_backward, train_tick_forward, BatchCore, Core, CoreConfig, CoreKind,
    TrainBatch,
};
use crate::curriculum::Curriculum;
use crate::optim::Optimizer;
use crate::tasks::{episode_loss_grad, Episode, Task};
use crate::training::{
    episode_grad, reduce_episode_grads, sample_batch, EpisodeGrad, LogPoint, TrainConfig,
    TrainLog,
};
use crate::util::metrics;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// One worker's lane group: B identical replicas of a batch-capable core,
/// or the serial fallback for kinds without a batched seam.
pub enum FusedLanes {
    Sam(Vec<SamCore>),
    Sdnc(Vec<SdncCore>),
    Lstm(Vec<LstmCore>),
    /// Per-episode serial path (ntm/dam/dnc) inside the same harness.
    Serial(Box<dyn Core>),
}

impl FusedLanes {
    /// Build a lane group. Every lane is constructed from a fresh
    /// `Rng::new(cfg.seed)` so all lanes (and all workers' lanes) hold
    /// bit-identical parameters — the same replica contract as
    /// [`super::workers::ParallelTrainer`].
    pub fn build(kind: CoreKind, cfg: &CoreConfig, lanes: usize) -> FusedLanes {
        assert!(lanes >= 1);
        match kind {
            CoreKind::Sam => FusedLanes::Sam(
                (0..lanes).map(|_| SamCore::new(cfg, &mut Rng::new(cfg.seed))).collect(),
            ),
            CoreKind::Sdnc => FusedLanes::Sdnc(
                (0..lanes).map(|_| SdncCore::new(cfg, &mut Rng::new(cfg.seed))).collect(),
            ),
            CoreKind::Lstm => FusedLanes::Lstm(
                (0..lanes).map(|_| LstmCore::new(cfg, &mut Rng::new(cfg.seed))).collect(),
            ),
            other => FusedLanes::Serial(build_core(other, cfg, &mut Rng::new(cfg.seed))),
        }
    }

    /// The primary lane as a `Core` (lane 0 — parameters are broadcast from
    /// worker 0's primary every update).
    fn primary_mut(&mut self) -> &mut dyn Core {
        match self {
            FusedLanes::Sam(v) => &mut v[0],
            FusedLanes::Sdnc(v) => &mut v[0],
            FusedLanes::Lstm(v) => &mut v[0],
            FusedLanes::Serial(c) => c.as_mut(),
        }
    }

    /// Load `flat` into every lane, optionally skipping lane 0 (the
    /// broadcast source itself).
    fn load_all(&mut self, flat: &[f32], skip_primary: bool) {
        let skip = usize::from(skip_primary);
        match self {
            FusedLanes::Sam(v) => v.iter_mut().skip(skip).for_each(|c| c.load_values(flat)),
            FusedLanes::Sdnc(v) => v.iter_mut().skip(skip).for_each(|c| c.load_values(flat)),
            FusedLanes::Lstm(v) => v.iter_mut().skip(skip).for_each(|c| c.load_values(flat)),
            FusedLanes::Serial(c) => {
                if !skip_primary {
                    c.load_values(flat);
                }
            }
        }
    }

    /// Run one group of ≤ B episodes, pushing `(global episode index,
    /// gradient)` results. Fused kinds run the lockstep ticks; the serial
    /// fallback runs [`episode_grad`] per episode.
    fn run_group(
        &mut self,
        batch: &mut TrainBatch,
        task: &dyn Task,
        eps: &[(usize, &Episode)],
        out: &mut Vec<(usize, EpisodeGrad)>,
    ) {
        match self {
            FusedLanes::Sam(v) => run_group(v, batch, task, eps, out),
            FusedLanes::Sdnc(v) => run_group(v, batch, task, eps, out),
            FusedLanes::Lstm(v) => run_group(v, batch, task, eps, out),
            FusedLanes::Serial(c) => {
                for (e, ep) in eps {
                    out.push((*e, episode_grad(c.as_mut(), task, ep)));
                }
            }
        }
    }
}

/// Drive one group of episodes through the fused ticks: lockstep forward
/// over max-length steps (shorter episodes idle their lane), loss gradients
/// staged per step, lockstep backward in reverse. Per-episode isolation is
/// structural — each lane owns its accumulators and is zeroed up front, so
/// the extracted flat gradients are exactly the serial [`episode_grad`]
/// vectors.
fn run_group<C: BatchCore>(
    lanes: &mut [C],
    batch: &mut TrainBatch,
    task: &dyn Task,
    eps: &[(usize, &Episode)],
    out: &mut Vec<(usize, EpisodeGrad)>,
) {
    let n = eps.len();
    assert!(n <= lanes.len(), "group of {n} episodes exceeds {} lanes", lanes.len());
    if n == 0 {
        return;
    }
    let lanes = &mut lanes[..n];
    let y_dim = lanes[0].y_dim();
    let t_max = eps.iter().map(|(_, ep)| ep.len()).max().unwrap_or(0);
    for lane in lanes.iter_mut() {
        lane.zero_grads();
        lane.reset();
    }
    let mut losses = vec![0.0f64; n];
    let mut outputs: Vec<Vec<Vec<f32>>> = (0..n).map(|_| Vec::new()).collect();
    let mut dys: Vec<Vec<Vec<f32>>> = (0..n).map(|_| Vec::new()).collect();
    let mut xs: Vec<Option<&[f32]>> = Vec::with_capacity(n);
    for t in 0..t_max {
        xs.clear();
        xs.extend(eps.iter().map(|(_, ep)| ep.inputs.get(t).map(|v| v.as_slice())));
        train_tick_forward(lanes, batch, &xs);
        for (l, (_, ep)) in eps.iter().enumerate() {
            if t < ep.len() {
                let y = batch.y_row(l).to_vec();
                let (lo, dy) = episode_loss_grad(ep, t, &y);
                losses[l] += lo as f64;
                dys[l].push(dy);
                outputs[l].push(y);
            }
        }
    }
    let mut active: Vec<bool> = Vec::with_capacity(n);
    for t in (0..t_max).rev() {
        active.clear();
        active.extend(eps.iter().map(|(_, ep)| t < ep.len()));
        batch.stage_dy(n, y_dim);
        for (l, (_, ep)) in eps.iter().enumerate() {
            if t < ep.len() {
                batch.dy_row_mut(l).copy_from_slice(&dys[l][t]);
            }
        }
        train_tick_backward(lanes, batch, &active);
    }
    for (l, (e, ep)) in eps.iter().enumerate() {
        lanes[l].end_episode();
        out.push((
            *e,
            EpisodeGrad {
                loss: losses[l],
                scored: ep.scored_steps(),
                errors: task.errors(ep, &outputs[l]),
                grad: lanes[l].save_grads(),
            },
        ));
    }
}

/// One worker thread's state: its lane group plus the reusable tick scratch.
struct FusedWorker {
    lanes: FusedLanes,
    batch: TrainBatch,
}

/// The threads × batch trainer (`--workers W --batch-fuse B`): W OS threads,
/// each fusing up to B episode lanes per tick. See the module docs for the
/// determinism contract.
pub struct FusedTrainer {
    workers: Vec<FusedWorker>,
    pub opt: Box<dyn Optimizer>,
    pub cfg: TrainConfig,
}

impl FusedTrainer {
    pub fn new(
        kind: CoreKind,
        core_cfg: &CoreConfig,
        n_workers: usize,
        opt: Box<dyn Optimizer>,
        cfg: TrainConfig,
    ) -> FusedTrainer {
        assert!(n_workers >= 1);
        let lanes = cfg.batch_fuse.max(1);
        let mut workers: Vec<FusedWorker> = (0..n_workers)
            .map(|_| FusedWorker {
                lanes: FusedLanes::build(kind, core_cfg, lanes),
                batch: TrainBatch::new(),
            })
            .collect();
        let reference = workers[0].lanes.primary_mut().save_values();
        for (i, w) in workers.iter_mut().enumerate().skip(1) {
            assert_eq!(
                w.lanes.primary_mut().save_values(),
                reference,
                "worker {i} replica differs from the primary"
            );
        }
        FusedTrainer { workers, opt, cfg }
    }

    /// Hand back the primary lane and optimizer (for checkpointing or
    /// wrapping in a serial [`crate::training::Trainer`] after training).
    pub fn into_primary(mut self) -> (Box<dyn Core>, Box<dyn Optimizer>) {
        let w = self.workers.swap_remove(0);
        let core: Box<dyn Core> = match w.lanes {
            FusedLanes::Sam(mut v) => Box::new(v.swap_remove(0)),
            FusedLanes::Sdnc(mut v) => Box::new(v.swap_remove(0)),
            FusedLanes::Lstm(mut v) => Box::new(v.swap_remove(0)),
            FusedLanes::Serial(c) => c,
        };
        (core, self.opt)
    }

    pub fn run(&mut self, task: &dyn Task, curriculum: &mut Curriculum) -> TrainLog {
        let n_workers = self.workers.len();
        let b = self.cfg.batch_fuse.max(1);
        let mut log = TrainLog::default();
        let timer = Timer::start();
        let mut window_loss = 0.0f64;
        let mut window_scored = 0usize;
        let mut window_errors = 0.0f64;
        let mut window_eps = 0usize;
        let mut rng = Rng::new(self.cfg.seed);

        for update in 1..=self.cfg.updates {
            // Broadcast parameters from the primary lane to every lane.
            let flat = self.workers[0].lanes.primary_mut().save_values();
            for (wi, w) in self.workers.iter_mut().enumerate() {
                w.lanes.load_all(&flat, wi == 0);
            }
            // Pre-sample the batch on the main thread, in episode order.
            let episodes = sample_batch(task, curriculum, &mut rng, self.cfg.batch);

            // Deal episodes round-robin (same schedule as ParallelTrainer)
            // and run each worker's slice in consecutive groups of ≤ B.
            let mut results: Vec<(usize, EpisodeGrad)> = std::thread::scope(|scope| {
                let eps = &episodes;
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .enumerate()
                    .map(|(w, worker)| {
                        scope.spawn(move || {
                            let mut mine: Vec<(usize, &Episode)> = Vec::new();
                            let mut e = w;
                            while e < eps.len() {
                                mine.push((e, &eps[e]));
                                e += n_workers;
                            }
                            let mut out = Vec::new();
                            for chunk in mine.chunks(b) {
                                worker.lanes.run_group(
                                    &mut worker.batch,
                                    task,
                                    chunk,
                                    &mut out,
                                );
                            }
                            out
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });

            // Deterministic fixed-order reduction: episode order, on this
            // thread, regardless of lane/worker provenance.
            results.sort_by_key(|&(e, _)| e);
            let ordered: Vec<EpisodeGrad> = results.into_iter().map(|(_, r)| r).collect();
            let reduce_start = std::time::Instant::now();
            reduce_episode_grads(self.workers[0].lanes.primary_mut(), &ordered);
            for r in &ordered {
                let scored = r.scored.max(1);
                curriculum.report(r.loss / scored as f64);
                window_loss += r.loss;
                window_scored += scored;
                window_errors += r.errors;
                window_eps += 1;
                log.total_episodes += 1;
            }
            metrics::TRAIN_EPISODES.add(ordered.len() as u64);
            self.opt.step(self.workers[0].lanes.primary_mut());
            // Reduce + apply time per update (the serial section between
            // parallel episode groups — the scaling ceiling).
            metrics::TRAIN_GRAD_REDUCE_US.observe_since(reduce_start);

            if update % self.cfg.log_every == 0 || update == self.cfg.updates {
                let point = LogPoint {
                    update,
                    loss: window_loss / window_scored.max(1) as f64,
                    errors: window_errors / window_eps.max(1) as f64,
                    level: curriculum.h,
                    wall_s: timer.elapsed_s(),
                };
                if self.cfg.verbose {
                    println!(
                        "[{}x{}b{}] update {:>5} loss/step {:.4} errors/ep {:.3} level {}",
                        self.workers[0].lanes.primary_mut().name(),
                        n_workers,
                        b,
                        point.update,
                        point.loss,
                        point.errors,
                        point.level
                    );
                }
                log.points.push(point);
                window_loss = 0.0;
                window_scored = 0;
                window_errors = 0.0;
                window_eps = 0;
            }
        }
        log.final_level = curriculum.h;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::RmsProp;
    use crate::tasks::copy::CopyTask;
    use crate::training::Trainer;

    fn core_cfg(task: &CopyTask, seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 10,
            heads: 1,
            word: 6,
            mem_words: 12,
            k: 2,
            seed,
            ..CoreConfig::default()
        }
    }

    fn train_cfg(batch_fuse: usize) -> TrainConfig {
        TrainConfig {
            lr: 2e-3,
            batch: 5,
            updates: 8,
            log_every: 4,
            seed: 11,
            verbose: false,
            batch_fuse,
        }
    }

    /// The fused trainer at B ∈ {2, 8} (lanes exceeding the batch included)
    /// produces bit-identical parameters to the serial Trainer for the
    /// dense witness. The full SAM/SDNC × workers matrix lives in
    /// rust/tests/batch_parity.rs.
    #[test]
    fn fused_lstm_matches_serial_trainer_bitwise() {
        let task = CopyTask::new(4);
        let ccfg = core_cfg(&task, 21);
        let mut serial = Trainer::new(
            build_core(CoreKind::Lstm, &ccfg, &mut Rng::new(21)),
            Box::new(RmsProp::new(2e-3)),
            train_cfg(1),
        );
        let mut cur = Curriculum::fixed(2);
        let slog = serial.run(&task, &mut cur);
        let sparams = serial.core.save_values();

        for b in [2usize, 8] {
            let mut fused = FusedTrainer::new(
                CoreKind::Lstm,
                &ccfg,
                1,
                Box::new(RmsProp::new(2e-3)),
                train_cfg(b),
            );
            let mut cur = Curriculum::fixed(2);
            let flog = fused.run(&task, &mut cur);
            assert_eq!(flog.total_episodes, slog.total_episodes);
            for (a, p) in slog.points.iter().zip(&flog.points) {
                assert_eq!(a.loss.to_bits(), p.loss.to_bits(), "B={b} loss diverged");
            }
            let (mut core, _) = fused.into_primary();
            let fparams = core.save_values();
            assert_eq!(sparams.len(), fparams.len());
            for (x, y) in sparams.iter().zip(&fparams) {
                assert_eq!(x.to_bits(), y.to_bits(), "B={b} param diverged");
            }
        }
    }

    /// Serial-fallback kinds run through the same harness unchanged.
    #[test]
    fn fallback_kind_matches_serial_trainer_bitwise() {
        let task = CopyTask::new(4);
        let ccfg = core_cfg(&task, 23);
        let mut serial = Trainer::new(
            build_core(CoreKind::Ntm, &ccfg, &mut Rng::new(23)),
            Box::new(RmsProp::new(2e-3)),
            train_cfg(1),
        );
        let mut cur = Curriculum::fixed(2);
        serial.run(&task, &mut cur);
        let sparams = serial.core.save_values();

        let mut fused = FusedTrainer::new(
            CoreKind::Ntm,
            &ccfg,
            2,
            Box::new(RmsProp::new(2e-3)),
            train_cfg(4),
        );
        let mut cur = Curriculum::fixed(2);
        fused.run(&task, &mut cur);
        let (mut core, _) = fused.into_primary();
        assert_eq!(core.save_values(), sparams);
    }
}
