//! Training stack: episode runner, BPTT trainer with curriculum, and
//! (optionally) multi-worker data parallelism ([`workers`]).

pub mod batched;
pub mod workers;

use crate::cores::Core;
use crate::curriculum::Curriculum;
use crate::optim::Optimizer;
use crate::tasks::{episode_loss_grad, Episode, Task};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Trainer hyper-parameters (paper Supp C: RMSProp, minibatch 8).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f32,
    /// Episodes accumulated per parameter update.
    pub batch: usize,
    /// Parameter updates to run.
    pub updates: usize,
    pub log_every: usize,
    pub seed: u64,
    /// Print progress lines.
    pub verbose: bool,
    /// Episode lanes fused per worker through the batched training tick
    /// (`--batch-fuse`; see [`batched::FusedTrainer`]). 1 = the serial
    /// per-episode path. Bit-identical at any value for `ann=linear`.
    pub batch_fuse: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 1e-4,
            batch: 8,
            updates: 200,
            log_every: 10,
            seed: 7,
            verbose: false,
            batch_fuse: 1,
        }
    }
}

/// One logged point of a training run.
#[derive(Debug, Clone)]
pub struct LogPoint {
    pub update: usize,
    /// Mean loss per scored step over the logging window.
    pub loss: f64,
    /// Mean task errors per episode over the window.
    pub errors: f64,
    /// Curriculum ceiling h at this point.
    pub level: usize,
    pub wall_s: f64,
}

/// Full run record (serializable for EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub points: Vec<LogPoint>,
    pub final_level: usize,
    pub total_episodes: usize,
}

impl TrainLog {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_level", Json::num(self.final_level as f64)),
            ("total_episodes", Json::num(self.total_episodes as f64)),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("update", Json::num(p.update as f64)),
                        ("loss", Json::num(p.loss)),
                        ("errors", Json::num(p.errors)),
                        ("level", Json::num(p.level as f64)),
                        ("wall_s", Json::num(p.wall_s)),
                    ])
                })),
            ),
        ])
    }

    /// Smallest loss seen over the run.
    pub fn best_loss(&self) -> f64 {
        self.points.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min)
    }
}

/// Run one training episode: forward, per-step loss, backward, gradients
/// accumulated into the core's params. Returns (total loss, scored steps,
/// outputs).
pub fn train_episode(core: &mut dyn Core, ep: &Episode) -> (f64, usize, Vec<Vec<f32>>) {
    core.reset();
    let mut dys: Vec<Vec<f32>> = Vec::with_capacity(ep.len());
    let mut outputs = Vec::with_capacity(ep.len());
    let mut loss = 0.0f64;
    for t in 0..ep.len() {
        let y = core.forward(&ep.inputs[t]);
        let (l, dy) = episode_loss_grad(ep, t, &y);
        loss += l as f64;
        dys.push(dy);
        outputs.push(y);
    }
    for dy in dys.iter().rev() {
        core.backward(dy);
    }
    core.end_episode();
    (loss, ep.scored_steps(), outputs)
}

/// One episode's contribution to a batched parameter update.
#[derive(Debug, Clone)]
pub struct EpisodeGrad {
    pub loss: f64,
    pub scored: usize,
    pub errors: f64,
    /// Flat gradient of this episode alone (`HasParams::save_grads` layout).
    pub grad: Vec<f32>,
}

/// Run one episode from zeroed gradients and extract its flat gradient.
///
/// This is the unit of work of the canonical batch protocol shared by
/// [`Trainer`] and [`workers::ParallelTrainer`]: every episode's gradient
/// is computed in isolation and the batch gradient is the sum of the
/// per-episode vectors *in episode order*. Because that fixed-order
/// reduction always happens on one thread, a given seed produces
/// bit-identical updates at any worker count (see `workers`).
pub fn episode_grad(core: &mut dyn Core, task: &dyn Task, ep: &Episode) -> EpisodeGrad {
    core.zero_grads();
    let (loss, scored, outputs) = train_episode(core, ep);
    EpisodeGrad { loss, scored, errors: task.errors(ep, &outputs), grad: core.save_grads() }
}

/// Draw one update's episodes up-front, levels in episode order. Sampling
/// the whole batch before any training keeps the RNG stream — and thus the
/// episodes — identical between the serial and data-parallel trainers.
pub fn sample_batch(
    task: &dyn Task,
    curriculum: &Curriculum,
    rng: &mut Rng,
    batch: usize,
) -> Vec<Episode> {
    (0..batch)
        .map(|_| {
            let level = curriculum.sample_level(rng);
            task.sample(level, rng)
        })
        .collect()
}

/// Sum per-episode gradients in episode order into `core`'s accumulators.
/// One fixed association for every worker count ⇒ bitwise determinism.
pub(crate) fn reduce_episode_grads(core: &mut dyn Core, results: &[EpisodeGrad]) {
    if results.is_empty() {
        return;
    }
    let mut batch_grad = vec![0.0f32; results[0].grad.len()];
    for r in results {
        crate::tensor::matrix::axpy(&mut batch_grad, 1.0, &r.grad);
    }
    core.load_grads(&batch_grad);
}

/// Evaluate an episode without gradients (forward + rollback).
pub fn eval_episode(core: &mut dyn Core, ep: &Episode) -> (f64, Vec<Vec<f32>>) {
    core.reset();
    let mut outputs = Vec::with_capacity(ep.len());
    let mut loss = 0.0f64;
    for t in 0..ep.len() {
        let y = core.forward(&ep.inputs[t]);
        let (l, _) = episode_loss_grad(ep, t, &y);
        loss += l as f64;
        outputs.push(y);
    }
    core.rollback();
    core.end_episode();
    (loss, outputs)
}

/// Single-threaded trainer driving core + optimizer + curriculum.
pub struct Trainer {
    pub core: Box<dyn Core>,
    pub opt: Box<dyn Optimizer>,
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(core: Box<dyn Core>, opt: Box<dyn Optimizer>, cfg: TrainConfig) -> Trainer {
        Trainer { core, opt, cfg }
    }

    /// Train on `task` under `curriculum` for `cfg.updates` updates.
    ///
    /// Follows the canonical batch protocol (see [`episode_grad`]): the
    /// whole batch is sampled up-front, each episode's gradient is computed
    /// from zeroed accumulators, and the batch gradient is reduced in
    /// episode order — so this serial trainer is bit-identical to
    /// [`workers::ParallelTrainer`] at any worker count.
    pub fn run(&mut self, task: &dyn Task, curriculum: &mut Curriculum) -> TrainLog {
        let mut rng = Rng::new(self.cfg.seed);
        let mut log = TrainLog::default();
        let timer = Timer::start();
        let mut window_loss = 0.0f64;
        let mut window_scored = 0usize;
        let mut window_errors = 0.0f64;
        let mut window_eps = 0usize;
        for update in 1..=self.cfg.updates {
            let episodes = sample_batch(task, curriculum, &mut rng, self.cfg.batch);
            let results: Vec<EpisodeGrad> = episodes
                .iter()
                .map(|ep| episode_grad(self.core.as_mut(), task, ep))
                .collect();
            let reduce_start = std::time::Instant::now();
            reduce_episode_grads(self.core.as_mut(), &results);
            for r in &results {
                let scored = r.scored.max(1);
                curriculum.report(r.loss / scored as f64);
                window_loss += r.loss;
                window_scored += scored;
                window_errors += r.errors;
                window_eps += 1;
                log.total_episodes += 1;
            }
            crate::util::metrics::TRAIN_EPISODES.add(results.len() as u64);
            self.opt.step(self.core.as_mut());
            crate::util::metrics::TRAIN_GRAD_REDUCE_US.observe_since(reduce_start);
            if update % self.cfg.log_every == 0 || update == self.cfg.updates {
                let point = LogPoint {
                    update,
                    loss: window_loss / window_scored.max(1) as f64,
                    errors: window_errors / window_eps.max(1) as f64,
                    level: curriculum.h,
                    wall_s: timer.elapsed_s(),
                };
                if self.cfg.verbose {
                    println!(
                        "[{}] update {:>5} loss/step {:.4} errors/ep {:.3} level {} ({:.1}s)",
                        self.core.name(),
                        point.update,
                        point.loss,
                        point.errors,
                        point.level,
                        point.wall_s
                    );
                }
                log.points.push(point);
                window_loss = 0.0;
                window_scored = 0;
                window_errors = 0.0;
                window_eps = 0;
            }
        }
        log.final_level = curriculum.h;
        log
    }

    /// Mean task errors per episode over `n` eval episodes at `level`.
    pub fn evaluate(&mut self, task: &dyn Task, level: usize, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut errors = 0.0;
        for _ in 0..n {
            let ep = task.sample(level, &mut rng);
            let (_, outputs) = eval_episode(self.core.as_mut(), &ep);
            errors += task.errors(&ep, &outputs);
        }
        errors / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::{build_core, CoreConfig, CoreKind};
    use crate::optim::RmsProp;
    use crate::tasks::copy::CopyTask;

    fn tiny_trainer(kind: CoreKind, updates: usize) -> (Trainer, CopyTask) {
        let task = CopyTask::new(4);
        let cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 16,
            heads: 1,
            word: 8,
            mem_words: 16,
            k: 2,
            seed: 99,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(99);
        let core = build_core(kind, &cfg, &mut rng);
        let t = Trainer::new(
            core,
            Box::new(RmsProp::new(3e-3)),
            TrainConfig { batch: 2, updates, log_every: 5, seed: 5, ..TrainConfig::default() },
        );
        (t, task)
    }

    #[test]
    fn loss_decreases_on_tiny_copy_sam() {
        let (mut trainer, task) = tiny_trainer(CoreKind::Sam, 150);
        let mut cur = Curriculum::fixed(2);
        let log = trainer.run(&task, &mut cur);
        let first = log.points.first().unwrap().loss;
        let best = log.best_loss();
        assert!(
            best < first * 0.85,
            "no learning: first {first:.4} best {best:.4}"
        );
    }

    #[test]
    fn loss_decreases_on_tiny_copy_lstm() {
        let (mut trainer, task) = tiny_trainer(CoreKind::Lstm, 60);
        let mut cur = Curriculum::fixed(2);
        let log = trainer.run(&task, &mut cur);
        assert!(log.best_loss() < log.points[0].loss);
    }

    #[test]
    fn evaluate_runs_cleanly() {
        let (mut trainer, task) = tiny_trainer(CoreKind::Sam, 2);
        let mut cur = Curriculum::fixed(2);
        trainer.run(&task, &mut cur);
        let errs = trainer.evaluate(&task, 2, 4, 123);
        assert!(errs >= 0.0);
    }

    #[test]
    fn log_serializes() {
        let (mut trainer, task) = tiny_trainer(CoreKind::Lstm, 5);
        let mut cur = Curriculum::fixed(2);
        let log = trainer.run(&task, &mut cur);
        let j = log.to_json().encode();
        assert!(j.contains("points"));
        crate::util::json::Json::parse(&j).unwrap();
    }
}
