//! Optimizers: RMSProp (the paper's choice, Supp C) and Adam, plus global
//! gradient-norm clipping. Optimizer slots live inside each [`Param`].

use crate::nn::param::{HasParams, Param};

/// An optimizer consumes accumulated gradients and updates values in place,
/// then zeroes the gradients.
pub trait Optimizer: Send {
    fn step(&mut self, model: &mut dyn HasParams);
    fn lr(&self) -> f32;
    fn set_lr(&mut self, lr: f32);
}

/// RMSProp (Tieleman & Hinton 2012) as used in the paper (Supp C).
pub struct RmsProp {
    pub lr: f32,
    pub decay: f32,
    pub eps: f32,
    /// Optional global-norm clip applied before the update.
    pub clip: Option<GradClip>,
}

impl RmsProp {
    pub fn new(lr: f32) -> RmsProp {
        RmsProp { lr, decay: 0.9, eps: 1e-8, clip: Some(GradClip { max_norm: 10.0 }) }
    }

    fn update_param(&self, p: &mut Param, scale: f32) {
        for k in 0..p.w.data.len() {
            let g = p.g.data[k] * scale;
            let ms = self.decay * p.m1.data[k] + (1.0 - self.decay) * g * g;
            p.m1.data[k] = ms;
            p.w.data[k] -= self.lr * g / (ms.sqrt() + self.eps);
            p.g.data[k] = 0.0;
        }
    }
}

impl Optimizer for RmsProp {
    fn step(&mut self, model: &mut dyn HasParams) {
        let scale = self.clip.as_ref().map(|c| c.scale(model)).unwrap_or(1.0);
        self.update_param_all(model, scale);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

impl RmsProp {
    fn update_param_all(&self, model: &mut dyn HasParams, scale: f32) {
        model.visit_params(&mut |p| self.update_param(p, scale));
    }
}

/// Adam (for ablations; the paper used RMSProp).
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub clip: Option<GradClip>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, clip: Some(GradClip { max_norm: 10.0 }), t: 0 }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, model: &mut dyn HasParams) {
        self.t += 1;
        let scale = self.clip.as_ref().map(|c| c.scale(model)).unwrap_or(1.0);
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        model.visit_params(&mut |p| {
            for k in 0..p.w.data.len() {
                let g = p.g.data[k] * scale;
                p.m2.data[k] = b1 * p.m2.data[k] + (1.0 - b1) * g;
                p.m1.data[k] = b2 * p.m1.data[k] + (1.0 - b2) * g * g;
                let mhat = p.m2.data[k] / bc1;
                let vhat = p.m1.data[k] / bc2;
                p.w.data[k] -= lr * mhat / (vhat.sqrt() + eps);
                p.g.data[k] = 0.0;
            }
        });
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Global L2-norm gradient clip.
pub struct GradClip {
    pub max_norm: f32,
}

impl GradClip {
    /// Returns the scale to apply to every gradient.
    pub fn scale(&self, model: &mut dyn HasParams) -> f32 {
        let norm = model.grad_norm();
        if norm > self.max_norm && norm > 0.0 {
            self.max_norm / norm
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::param::Param;

    struct One {
        p: Param,
    }
    impl HasParams for One {
        fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
            f(&mut self.p);
        }
    }

    /// Minimize (w-3)^2 with RMSProp: dL/dw = 2(w-3).
    #[test]
    fn rmsprop_converges_on_quadratic() {
        let mut m = One { p: Param::zeros("w", 1, 1) };
        let mut opt = RmsProp::new(0.05);
        for _ in 0..500 {
            m.p.g.data[0] = 2.0 * (m.p.w.data[0] - 3.0);
            opt.step(&mut m);
        }
        assert!((m.p.w.data[0] - 3.0).abs() < 0.05, "w={}", m.p.w.data[0]);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut m = One { p: Param::zeros("w", 1, 1) };
        let mut opt = Adam::new(0.05);
        for _ in 0..500 {
            m.p.g.data[0] = 2.0 * (m.p.w.data[0] + 1.5);
            opt.step(&mut m);
        }
        assert!((m.p.w.data[0] + 1.5).abs() < 0.05);
    }

    #[test]
    fn clip_bounds_update() {
        let mut m = One { p: Param::zeros("w", 1, 2) };
        m.p.g.data = vec![300.0, 400.0]; // norm 500
        let clip = GradClip { max_norm: 5.0 };
        let s = clip.scale(&mut m);
        assert!((s - 0.01).abs() < 1e-6);
    }

    #[test]
    fn step_zeroes_grads() {
        let mut m = One { p: Param::zeros("w", 1, 2) };
        m.p.g.data = vec![1.0, -1.0];
        RmsProp::new(0.01).step(&mut m);
        assert_eq!(m.p.g.data, vec![0.0, 0.0]);
    }
}
