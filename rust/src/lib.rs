//! # sam — Sparse Access Memory
//!
//! A production-grade reproduction of *"Scaling Memory-Augmented Neural
//! Networks with Sparse Reads and Writes"* (Rae et al., NIPS 2016).
//!
//! The crate implements six memory-augmented model cores (LSTM, NTM, DAM,
//! SAM, DNC, SDNC) with hand-derived backward passes, the sparse-memory
//! substrates that give SAM its asymptotics (approximate-nearest-neighbour
//! indexes — exact linear scan, the paper's kd-forest and LSH, plus an
//! O(log N) HNSW graph, selected with `--ann linear|kdtree|lsh|hnsw` —
//! a least-recently-accessed ring, CSR sparse tensors, and a
//! rollback journal for O(1)-space BPTT), an S-way **sharded memory
//! engine** whose parallel ANN fan-out serves million-slot memories
//! (bit-identical to the unsharded engine for the exact Linear index —
//! `shards`/`--shards` is a pure throughput knob), the paper's task suite
//! and curriculum, a trainer, a shared-weight multi-session serving
//! runtime, and a PJRT seam that executes JAX/Pallas AOT-compiled cells
//! from Rust.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured results.
//!
//! ```no_run
//! use sam::prelude::*;
//!
//! let mut rng = Rng::new(42);
//! // A million-slot SAM memory striped across 4 shards: queries fan out
//! // across a persistent worker pool and merge deterministically.
//! let cfg = CoreConfig {
//!     mem_words: 1 << 20,
//!     ann: AnnKind::Linear,
//!     shards: 4,
//!     ..CoreConfig::default()
//! };
//! let mut core = build_core(CoreKind::Sam, &cfg, &mut rng);
//! core.reset();
//! let y = core.forward(&vec![0.0; cfg.x_dim]);
//! assert_eq!(y.len(), cfg.y_dim);
//! ```

pub mod ann;
pub mod bench;
pub mod cores;
pub mod coordinator;
pub mod curriculum;
pub mod memory;
pub mod nn;
pub mod optim;
pub mod runtime;
pub mod serving;
pub mod tasks;
pub mod tensor;
pub mod training;
pub mod util;

/// Counting allocator so every binary in the crate can report the paper's
/// memory-overhead benchmarks (Fig 1b / 7b).
#[global_allocator]
static GLOBAL_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;

/// Convenient re-exports for examples and binaries.
pub mod prelude {
    pub use crate::ann::AnnKind;
    pub use crate::cores::{build_core, Core, CoreConfig, CoreKind};
    pub use crate::curriculum::Curriculum;
    pub use crate::nn::param::HasParams;
    pub use crate::optim::{GradClip, Optimizer, RmsProp};
    pub use crate::serving::{
        build_infer_model, BatchScheduler, InferModel, Session, SessionConfig, SessionManager,
    };
    pub use crate::tasks::{
        babi::BabiTask, copy::CopyTask, omniglot::OmniglotTask, recall::AssociativeRecall,
        sort::PrioritySort, Episode, Task,
    };
    pub use crate::training::batched::FusedTrainer;
    pub use crate::training::workers::ParallelTrainer;
    pub use crate::training::{TrainConfig, Trainer};
    pub use crate::util::args::Args;
    pub use crate::util::rng::Rng;
}
