//! `sam` — CLI for the Sparse Access Memory reproduction.
//!
//! Subcommands:
//!   train   — train a core on a task (paper defaults; see --help)
//!   eval    — evaluate a checkpoint
//!   serve   — TCP inference server over a (checkpointed) core
//!   info    — model/param/artifact summary
//!
//! Examples:
//!   sam train --model sam --task copy --memory 65536 --ann kdtree --updates 500
//!   sam train --model sam --task recall --curriculum-max 4096
//!   sam serve --model sam --task copy --checkpoint ckpt.bin --addr 127.0.0.1:7878

use anyhow::{anyhow, Result};
use sam::coordinator::{
    build_task, build_trainer, load_checkpoint, read_checkpoint_for, resolved_core_cfg,
    run_experiment, save_checkpoint, server, ExperimentConfig,
};
use sam::serving::{build_infer_model, SessionConfig};
use sam::util::args::Args;
use sam::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

const HELP: &str = "\
sam — Sparse Access Memory (Rae et al., NIPS 2016) reproduction

USAGE: sam <train|eval|serve|info> [flags]

Common flags (paper defaults in parens):
  --model lstm|ntm|dam|sam|dnc|sdnc   (sam)
  --task copy|recall|sort|omniglot|babi (copy)
  --memory N        memory words (128)
  --word W          word size (32)
  --heads R         access heads (4)
  --k K             sparse reads per head (4)
  --ann linear|kdtree|lsh|hnsw  (linear)
  --row-format f32|bf16|int8    memory-row storage codec (f32). Compact
                    rows (bf16: 2 B/value; int8: 1 B/value + per-row scale)
                    cut scan bandwidth for eval AND serve; training is
                    f32-only (backward borrows rows as f32)
  --shards S        memory shards for SAM/SDNC (1); rows stripe across S
                    stores+ANNs and queries fan out across a worker pool.
                    Bit-identical to S=1 for --ann linear at any S — a pure
                    throughput knob for train, eval AND serve
  --hidden H        controller LSTM size (100)
  --lr LR           learning rate (1e-4)
  --batch B         episodes per update (8)
  --updates U       parameter updates (200)
  --curriculum-max H  enable exponential curriculum up to H
  --workers N       data-parallel worker threads (1); same seed ⇒ same
                    result at any N (deterministic fixed-order reduction)
  --batch-fuse B    episode lanes fused per worker (1): each worker drives
                    B episodes in lockstep so controller GEMMs batch across
                    lanes and ANN lookups merge into one dispatch. Same
                    seed ⇒ same result at any (N, B) for --ann linear
  --seed S          RNG seed (1)
  --checkpoint PATH save/load parameters
  --metrics-json P  write metrics-registry snapshots to P (~every 2s while
                    training, plus a final snapshot; see DESIGN.md
                    "Observability")
  --quiet           suppress progress lines

Serve flags (shared-weight multi-session runtime):
  --addr HOST:PORT      serve address (127.0.0.1:7878)
  --serve-workers N     connection worker threads (4)
  --tick-us T           batch-coalescing tick in µs (200)
  --max-batch B         max sessions per tick (64)
  --session-budget-mb M episodic-state byte budget, LRU-evicted (1024)
  --idle-expiry-s S     drop sessions idle this long (300)
  --read-timeout-ms T   park idle connections after this (25)
  --spill-dir PATH      durable sessions: evicted/idle sessions demote to
                        checksummed spill files here instead of being
                        destroyed, rehydrate transparently on their next
                        step, and survive a server restart. Unset (default)
                        keeps destroy-eviction
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => train(&args),
        "eval" => eval(&args),
        "serve" => serve_cmd(&args),
        "info" => info(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

fn train(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    // Compact rows are serve/eval-only: the backward pass borrows memory
    // rows as `&[f32]`, which quantized storage cannot lend.
    if !cfg.core_cfg.row_format.train_legal() {
        return Err(anyhow!(
            "--row-format {} is serve/eval-only; train requires f32 rows",
            cfg.core_cfg.row_format.name()
        ));
    }
    println!(
        "training {:?} on {:?} (N={}, W={}, heads={}, K={}, ann={:?}, shards={}, workers={}, batch-fuse={})",
        cfg.core, cfg.task, cfg.core_cfg.mem_words, cfg.core_cfg.word, cfg.core_cfg.heads,
        cfg.core_cfg.k, cfg.core_cfg.ann, cfg.core_cfg.shards, cfg.workers,
        cfg.train_cfg.batch_fuse
    );
    // Periodic metrics snapshots while training runs; a final snapshot is
    // written after the run so short runs still produce a complete file.
    let metrics_path = args.get("metrics-json").map(PathBuf::from);
    let snapshotter = metrics_path.clone().map(|path| {
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = std::sync::Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let mut ticks = 0u32;
            while !flag.load(std::sync::atomic::Ordering::SeqCst) {
                std::thread::sleep(std::time::Duration::from_millis(100));
                ticks += 1;
                if ticks % 20 == 0 {
                    let _ = std::fs::write(&path, sam::util::metrics::snapshot_json().encode());
                }
            }
        });
        (stop, handle)
    });
    let run = run_experiment(&cfg);
    if let Some((stop, handle)) = snapshotter {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = handle.join();
    }
    let (mut trainer, log) = run?;
    if let Some(path) = &metrics_path {
        std::fs::write(path, sam::util::metrics::snapshot_json().encode())?;
        println!("metrics snapshot written to {}", path.display());
    }
    println!(
        "done: {} episodes, best loss/step {:.4}, final level {}",
        log.total_episodes,
        log.best_loss(),
        log.final_level
    );
    if let Some(path) = args.get("checkpoint") {
        let task = build_task(&cfg.task)?;
        let core_cfg = resolved_core_cfg(&cfg, task.as_ref());
        save_checkpoint(trainer.core.as_mut(), &core_cfg, &PathBuf::from(path))?;
        println!("checkpoint written to {path}");
    }
    if let Some(path) = args.get("log-json") {
        std::fs::write(path, log.to_json().encode())?;
        println!("training log written to {path}");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let task = build_task(&cfg.task)?;
    let mut trainer = build_trainer(&cfg, task.as_ref());
    if let Some(path) = args.get("checkpoint") {
        let core_cfg = resolved_core_cfg(&cfg, task.as_ref());
        load_checkpoint(trainer.core.as_mut(), &core_cfg, &PathBuf::from(path))?;
    }
    let level = args.usize_or("level", task.base_level());
    let episodes = args.usize_or("episodes", 20);
    let errs = trainer.evaluate(task.as_ref(), level, episodes, args.u64_or("seed", 17));
    println!(
        "eval {:?} on {:?} level {}: {:.3} errors/episode over {} episodes",
        cfg.core, cfg.task, level, errs, episodes
    );
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let task = build_task(&cfg.task)?;
    // One copy of trained weights, shared read-only across the worker pool
    // and every session (the parameters/state split — see DESIGN.md
    // "Serving runtime").
    let core_cfg = resolved_core_cfg(&cfg, task.as_ref());
    let params = match args.get("checkpoint") {
        Some(path) => {
            // Validated against the served core's kind and shape — serving
            // a checkpoint from the wrong model must fail here, not produce
            // garbage outputs per-request.
            let p = read_checkpoint_for(&PathBuf::from(&path), cfg.core.as_str(), &core_cfg)?;
            println!("loaded checkpoint {path} ({} params)", p.len());
            Some(p)
        }
        None => None,
    };
    let mut rng = Rng::new(core_cfg.seed);
    let model = build_infer_model(cfg.core, &core_cfg, &mut rng, params.as_deref());
    let serve_cfg = server::ServeConfig {
        workers: args.usize_or("serve-workers", 4),
        read_timeout: Duration::from_millis(args.u64_or("read-timeout-ms", 25)),
        tick: Duration::from_micros(args.u64_or("tick-us", 200)),
        max_batch: args.usize_or("max-batch", 64),
        session: SessionConfig {
            byte_budget: args.usize_or("session-budget-mb", 1024) * (1 << 20),
            idle_expiry: Duration::from_secs(args.u64_or("idle-expiry-s", 300)),
            seed: cfg.core_cfg.seed ^ 0x5E55,
            spill_dir: args.get("spill-dir").map(PathBuf::from),
        },
    };
    if let Some(dir) = serve_cfg.session.spill_dir.as_deref() {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("cannot create spill dir {}: {e}", dir.display()))?;
    }
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let stop = Arc::new(AtomicBool::new(false));
    server::serve_model(model, &addr, &serve_cfg, stop).map_err(|e| anyhow!("server: {e:#}"))
}

fn info(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let task = build_task(&cfg.task)?;
    let mut trainer = build_trainer(&cfg, task.as_ref());
    println!("model: {:?}", cfg.core);
    println!("task:  {} (x_dim {}, y_dim {})", cfg.task, task.x_dim(), task.y_dim());
    println!("params: {}", trainer.core.param_count());
    println!(
        "memory: {} words x {} (heads {}, K {}, ann {:?}, shards {}, rows {})",
        cfg.core_cfg.mem_words, cfg.core_cfg.word, cfg.core_cfg.heads, cfg.core_cfg.k,
        cfg.core_cfg.ann, cfg.core_cfg.shards, cfg.core_cfg.row_format.name()
    );
    println!("kernels: {} dispatch", sam::tensor::simd::kernel_path_name());
    // Durable-session spill directory, if one is configured.
    if let Some(dir) = args.get("spill-dir").map(PathBuf::from) {
        let report = sam::serving::spill::scan_dir(&dir);
        println!(
            "spill dir {}: {} session files, {} bytes, {} corrupt",
            dir.display(),
            report.files(),
            report.bytes,
            report.corrupt
        );
    }
    // PJRT artifacts, if built.
    let dir = sam::runtime::artifacts_dir();
    match sam::runtime::Runtime::cpu() {
        Ok(mut rt) => match rt.load_dir(&dir) {
            Ok(names) => println!("artifacts ({dir:?}): {names:?} on {}", rt.platform()),
            Err(_) => println!("artifacts: none at {dir:?} (run `make artifacts`)"),
        },
        Err(e) => println!("pjrt unavailable: {e:#}"),
    }
    Ok(())
}
