//! Neural Turing Machine (Graves et al. 2014) — the paper's principal dense
//! baseline (§2.3, Fig 1/2/3). Full addressing pipeline per head:
//! content (cosine+β softmax) → interpolation (g) → circular shift (3-way
//! softmax) → sharpening (γ ≥ 1); reads and erase/add writes share each
//! head's addressing, as in the paper's "4 access heads" setup.
//!
//! Everything is dense: O(N·W) per step per head, with a full memory
//! snapshot per head-write on the BPTT tape — the scaling pathology the
//! paper measures in Fig 1.

use super::addressing::{content_weights, content_weights_backward, ContentRead};
use super::{Controller, ControllerState, Core, CoreConfig};
use crate::memory::store::MemoryStore;
use crate::nn::act::{dsigmoid, oneplus, sigmoid};
use crate::nn::param::{HasParams, Param};
use crate::tensor::matrix::{dot, softmax_backward, softmax_inplace, Matrix};
use crate::util::rng::Rng;

/// Head params: [q(W), β̂, ĝ, ŝ(3), γ̂, e(W), a(W)].
const fn head_dim(word: usize) -> usize {
    3 * word + 6
}

const SHARPEN_EPS: f32 = 1e-6;

struct HeadStep {
    query: Vec<f32>,
    read: ContentRead,
    g: f32,
    shift: Vec<f32>,    // softmaxed (3)
    gamma_raw: f32,
    gamma: f32,
    w_g: Vec<f32>,
    w_s: Vec<f32>,
    w_final: Vec<f32>,
    w_prev_used: Vec<f32>,
    erase: Vec<f32>,    // σ(ê)
    add: Vec<f32>,
    /// Memory snapshot taken *before* this head's write.
    mem_before_write: Vec<f32>,
}

struct NtmStep {
    heads: Vec<HeadStep>,
}

pub struct NtmCore {
    cfg: CoreConfig,
    ctrl: Controller,
    mem: MemoryStore,
    w_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<NtmStep>,
    // carried backward state
    d_r: Vec<Vec<f32>>,
    d_wprev: Vec<Vec<f32>>,
    dmem: Matrix,
}

impl NtmCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> NtmCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "ntm",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        let n = cfg.mem_words;
        NtmCore {
            ctrl,
            mem: MemoryStore::zeros(n, cfg.word),
            w_prev: vec![vec![1.0 / n as f32; n]; cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wprev: vec![vec![0.0; n]; cfg.heads],
            dmem: Matrix::zeros(n, cfg.word),
            cfg: cfg.clone(),
        }
    }

    /// Open a detached inference session (zero-initialized memory, uniform
    /// initial addressing — same as a freshly reset training core).
    pub fn infer_session(&self, _seed: Option<u64>) -> NtmSession {
        let n = self.cfg.mem_words;
        NtmSession {
            ctrl: self.ctrl.new_state(),
            mem: MemoryStore::zeros(n, self.cfg.word),
            w_prev: vec![vec![1.0 / n as f32; n]; self.cfg.heads],
            r_prev: vec![vec![0.0; self.cfg.word]; self.cfg.heads],
        }
    }

    /// One forward-only step: bit-identical to [`Core::forward_into`] on a
    /// freshly reset core, minus the per-head memory snapshots of the
    /// training tape. (Dense baseline: the step allocates — NTM is not on
    /// the zero-allocation serving path.)
    pub fn infer_step(&self, st: &mut NtmSession, x: &[f32], y: &mut Vec<f32>) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.ctrl.infer_step(&mut st.ctrl, x, &st.r_prev);
        // Addressing for every head, from M_{t-1} (before any write).
        let mut finals: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> =
            Vec::with_capacity(self.cfg.heads);
        for hi in 0..self.cfg.heads {
            let ph = &st.ctrl.p[hi * hd..(hi + 1) * hd];
            let query = &ph[..w];
            let beta_raw = ph[w];
            let g = sigmoid(ph[w + 1]);
            let mut shift = ph[w + 2..w + 5].to_vec();
            softmax_inplace(&mut shift);
            let gamma = oneplus(ph[w + 5]);
            let erase: Vec<f32> = ph[w + 6..2 * w + 6].iter().map(|&v| sigmoid(v)).collect();
            let add = ph[2 * w + 6..3 * w + 6].to_vec();
            let read = content_weights(query, beta_raw, &st.mem, (0..n).collect());
            let mut w_g = vec![0.0f32; n];
            for i in 0..n {
                w_g[i] = g * read.weights[i] + (1.0 - g) * st.w_prev[hi][i];
            }
            let w_s = shift_conv(&w_g, &shift);
            let (w_final, _, _) = sharpen(&w_s, gamma);
            finals.push((w_final, erase, add));
        }
        // Sequential erase/add writes, then reads from M_t.
        for (wf, erase, add) in &finals {
            st.mem.apply_write_dense(wf, erase, add);
        }
        for (hi, (wf, _, _)) in finals.iter().enumerate() {
            let mut r = vec![0.0; w];
            st.mem.read_dense(wf, &mut r);
            st.w_prev[hi] = wf.clone();
            st.r_prev[hi] = r;
        }
        self.ctrl.infer_output(&mut st.ctrl, &st.r_prev, y);
    }

    pub fn params_heap_bytes(&self) -> usize {
        self.ctrl.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.ctrl.params_len()
    }
}

/// Detached per-session state for NTM serving.
pub struct NtmSession {
    ctrl: ControllerState,
    mem: MemoryStore,
    w_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
}

impl NtmSession {
    pub fn reset(&mut self) {
        self.ctrl.reset();
        self.mem.fill(0.0);
        let n = self.w_prev.first().map(|v| v.len()).unwrap_or(0);
        for v in &mut self.w_prev {
            v.iter_mut().for_each(|x| *x = 1.0 / n as f32);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.mem.heap_bytes()
            + self.ctrl.heap_bytes()
            + self
                .w_prev
                .iter()
                .chain(self.r_prev.iter())
                .map(|v| v.capacity() * 4)
                .sum::<usize>()
    }

    pub fn tape_bytes(&self) -> usize {
        0
    }
}

/// w_s(i) = Σ_k s_k · w_g((i - shift_k) mod N), shifts = {-1, 0, +1}.
fn shift_conv(w_g: &[f32], s: &[f32]) -> Vec<f32> {
    let n = w_g.len();
    let mut out = vec![0.0f32; n];
    for (k, &sk) in s.iter().enumerate() {
        let shift = k as isize - 1; // -1, 0, +1
        if sk == 0.0 {
            continue;
        }
        for i in 0..n {
            let j = (i as isize - shift).rem_euclid(n as isize) as usize;
            out[i] += sk * w_g[j];
        }
    }
    out
}

/// Sharpen: w_i = (u_i+ε)^γ / Σ_j (u_j+ε)^γ. Returns (w, powers, z).
fn sharpen(u: &[f32], gamma: f32) -> (Vec<f32>, Vec<f32>, f32) {
    let p: Vec<f32> = u.iter().map(|&x| (x + SHARPEN_EPS).powf(gamma)).collect();
    let z: f32 = p.iter().sum();
    let w = p.iter().map(|&x| x / z).collect();
    (w, p, z)
}

impl HasParams for NtmCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for NtmCore {
    fn name(&self) -> &'static str {
        "ntm"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        self.mem.fill(0.0);
        let n = self.cfg.mem_words;
        for v in &mut self.w_prev {
            v.iter_mut().for_each(|x| *x = 1.0 / n as f32);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.d_wprev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.dmem.fill(0.0);
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- addressing for every head, from M_{t-1} ---
        for hi in 0..self.cfg.heads {
            let ph = &p[hi * hd..(hi + 1) * hd];
            let query = ph[..w].to_vec();
            let beta_raw = ph[w];
            let g = sigmoid(ph[w + 1]);
            let mut shift = ph[w + 2..w + 5].to_vec();
            softmax_inplace(&mut shift);
            let gamma_raw = ph[w + 5];
            let gamma = oneplus(gamma_raw);
            let erase: Vec<f32> = ph[w + 6..2 * w + 6].iter().map(|&v| sigmoid(v)).collect();
            let add = ph[2 * w + 6..3 * w + 6].to_vec();

            let read = content_weights(&query, beta_raw, &self.mem, (0..n).collect());
            let mut w_g = vec![0.0f32; n];
            for i in 0..n {
                w_g[i] = g * read.weights[i] + (1.0 - g) * self.w_prev[hi][i];
            }
            let w_s = shift_conv(&w_g, &shift);
            let (w_final, _, _) = sharpen(&w_s, gamma);
            heads.push(HeadStep {
                query,
                read,
                g,
                shift,
                gamma_raw,
                gamma,
                w_g,
                w_s,
                w_final,
                w_prev_used: self.w_prev[hi].clone(),
                erase,
                add,
                mem_before_write: Vec::new(),
            });
        }

        // --- sequential erase/add writes ---
        for hstep in heads.iter_mut() {
            hstep.mem_before_write = self.mem.snapshot();
            self.mem.apply_write_dense(&hstep.w_final, &hstep.erase, &hstep.add);
        }

        // --- reads from M_t ---
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for (hi, hstep) in heads.iter().enumerate() {
            let mut r = vec![0.0; w];
            self.mem.read_dense(&hstep.w_final, &mut r);
            self.w_prev[hi] = hstep.w_final.clone();
            reads.push(r);
        }

        *y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(NtmStep { heads });
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);
        let mut dp = vec![0.0f32; self.cfg.heads * hd];
        // Accumulated gradient on each head's final weights (read + write +
        // next step's w_prev recurrency).
        let mut dw_final: Vec<Vec<f32>> = vec![vec![0.0f32; n]; self.cfg.heads];

        // --- read backward (memory = M_t) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            for i in 0..n {
                dw_final[hi][i] += dot(self.mem.row(i), &dr) + self.d_wprev[hi][i];
                let wv = hstep.w_final[i];
                if wv != 0.0 {
                    let row = self.dmem.row_mut(i);
                    for (gd, &d) in row.iter_mut().zip(&dr) {
                        *gd += wv * d;
                    }
                }
            }
        }

        // --- write backward (reverse head order, restoring memory) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            // Restore M to the state before this head's write.
            self.mem.restore(&hstep.mem_before_write);
            let ph = &mut dp[hi * hd..(hi + 1) * hd];
            // M'(i,j) = M(i,j)(1 - w_i e_j) + w_i a_j
            for i in 0..n {
                let wv = hstep.w_final[i];
                let mrow = self.mem.row(i);
                let drow = self.dmem.row_mut(i);
                let mut dw_i = 0.0f32;
                for j in 0..w {
                    let d = drow[j];
                    dw_i += d * (hstep.add[j] - mrow[j] * hstep.erase[j]);
                    // de_j and da_j accumulate into head params below.
                    ph[w + 6 + j] += d * (-mrow[j] * wv) * dsigmoid(hstep.erase[j]);
                    ph[2 * w + 6 + j] += d * wv;
                    drow[j] = d * (1.0 - wv * hstep.erase[j]);
                }
                dw_final[hi][i] += dw_i;
            }
        }

        // --- addressing backward (memory = M_{t-1}) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let ph_start = hi * hd;
            // sharpen backward
            let (w_sharp, pvec, z) = sharpen(&hstep.w_s, hstep.gamma);
            debug_assert!(w_sharp
                .iter()
                .zip(&hstep.w_final)
                .all(|(a, b)| (a - b).abs() < 1e-5));
            let dwf = &dw_final[hi];
            let dot_dw_w: f32 = dwf.iter().zip(&w_sharp).map(|(a, b)| a * b).sum();
            let mut dws = vec![0.0f32; n];
            let mut dgamma = 0.0f32;
            for i in 0..n {
                let dp_i = (dwf[i] - dot_dw_w) / z;
                let u = hstep.w_s[i] + SHARPEN_EPS;
                dws[i] = dp_i * hstep.gamma * u.powf(hstep.gamma - 1.0);
                dgamma += dp_i * pvec[i] * u.ln();
            }
            dp[ph_start + w + 5] += dgamma * sigmoid(hstep.gamma_raw); // oneplus'

            // shift backward
            let mut dwg = vec![0.0f32; n];
            let mut dshift = vec![0.0f32; 3];
            for (k, &sk) in hstep.shift.iter().enumerate() {
                let shift = k as isize - 1;
                for i in 0..n {
                    let j = (i as isize - shift).rem_euclid(n as isize) as usize;
                    dwg[j] += sk * dws[i];
                    dshift[k] += dws[i] * hstep.w_g[j];
                }
            }
            let mut dshift_logits = vec![0.0f32; 3];
            softmax_backward(&hstep.shift, &dshift, &mut dshift_logits);
            for k in 0..3 {
                dp[ph_start + w + 2 + k] += dshift_logits[k];
            }

            // interpolation backward
            let mut dwc = vec![0.0f32; n];
            let mut dg = 0.0f32;
            for i in 0..n {
                dg += dwg[i] * (hstep.read.weights[i] - hstep.w_prev_used[i]);
                dwc[i] = hstep.g * dwg[i];
                self.d_wprev[hi][i] = (1.0 - hstep.g) * dwg[i];
            }
            dp[ph_start + w + 1] += dg * dsigmoid(hstep.g);

            // content backward (over all N rows of M_{t-1})
            let mut dq = vec![0.0f32; w];
            let mut dbeta_raw = 0.0f32;
            let dmem_ref = &mut self.dmem;
            content_weights_backward(
                &hstep.read,
                &hstep.query,
                &self.mem,
                &dwc,
                &mut dq,
                &mut dbeta_raw,
                |row, d| {
                    let r = dmem_ref.row_mut(row);
                    for (g, &x) in r.iter_mut().zip(d) {
                        *g += x;
                    }
                },
            );
            dp[ph_start..ph_start + w]
                .iter_mut()
                .zip(&dq)
                .for_each(|(a, b)| *a += b);
            dp[ph_start + w] += dbeta_raw;
        }

        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        if let Some(first) = self.tape.first() {
            if let Some(h0) = first.heads.first() {
                let m = h0.mem_before_write.clone();
                self.mem.restore(&m);
            }
        }
        self.tape.clear();
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                s.heads
                    .iter()
                    .map(|h| {
                        (h.mem_before_write.capacity()
                            + h.w_g.capacity()
                            + h.w_s.capacity()
                            + h.w_final.capacity()
                            + h.w_prev_used.capacity()
                            + h.read.weights.capacity()
                            + h.query.capacity()
                            + h.erase.capacity()
                            + h.add.capacity())
                            * 4
                            + h.read.sims.capacity() * 12
                            + h.read.rows.capacity() * 8
                    })
                    .sum::<usize>()
            })
            .sum();
        step + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 5,
            mem_words: 10,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn shift_conv_rotates() {
        let w = vec![1.0, 0.0, 0.0, 0.0];
        // pure +1 shift
        let out = shift_conv(&w, &[0.0, 0.0, 1.0]);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 0.0]);
        // pure -1 shift
        let out = shift_conv(&w, &[1.0, 0.0, 0.0]);
        assert_eq!(out, vec![0.0, 0.0, 0.0, 1.0]);
        // identity
        let out = shift_conv(&w, &[0.0, 1.0, 0.0]);
        assert_eq!(out, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn sharpen_normalizes_and_peaks() {
        let u = vec![0.6, 0.3, 0.1];
        let (w, _, _) = sharpen(&u, 2.0);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(w[0] > u[0]); // sharpening concentrates mass
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(23);
        let mut core = NtmCore::new(&small_cfg(23), &mut rng);
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.2);
        assert!(checked >= 30);
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn memory_restored_after_backward() {
        let mut rng = Rng::new(24);
        let mut core = NtmCore::new(&small_cfg(24), &mut rng);
        core.reset();
        let start = core.mem.snapshot();
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        assert_eq!(core.mem.snapshot(), start);
    }

    #[test]
    fn infer_session_matches_train_forward_bitwise() {
        let mut rng = Rng::new(26);
        let mut core = NtmCore::new(&small_cfg(26), &mut rng);
        let (xs, _) = random_episode(4, 3, 5, &mut rng);
        let mut st = core.infer_session(None);
        let mut yi = Vec::new();
        for ep in 0..2 {
            core.reset();
            for x in &xs {
                let yt = core.forward(x);
                core.infer_step(&mut st, x, &mut yi);
                for (a, b) in yt.iter().zip(&yi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            core.rollback();
            core.end_episode();
            st.reset();
            assert_eq!(st.tape_bytes(), 0);
        }
    }

    #[test]
    fn weights_stay_normalized() {
        let mut rng = Rng::new(25);
        let mut core = NtmCore::new(&small_cfg(25), &mut rng);
        core.reset();
        for t in 0..6 {
            core.forward(&[1.0, 0.0, 0.0, 1.0]);
            let s = core.tape.last().unwrap();
            for h in &s.heads {
                let sum: f32 = h.w_final.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "t={t} sum={sum}");
                assert!(h.w_final.iter().all(|&x| x >= 0.0));
            }
        }
        core.rollback();
    }
}
