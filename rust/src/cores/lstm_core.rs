//! Plain LSTM baseline (no external memory) — the paper's weakest baseline.
//! Uses the same controller width and output projection as the MANNs so the
//! comparison isolates the memory.

use super::{BatchCore, Core, CoreConfig, LaneWeights};
use crate::nn::linear::Linear;
use crate::nn::lstm::{Lstm, LstmState};
use crate::nn::param::{HasParams, Param};
use crate::util::rng::Rng;

pub struct LstmCore {
    lstm: Lstm,
    out: Linear,
    x_dim: usize,
    y_dim: usize,
    steps: usize,
    /// Persistent backward scratch (dh from the output layer, dx sink).
    dh_buf: Vec<f32>,
    dx_buf: Vec<f32>,
}

impl LstmCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> LstmCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        LstmCore {
            lstm: Lstm::new("lstm", cfg.x_dim, cfg.hidden, &mut rng),
            out: Linear::new("lstm.out", cfg.hidden, cfg.y_dim, &mut rng),
            x_dim: cfg.x_dim,
            y_dim: cfg.y_dim,
            steps: 0,
            dh_buf: Vec::new(),
            dx_buf: Vec::new(),
        }
    }

    /// Open a detached inference session (no external memory, so the state
    /// is just the recurrent h/c).
    pub fn infer_session(&self, _seed: Option<u64>) -> LstmSession {
        LstmSession { lstm: self.lstm.new_state() }
    }

    /// One forward-only step; bit-identical to [`Core::forward_into`].
    pub fn infer_step(&self, st: &mut LstmSession, x: &[f32], y: &mut Vec<f32>) {
        self.lstm.infer_step(&mut st.lstm, x);
        self.out.infer_into(&st.lstm.h, y);
    }

    pub fn params_heap_bytes(&self) -> usize {
        self.lstm.params_heap_bytes() + self.out.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.lstm.wx.len()
            + self.lstm.wh.len()
            + self.lstm.b.len()
            + self.out.w.len()
            + self.out.b.len()
    }
}

/// Detached per-session state for the memoryless LSTM baseline.
pub struct LstmSession {
    lstm: LstmState,
}

impl LstmSession {
    pub fn reset(&mut self) {
        self.lstm.reset();
    }

    pub fn heap_bytes(&self) -> usize {
        self.lstm.heap_bytes()
    }

    pub fn tape_bytes(&self) -> usize {
        0
    }
}

impl HasParams for LstmCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.lstm.visit_params(f);
        self.out.visit_params(f);
    }
}

impl Core for LstmCore {
    fn name(&self) -> &'static str {
        "lstm"
    }

    fn reset(&mut self) {
        self.lstm.reset();
        self.out.clear_cache();
        self.steps = 0;
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        self.steps += 1;
        self.lstm.step_hot(x);
        self.out.forward_into(&self.lstm.h, y);
    }

    fn backward(&mut self, dy: &[f32]) {
        self.out.backward_into(dy, &mut self.dh_buf);
        let dh = std::mem::take(&mut self.dh_buf);
        self.lstm.backward_into(&dh, &mut self.dx_buf);
        self.dh_buf = dh;
        self.steps -= 1;
    }

    fn rollback(&mut self) {
        self.reset();
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.x_dim
    }

    fn y_dim(&self) -> usize {
        self.y_dim
    }

    fn tape_bytes(&self) -> usize {
        self.lstm.cache_bytes() + self.out.cache_bytes()
    }
}

/// The dense witness for the batched training path: no head projection, no
/// memory phase — just the cell and the output projection, fused across
/// lanes. Exercises the `head = None` legs of the batched ticks.
impl BatchCore for LstmCore {
    fn cell_in_dim(&self) -> usize {
        self.x_dim
    }

    fn cell_hidden(&self) -> usize {
        self.lstm.hidden
    }

    fn head_param_dim(&self) -> usize {
        0
    }

    fn out_in_dim(&self) -> usize {
        self.out.in_dim()
    }

    fn weights(&self) -> LaneWeights<'_> {
        LaneWeights {
            wx: &self.lstm.wx.w,
            wh: &self.lstm.wh.w,
            head: None,
            out: (&self.out.w.w, &self.out.b.w.data),
        }
    }

    fn stage_input(&self, x: &[f32], x_row: &mut [f32], h_row: &mut [f32]) {
        x_row.copy_from_slice(x);
        h_row.copy_from_slice(&self.lstm.h);
    }

    fn cell_step(&mut self, x_row: &[f32], zx_row: &mut [f32], zh_row: &[f32]) {
        self.steps += 1;
        for (zv, (bv, zhv)) in zx_row.iter_mut().zip(self.lstm.b.w.data.iter().zip(zh_row)) {
            *zv = (*zv + bv) + zhv;
        }
        self.lstm.step_with_z(x_row, zx_row);
    }

    fn h(&self) -> &[f32] {
        &self.lstm.h
    }

    fn stage_output(&self, o_row: &mut [f32]) {
        o_row.copy_from_slice(&self.lstm.h);
    }

    fn note_forward_out(&mut self, o_row: &[f32]) {
        self.out.note_forward(o_row);
    }

    fn note_output_backward(&mut self, dy: &[f32], _d_o_row: &[f32]) {
        self.out.note_backward(dy);
    }

    fn backward_cell_z(&mut self, dh_row: &mut [f32], dz_row: &mut [f32]) {
        self.lstm.backward_z_into(dh_row, dz_row);
        self.steps -= 1;
    }

    fn finish_backward(&mut self, dz_row: &[f32], dh_prev_row: &[f32], _dx_row: &[f32]) {
        self.lstm.backward_finish(dz_row, dh_prev_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    #[test]
    fn gradients_match_fd() {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(42);
        let mut core = LstmCore::new(&cfg, &mut rng);
        let (xs, ts) = random_episode(4, 3, 6, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 8, 1e-2, 0.15);
        assert!(checked >= 30);
        assert_eq!(failed, 0, "{failed}/{checked} gradient checks failed");
    }

    /// Dense witness for the batched training ticks: ragged lanes driven in
    /// lockstep through `train_tick_forward`/`train_tick_backward` produce
    /// bit-identical outputs AND parameter gradients to the serial
    /// `forward`/`backward` path.
    #[test]
    fn batched_ticks_match_serial_core_bitwise() {
        use crate::cores::{train_tick_backward, train_tick_forward, TrainBatch};
        let cfg = CoreConfig { x_dim: 4, y_dim: 3, hidden: 8, ..CoreConfig::default() };
        let lens = [5usize, 3, 5];
        let t_max = 5;
        let mut lanes: Vec<LstmCore> =
            (0..3).map(|i| LstmCore::new(&cfg, &mut Rng::new(50 + i))).collect();
        let mut serial: Vec<LstmCore> =
            (0..3).map(|i| LstmCore::new(&cfg, &mut Rng::new(50 + i))).collect();
        let mut data_rng = Rng::new(7);
        let mut mk = |len: usize, dim: usize| -> Vec<Vec<f32>> {
            (0..len).map(|_| (0..dim).map(|_| data_rng.uniform_in(-1.0, 1.0)).collect()).collect()
        };
        let xs: Vec<Vec<Vec<f32>>> = lens.iter().map(|&len| mk(len, 4)).collect();
        let dys: Vec<Vec<Vec<f32>>> = lens.iter().map(|&len| mk(len, 3)).collect();

        // Serial reference.
        let mut ys_ref: Vec<Vec<Vec<f32>>> = Vec::new();
        for (l, core) in serial.iter_mut().enumerate() {
            core.reset();
            let mut ys = Vec::new();
            for x in &xs[l] {
                ys.push(core.forward(x));
            }
            for dy in dys[l].iter().rev() {
                core.backward(dy);
            }
            ys_ref.push(ys);
        }

        // Batched lockstep.
        for lane in lanes.iter_mut() {
            lane.reset();
        }
        let mut batch = TrainBatch::new();
        let mut ys_bat: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
        for t in 0..t_max {
            let step_xs: Vec<Option<&[f32]>> =
                (0..3).map(|l| xs[l].get(t).map(|v| v.as_slice())).collect();
            train_tick_forward(&mut lanes, &mut batch, &step_xs);
            for (l, &len) in lens.iter().enumerate() {
                if t < len {
                    ys_bat[l].push(batch.y_row(l).to_vec());
                }
            }
        }
        for t in (0..t_max).rev() {
            let active: Vec<bool> = lens.iter().map(|&len| t < len).collect();
            batch.stage_dy(3, 3);
            for (l, &len) in lens.iter().enumerate() {
                if t < len {
                    batch.dy_row_mut(l).copy_from_slice(&dys[l][t]);
                }
            }
            train_tick_backward(&mut lanes, &mut batch, &active);
        }

        for l in 0..3 {
            assert_eq!(ys_ref[l].len(), ys_bat[l].len());
            for (a, b) in ys_ref[l].iter().zip(&ys_bat[l]) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "lane {l} output mismatch");
                }
            }
            let mut ga: Vec<f32> = Vec::new();
            serial[l].visit_params(&mut |p| ga.extend_from_slice(&p.g.data));
            let mut gb: Vec<f32> = Vec::new();
            lanes[l].visit_params(&mut |p| gb.extend_from_slice(&p.g.data));
            assert_eq!(ga.len(), gb.len());
            for (x, y) in ga.iter().zip(&gb) {
                assert_eq!(x.to_bits(), y.to_bits(), "lane {l} grad mismatch");
            }
        }
    }

    #[test]
    fn forward_shapes() {
        let cfg = CoreConfig { x_dim: 5, y_dim: 2, hidden: 8, ..CoreConfig::default() };
        let mut rng = Rng::new(1);
        let mut core = LstmCore::new(&cfg, &mut rng);
        core.reset();
        let y = core.forward(&[1.0, 0.0, 1.0, 0.0, 1.0]);
        assert_eq!(y.len(), 2);
    }
}
