//! Plain LSTM baseline (no external memory) — the paper's weakest baseline.
//! Uses the same controller width and output projection as the MANNs so the
//! comparison isolates the memory.

use super::{Core, CoreConfig};
use crate::nn::linear::Linear;
use crate::nn::lstm::{Lstm, LstmState};
use crate::nn::param::{HasParams, Param};
use crate::util::rng::Rng;

pub struct LstmCore {
    lstm: Lstm,
    out: Linear,
    x_dim: usize,
    y_dim: usize,
    steps: usize,
    /// Persistent backward scratch (dh from the output layer, dx sink).
    dh_buf: Vec<f32>,
    dx_buf: Vec<f32>,
}

impl LstmCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> LstmCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        LstmCore {
            lstm: Lstm::new("lstm", cfg.x_dim, cfg.hidden, &mut rng),
            out: Linear::new("lstm.out", cfg.hidden, cfg.y_dim, &mut rng),
            x_dim: cfg.x_dim,
            y_dim: cfg.y_dim,
            steps: 0,
            dh_buf: Vec::new(),
            dx_buf: Vec::new(),
        }
    }

    /// Open a detached inference session (no external memory, so the state
    /// is just the recurrent h/c).
    pub fn infer_session(&self, _seed: Option<u64>) -> LstmSession {
        LstmSession { lstm: self.lstm.new_state() }
    }

    /// One forward-only step; bit-identical to [`Core::forward_into`].
    pub fn infer_step(&self, st: &mut LstmSession, x: &[f32], y: &mut Vec<f32>) {
        self.lstm.infer_step(&mut st.lstm, x);
        self.out.infer_into(&st.lstm.h, y);
    }

    pub fn params_heap_bytes(&self) -> usize {
        self.lstm.params_heap_bytes() + self.out.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.lstm.wx.len()
            + self.lstm.wh.len()
            + self.lstm.b.len()
            + self.out.w.len()
            + self.out.b.len()
    }
}

/// Detached per-session state for the memoryless LSTM baseline.
pub struct LstmSession {
    lstm: LstmState,
}

impl LstmSession {
    pub fn reset(&mut self) {
        self.lstm.reset();
    }

    pub fn heap_bytes(&self) -> usize {
        self.lstm.heap_bytes()
    }

    pub fn tape_bytes(&self) -> usize {
        0
    }
}

impl HasParams for LstmCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.lstm.visit_params(f);
        self.out.visit_params(f);
    }
}

impl Core for LstmCore {
    fn name(&self) -> &'static str {
        "lstm"
    }

    fn reset(&mut self) {
        self.lstm.reset();
        self.out.clear_cache();
        self.steps = 0;
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        self.steps += 1;
        self.lstm.step_hot(x);
        self.out.forward_into(&self.lstm.h, y);
    }

    fn backward(&mut self, dy: &[f32]) {
        self.out.backward_into(dy, &mut self.dh_buf);
        let dh = std::mem::take(&mut self.dh_buf);
        self.lstm.backward_into(&dh, &mut self.dx_buf);
        self.dh_buf = dh;
        self.steps -= 1;
    }

    fn rollback(&mut self) {
        self.reset();
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.x_dim
    }

    fn y_dim(&self) -> usize {
        self.y_dim
    }

    fn tape_bytes(&self) -> usize {
        self.lstm.cache_bytes() + self.out.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    #[test]
    fn gradients_match_fd() {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(42);
        let mut core = LstmCore::new(&cfg, &mut rng);
        let (xs, ts) = random_episode(4, 3, 6, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 8, 1e-2, 0.15);
        assert!(checked >= 30);
        assert_eq!(failed, 0, "{failed}/{checked} gradient checks failed");
    }

    #[test]
    fn forward_shapes() {
        let cfg = CoreConfig { x_dim: 5, y_dim: 2, hidden: 8, ..CoreConfig::default() };
        let mut rng = Rng::new(1);
        let mut core = LstmCore::new(&cfg, &mut rng);
        core.reset();
        let y = core.forward(&[1.0, 0.0, 1.0, 0.0, 1.0]);
        assert_eq!(y.len(), 2);
    }
}
