//! Sparse Access Memory (SAM) — the paper's contribution (§3).
//!
//! Per step and per head:
//!   1. **Write** (§3.2, eq. 5): w^W = α(γ·w̃^R_{t-1} + (1-γ)·𝕀^U) where 𝕀^U
//!      is the least-recently-accessed word; the LRA row is erased
//!      (R_t = 𝕀^U 1ᵀ) then the sparse add w^W a_tᵀ applied. O(K·W) time.
//!   2. **Read** (§3.1, eq. 4): the ANN returns the K most similar words to
//!      the query; w̃^R = softmax(β·cos) over those K; r̃ = Σ w̃^R(sᵢ)M(sᵢ).
//!      O(log N) for the ANN query, O(K·W) for everything else.
//!
//! All memory/ANN/usage/journal state lives in the shared
//! [`ShardedMemoryEngine`] (S memory shards with a parallel fan-out query;
//! `CoreConfig::shards = 1`, the default, is exactly the single
//! [`crate::memory::engine::SparseMemoryEngine`]): the core owns only its
//! controller, head parameters and the recurrent read state. BPTT (§3.4, Supp Fig 5) is the
//! engine's journaled rollback — O(1) space per step instead of O(N); the
//! carried row-sparse memory gradient also lives engine-side.
//!
//! **Zero-allocation steps**: every tape buffer is pooled through the
//! core's [`Workspace`] (or the engine's pools) and recycled during
//! `backward`, so after one warm-up episode `forward_into` + `backward`
//! touch the allocator zero times (rust/tests/zero_alloc.rs).

use super::addressing::{ContentRead, WriteGate};
use super::{BatchCore, Controller, ControllerState, Core, CoreConfig, CtrlBatch, LaneWeights};
use crate::memory::engine::TopKRead;
use crate::memory::sharded::ShardedMemoryEngine;
use crate::serving::spill::SessionSnapshot;
use crate::nn::param::{HasParams, Param};
use crate::tensor::csr::SparseVec;
use crate::tensor::matrix::axpy;
use crate::tensor::workspace::Workspace;
use crate::util::rng::Rng;

/// Raw head parameter layout: [q(W), a(W), α̂, γ̂, β̂].
const fn head_dim(word: usize) -> usize {
    2 * word + 3
}

struct HeadStep {
    /// Write-side caches (the journal itself lives on the engine's tape).
    gate: WriteGate,
    /// The w̃^R_{t-1} actually used by this step's write (moved off the
    /// recurrent state, which the read phase overwrites anyway).
    w_read_used: SparseVec,
    write_word: Vec<f32>,
    /// Read-side caches.
    read: ContentRead,
    query: Vec<f32>,
}

struct SamStep {
    heads: Vec<HeadStep>,
}

/// The SAM core.
pub struct SamCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: ShardedMemoryEngine,
    /// Seeds the training engine was built from, recorded so
    /// [`SamCore::infer_session`] can construct per-session engines whose
    /// episode-start state is bit-identical to the trained core's.
    mem_seed: u64,
    ann_seed: u64,
    /// Per-head previous read weights / read words (recurrent memory state).
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<SamStep>,
    /// The step under construction between `mem_stage_phase` and
    /// `mem_finish_phase` (the batched tick interleaves other lanes'
    /// phases in the gap; the serial forward runs the phases back to back).
    staged_step: Option<SamStep>,
    // ---- carried backward state ----
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<SparseVec>,
    // ---- pooled / persistent step scratch ----
    ws: Workspace,
    /// Per-head query staging (persistent, overwritten each step).
    queries: Vec<Vec<f32>>,
    betas: Vec<f32>,
    /// read_topk staging, drained into the tape every step.
    topk_tmp: Vec<TopKRead>,
    /// Drained SamStep shells (their `heads` Vec capacity).
    spare_steps: Vec<SamStep>,
    dp_buf: Vec<f32>,
    dr_buf: Vec<f32>,
    dq_buf: Vec<f32>,
    da_buf: Vec<f32>,
}

impl SamCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> SamCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "sam",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        // Same seed draw order as `SparseMemoryEngine::new_sparse`, drawn
        // here so sessions can re-derive the identical episode-start state.
        let mem_seed = rng.next_u64();
        let ann_seed = rng.next_u64();
        let engine = ShardedMemoryEngine::new_sparse_from_seeds_fmt(
            cfg.mem_words,
            cfg.word,
            cfg.k,
            cfg.delta,
            cfg.ann,
            mem_seed,
            ann_seed,
            cfg.shards,
            cfg.row_format,
        );
        SamCore {
            ctrl,
            engine,
            mem_seed,
            ann_seed,
            w_read_prev: vec![SparseVec::new(); cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            staged_step: None,
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![SparseVec::new(); cfg.heads],
            ws: Workspace::new(),
            queries: vec![Vec::new(); cfg.heads],
            betas: vec![0.0; cfg.heads],
            topk_tmp: Vec::new(),
            spare_steps: Vec::new(),
            dp_buf: Vec::new(),
            dr_buf: Vec::new(),
            dq_buf: Vec::new(),
            da_buf: Vec::new(),
            cfg: cfg.clone(),
        }
    }

    /// The shared memory engine (read-only) — exposed for the accounting
    /// checks in `benches/fig1_memory.rs` and the parity tests.
    pub fn engine(&self) -> &ShardedMemoryEngine {
        &self.engine
    }

    // -- forward-only inference (shared weights, detached state) ------------

    /// Open a detached inference session: fresh per-session memory and
    /// zeroed recurrent state; the core itself is only read, so one
    /// `Arc<SamCore>` serves any number of sessions concurrently.
    /// `seed: None` (the parity default) reuses the trained core's own
    /// memory/ANN seeds so session outputs are bit-identical to train-mode
    /// forwards; `Some(s)` derives per-session init noise instead.
    pub fn infer_session(&self, seed: Option<u64>) -> SamSession {
        let (mem_seed, ann_seed) = match seed {
            None => (self.mem_seed, self.ann_seed),
            Some(s) => {
                let mut r = Rng::new(s);
                (r.next_u64(), r.next_u64())
            }
        };
        SamSession {
            ctrl: self.ctrl.new_state(),
            engine: ShardedMemoryEngine::new_sparse_from_seeds_fmt(
                self.cfg.mem_words,
                self.cfg.word,
                self.cfg.k,
                self.cfg.delta,
                self.cfg.ann,
                mem_seed,
                ann_seed,
                self.cfg.shards,
                self.cfg.row_format,
            ),
            w_read_prev: vec![SparseVec::new(); self.cfg.heads],
            r_prev: vec![vec![0.0; self.cfg.word]; self.cfg.heads],
            ws: Workspace::new(),
            queries: vec![Vec::new(); self.cfg.heads],
            betas: vec![0.0; self.cfg.heads],
            topk_tmp: Vec::new(),
        }
    }

    /// One forward-only step against shared read-only weights. Same math
    /// and float-op order as [`Core::forward_into`] on a freshly reset core
    /// (bit-identical outputs for matching seeds), but no journal, no tape
    /// and no gradient state: steady-state calls perform **zero** heap
    /// allocations and the session's tape bytes stay 0
    /// (rust/tests/zero_alloc.rs, rust/tests/serving.rs).
    pub fn infer_step(&self, st: &mut SamSession, x: &[f32], y: &mut Vec<f32>) {
        self.ctrl.infer_step(&mut st.ctrl, x, &st.r_prev);
        self.infer_mem_phase(st);
        self.ctrl.infer_output(&mut st.ctrl, &st.r_prev, y);
    }

    /// Batched serving tick: the controller projections of every session
    /// coalesce into one GEMM each (see [`super::infer_tick`]); the sparse
    /// memory phase stays per-session.
    pub fn infer_step_batch(
        &self,
        batch: &mut CtrlBatch,
        sessions: &mut [&mut SamSession],
        xs: &[&[f32]],
        ys: &mut [Vec<f32>],
    ) {
        super::infer_tick(
            &self.ctrl,
            batch,
            sessions,
            xs,
            ys,
            |s| &mut s.ctrl,
            |s| &s.r_prev,
            |s| self.infer_mem_phase(s),
        );
    }

    /// The memory phase of an infer step: per-head gated writes (eq. 5,
    /// journal-free) then one batched top-K read for all heads (eq. 2/4),
    /// consuming the raw head params in `st.ctrl.p`.
    fn infer_mem_phase(&self, st: &mut SamSession) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        for hi in 0..self.cfg.heads {
            let (alpha_raw, gamma_raw) =
                (st.ctrl.p[hi * hd + 2 * w], st.ctrl.p[hi * hd + 2 * w + 1]);
            let wts = st.engine.infer_write(
                alpha_raw,
                gamma_raw,
                &st.w_read_prev[hi],
                &st.ctrl.p[hi * hd + w..hi * hd + 2 * w],
                &mut st.ws,
            );
            st.ws.recycle_sparse(wts);
        }
        for hi in 0..self.cfg.heads {
            st.queries[hi].clear();
            st.queries[hi].extend_from_slice(&st.ctrl.p[hi * hd..hi * hd + w]);
            st.betas[hi] = st.ctrl.p[hi * hd + 2 * w + 2];
        }
        debug_assert!(st.topk_tmp.is_empty());
        let mut topk = std::mem::take(&mut st.topk_tmp);
        st.engine.read_topk_into(&st.queries, &st.betas, &mut topk, &mut st.ws);
        for (hi, tk) in topk.drain(..).enumerate() {
            let old = std::mem::replace(&mut st.w_read_prev[hi], tk.weights);
            st.ws.recycle_sparse(old);
            st.r_prev[hi].clear();
            st.r_prev[hi].extend_from_slice(&tk.r);
            st.ws.recycle_f32(tk.r);
            st.engine.recycle_content_read(tk.read, &mut st.ws);
        }
        st.topk_tmp = topk;
    }

    /// Heap bytes of the trained parameters (one Arc-shared copy in
    /// serving, regardless of session count).
    pub fn params_heap_bytes(&self) -> usize {
        self.ctrl.params_heap_bytes()
    }

    /// Parameter scalar count through `&self`.
    pub fn params_len(&self) -> usize {
        self.ctrl.params_len()
    }

    /// Recycle a popped tape step's buffers and park its shell.
    fn recycle_step(&mut self, mut step: SamStep) {
        for h in step.heads.drain(..) {
            self.ws.recycle_f32(h.write_word);
            self.ws.recycle_f32(h.query);
            self.ws.recycle_sparse(h.gate.weights);
            self.ws.recycle_sparse(h.w_read_used);
            self.engine.recycle_content_read(h.read, &mut self.ws);
        }
        self.spare_steps.push(step);
    }

    // -- memory-phase seams (shared by the serial path and the batched
    //    training tick; consume the raw head params in `self.ctrl`) --------

    /// F6a: per-head gated writes (previous step's read weights, eq. 5) and
    /// content-query staging — everything up to the ANN lookup.
    fn mem_stage_phase(&mut self) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        let mut step = self.spare_steps.pop().unwrap_or_else(|| SamStep { heads: Vec::new() });
        debug_assert!(step.heads.is_empty());
        for hi in 0..self.cfg.heads {
            let (alpha_raw, gamma_raw) = {
                let p = self.ctrl.head_params();
                (p[hi * hd + 2 * w], p[hi * hd + 2 * w + 1])
            };
            let a = {
                let p = self.ctrl.head_params();
                self.ws.take_f32_copy(&p[hi * hd + w..hi * hd + 2 * w])
            };
            let gate = self.engine.sparse_write(
                alpha_raw,
                gamma_raw,
                &self.w_read_prev[hi],
                &a,
                &mut self.ws,
            );
            step.heads.push(HeadStep {
                gate,
                w_read_used: std::mem::take(&mut self.w_read_prev[hi]),
                write_word: a,
                // placeholder read fields, filled by `mem_finish_phase`
                read: ContentRead::empty(),
                query: Vec::new(),
            });
        }
        for hi in 0..self.cfg.heads {
            let p = self.ctrl.head_params();
            self.queries[hi].clear();
            self.queries[hi].extend_from_slice(&p[hi * hd..hi * hd + w]);
            self.betas[hi] = p[hi * hd + 2 * w + 2];
        }
        self.staged_step = Some(step);
    }

    /// F6b: run the ANN lookup over the staged queries into the engine's
    /// neighbour lists. `nested` keeps the fill strictly serial (the batched
    /// tick's merged dispatch already runs each lane on a pool worker).
    fn ann_fill_phase(&mut self, nested: bool) {
        if self.staged_step.is_none() {
            return;
        }
        self.engine.ann_fill_neigh(&self.queries, nested);
    }

    /// F6c: finish the reads from the filled neighbour lists (post-write
    /// memory M_t; eq. 2/4), update the recurrent read state and push the
    /// completed step on the tape.
    fn mem_finish_phase(&mut self) {
        let mut step = self.staged_step.take().expect("mem_finish without mem_stage");
        debug_assert!(self.topk_tmp.is_empty());
        let mut topk = std::mem::take(&mut self.topk_tmp);
        self.engine.read_topk_from_neigh(&self.queries, &self.betas, &mut topk, &mut self.ws);
        for (hi, tk) in topk.drain(..).enumerate() {
            self.w_read_prev[hi] = tk.weights;
            self.r_prev[hi].clear();
            self.r_prev[hi].extend_from_slice(&tk.r);
            self.ws.recycle_f32(tk.r);
            let hstep = &mut step.heads[hi];
            hstep.read = tk.read;
            hstep.query = self.ws.take_f32_copy(&self.queries[hi]);
        }
        self.topk_tmp = topk;
        self.tape.push(step);
    }

    /// B4: memory backward for one step — read backward over M_t, then
    /// write backward in reverse head order rolling memory back — filling
    /// `self.dp_buf` with the raw head-parameter gradient.
    fn backward_mem_phase(&mut self, step: &SamStep) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.dp_buf.clear();
        self.dp_buf.resize(self.cfg.heads * hd, 0.0);

        // --- read backward (memory is M_t here) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            // dr = dL/dr_t from the output + r_t's feed of step t+1's input.
            self.dr_buf.clear();
            self.dr_buf.extend_from_slice(&self.ctrl.dreads()[hi]);
            axpy(&mut self.dr_buf, 1.0, &self.d_r[hi]);
            // w̃^R_t also fed step t+1's write gate (carried d_wread).
            self.dq_buf.clear();
            self.dq_buf.resize(w, 0.0);
            let mut dbeta_raw = 0.0;
            self.engine.backward_read_topk(
                &hstep.read,
                &hstep.query,
                &self.dr_buf,
                &self.d_wread[hi],
                &mut self.dq_buf,
                &mut dbeta_raw,
                &mut self.ws,
            );
            let dslice = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
            dslice[..w].iter_mut().zip(&self.dq_buf).for_each(|(a, b)| *a += b);
            dslice[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order, rolling memory back) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let (mut dar, mut dgr) = (0.0f32, 0.0f32);
            self.da_buf.clear();
            self.da_buf.resize(w, 0.0);
            let dw_prev = self.engine.backward_write_into(
                &hstep.gate,
                &hstep.write_word,
                &hstep.w_read_used,
                &mut dar,
                &mut dgr,
                &mut self.da_buf,
                &mut self.ws,
            );
            let old = std::mem::replace(&mut self.d_wread[hi], dw_prev);
            self.ws.recycle_sparse(old);
            let dslice = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
            dslice[w..2 * w].iter_mut().zip(&self.da_buf).for_each(|(x, d)| *x += d);
            dslice[2 * w] += dar;
            dslice[2 * w + 1] += dgr;
        }
    }
}

/// Detached per-session episodic state for SAM serving: everything an
/// infer step mutates — controller h/c, the session's private memory
/// (store + ANN + LRA ring, no journals), recurrent read state and the
/// buffer pools. Parameters live in the shared [`SamCore`].
pub struct SamSession {
    ctrl: ControllerState,
    engine: ShardedMemoryEngine,
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    ws: Workspace,
    queries: Vec<Vec<f32>>,
    betas: Vec<f32>,
    topk_tmp: Vec<TopKRead>,
}

impl SamSession {
    /// Start a new episode: memory back to its seeded init, recurrent
    /// state zeroed. Allocation-free (no journals to unwind in infer mode).
    pub fn reset(&mut self) {
        self.ctrl.reset();
        self.engine.reinit();
        for hi in 0..self.w_read_prev.len() {
            let old = std::mem::take(&mut self.w_read_prev[hi]);
            self.ws.recycle_sparse(old);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// The session's memory engine (read-only) — for accounting tests.
    pub fn engine(&self) -> &ShardedMemoryEngine {
        &self.engine
    }

    /// Capture everything an infer step mutates into a plain-vector
    /// snapshot (the spill payload): decoded memory rows + Int8 scales,
    /// LRA ring order, LSTM h/c and the recurrent read state. Scratch
    /// buffers (`ws`, `queries`, `betas`, `topk_tmp`) are rebuilt by the
    /// next step and deliberately excluded.
    pub fn export_state(&mut self) -> SessionSnapshot {
        SessionSnapshot {
            n: self.engine.n(),
            word: self.engine.word_size(),
            row_format: self.engine.row_format(),
            mem_seed: self.engine.mem_seed(),
            rows: self.engine.snapshot(),
            scales: self.engine.row_scales(),
            ring_order: self.engine.ring_order(),
            h: self.ctrl.lstm.h.clone(),
            c: self.ctrl.lstm.c.clone(),
            w_read_prev: self.w_read_prev.iter().map(|w| w.iter().collect()).collect(),
            r_prev: self.r_prev.clone(),
        }
    }

    /// Restore a spilled snapshot into this freshly opened session,
    /// overwriting rows (re-syncing each ANN slot, mirroring `reset`'s
    /// reinit discipline), ring order, h/c and read state. The session
    /// must have been opened from the same model with the same open seed —
    /// shape, row format and `mem_seed` are all checked. Bit-identical
    /// continuation for ann=linear; approximate indexes rebuild
    /// deterministically from the same rows but may break score ties
    /// differently than the live index they replace (DESIGN.md).
    pub fn import_state(&mut self, snap: &SessionSnapshot) -> anyhow::Result<()> {
        use anyhow::bail;
        if snap.n != self.engine.n() || snap.word != self.engine.word_size() {
            bail!(
                "snapshot shape {}x{} != session memory {}x{}",
                snap.n,
                snap.word,
                self.engine.n(),
                self.engine.word_size()
            );
        }
        if snap.row_format != self.engine.row_format() {
            bail!(
                "snapshot row format {} != session row format {}",
                snap.row_format.name(),
                self.engine.row_format().name()
            );
        }
        if snap.mem_seed != self.engine.mem_seed() {
            bail!(
                "snapshot mem_seed {:#x} != session mem_seed {:#x} (different open seed?)",
                snap.mem_seed,
                self.engine.mem_seed()
            );
        }
        if snap.heads() != self.w_read_prev.len() || snap.r_prev.len() != self.r_prev.len() {
            bail!(
                "snapshot heads {} != session heads {}",
                snap.heads(),
                self.w_read_prev.len()
            );
        }
        if snap.h.len() != self.ctrl.lstm.h.len() || snap.c.len() != self.ctrl.lstm.c.len() {
            bail!(
                "snapshot hidden width {} != session hidden width {}",
                snap.h.len(),
                self.ctrl.lstm.h.len()
            );
        }
        if snap.r_prev.iter().any(|r| r.len() != snap.word) {
            bail!("snapshot r_prev width != word");
        }
        self.engine.import_state(&snap.rows, &snap.scales, &snap.ring_order);
        self.ctrl.lstm.h.copy_from_slice(&snap.h);
        self.ctrl.lstm.c.copy_from_slice(&snap.c);
        for (dst, src) in self.w_read_prev.iter_mut().zip(&snap.w_read_prev) {
            dst.clear();
            for &(i, v) in src {
                dst.push(i, v);
            }
        }
        for (dst, src) in self.r_prev.iter_mut().zip(&snap.r_prev) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        Ok(())
    }

    /// Heap bytes of this session's state; the memory store dominates.
    /// Parameters are deliberately excluded — they are the shared model's.
    pub fn heap_bytes(&self) -> usize {
        self.engine.heap_bytes()
            + self.ws.heap_bytes()
            + self.ctrl.heap_bytes()
            + self.w_read_prev.iter().map(|v| v.heap_bytes()).sum::<usize>()
            + self.r_prev.iter().map(|r| r.capacity() * 4).sum::<usize>()
            + self.queries.iter().map(|q| q.capacity() * 4).sum::<usize>()
    }

    /// BPTT tape bytes — 0 by construction in infer mode (asserted while
    /// serving).
    pub fn tape_bytes(&self) -> usize {
        self.engine.tape_bytes()
    }
}

impl HasParams for SamCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for SamCore {
    fn name(&self) -> &'static str {
        "sam"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        if let Some(step) = self.staged_step.take() {
            self.recycle_step(step);
        }
        while let Some(step) = self.tape.pop() {
            self.recycle_step(step);
        }
        // Engine rollback restores memory + ANN even if the previous
        // episode was abandoned without backward/rollback.
        self.engine.reset(&mut self.ws);
        for hi in 0..self.cfg.heads {
            let old = std::mem::take(&mut self.w_read_prev[hi]);
            self.ws.recycle_sparse(old);
            let old = std::mem::take(&mut self.d_wread[hi]);
            self.ws.recycle_sparse(old);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        self.ctrl.step_hot(x, &self.r_prev);
        // The same memory-phase seams the batched tick drives, back to back.
        self.mem_stage_phase();
        self.ann_fill_phase(false);
        self.mem_finish_phase();
        self.ctrl.output_hot(&self.r_prev, y);
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        self.ctrl.backward_output_hot(dy);
        self.backward_mem_phase(&step);
        // --- controller backward (writes d_r_prev into self.d_r) ---
        self.ctrl.backward_step_hot(&self.dp_buf, &mut self.d_r);
        // Tape recycling: every pooled buffer this step held goes home.
        self.recycle_step(step);
    }

    fn rollback(&mut self) {
        while let Some(step) = self.tape.pop() {
            self.recycle_step(step);
        }
        self.engine.rollback_ws(&mut self.ws);
    }

    fn end_episode(&mut self) {
        debug_assert!(self.tape.is_empty(), "end_episode with live tape");
        self.engine.end_episode();
    }

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step_bytes: usize = self
            .tape
            .iter()
            .map(|s| {
                s.heads
                    .iter()
                    .map(|h| {
                        h.w_read_used.heap_bytes()
                            + (h.write_word.capacity() + h.query.capacity()) * 4
                            + h.read.rows.capacity() * 8
                            + h.read.weights.capacity() * 4
                            + h.read.sims.capacity() * 12
                            + h.gate.weights.heap_bytes()
                    })
                    .sum::<usize>()
            })
            .sum();
        step_bytes + self.engine.tape_bytes() + self.ctrl.cache_bytes()
    }
}

/// Batched-training seams: the controller hooks delegate to the shared
/// [`Controller`] staging methods; the memory phases are the same
/// `mem_*_phase`/`backward_mem_phase` bodies the serial path runs back to
/// back (one code path, bit-identical by construction).
impl BatchCore for SamCore {
    fn cell_in_dim(&self) -> usize {
        self.ctrl.lstm.input
    }

    fn cell_hidden(&self) -> usize {
        self.ctrl.lstm.hidden
    }

    fn head_param_dim(&self) -> usize {
        self.cfg.heads * head_dim(self.cfg.word)
    }

    fn out_in_dim(&self) -> usize {
        self.ctrl.out_lin.in_dim()
    }

    fn weights(&self) -> LaneWeights<'_> {
        LaneWeights {
            wx: &self.ctrl.lstm.wx.w,
            wh: &self.ctrl.lstm.wh.w,
            head: Some((&self.ctrl.head_lin.w.w, &self.ctrl.head_lin.b.w.data)),
            out: (&self.ctrl.out_lin.w.w, &self.ctrl.out_lin.b.w.data),
        }
    }

    fn stage_input(&self, x: &[f32], x_row: &mut [f32], h_row: &mut [f32]) {
        self.ctrl.stage_input_row(x, &self.r_prev, x_row, h_row);
    }

    fn cell_step(&mut self, x_row: &[f32], zx_row: &mut [f32], zh_row: &[f32]) {
        self.ctrl.cell_step_row(x_row, zx_row, zh_row);
    }

    fn h(&self) -> &[f32] {
        self.ctrl.h()
    }

    fn note_head_forward(&mut self, p_row: &[f32]) {
        self.ctrl.note_head_forward(p_row);
    }

    fn mem_stage(&mut self) {
        self.mem_stage_phase();
    }

    fn ann_fill(&mut self, nested: bool) {
        self.ann_fill_phase(nested);
    }

    fn ann_fill_rows(&self) -> usize {
        if self.staged_step.is_some() {
            self.cfg.mem_words
        } else {
            0
        }
    }

    fn mem_finish(&mut self) {
        self.mem_finish_phase();
    }

    fn stage_output(&self, o_row: &mut [f32]) {
        self.ctrl.stage_output_row(&self.r_prev, o_row);
    }

    fn note_forward_out(&mut self, o_row: &[f32]) {
        self.ctrl.note_forward_out(o_row);
    }

    fn note_output_backward(&mut self, dy: &[f32], d_o_row: &[f32]) {
        self.ctrl.note_output_backward(dy, d_o_row);
    }

    fn backward_mem(&mut self) {
        let step = self.tape.pop().expect("backward without forward");
        self.backward_mem_phase(&step);
        self.recycle_step(step);
    }

    fn dp(&self) -> &[f32] {
        &self.dp_buf
    }

    fn backward_cell_z(&mut self, dh_row: &mut [f32], dz_row: &mut [f32]) {
        self.ctrl.backward_cell_z_row(&self.dp_buf, dh_row, dz_row);
    }

    fn finish_backward(&mut self, dz_row: &[f32], dh_prev_row: &[f32], dx_row: &[f32]) {
        self.ctrl.finish_backward_row(dz_row, dh_prev_row, dx_row, &mut self.d_r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn forward_shapes_and_tape() {
        let mut rng = Rng::new(1);
        let mut core = SamCore::new(&small_cfg(1), &mut rng);
        core.reset();
        for _ in 0..5 {
            let y = core.forward(&[1.0, 0.0, 1.0, 0.0]);
            assert_eq!(y.len(), 3);
        }
        assert!(core.tape_bytes() > 0);
        core.rollback();
        core.end_episode();
    }

    #[test]
    fn memory_rolls_back_after_backward() {
        let mut rng = Rng::new(2);
        let mut core = SamCore::new(&small_cfg(2), &mut rng);
        core.reset();
        let start = core.engine().snapshot();
        let t = 6;
        let (xs, ts) = random_episode(4, 3, t, &mut rng);
        let mut dys = Vec::new();
        for (x, tt) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, tt).1);
        }
        assert_ne!(core.engine().snapshot(), start, "writes should modify memory");
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        assert_eq!(core.engine().snapshot(), start, "BPTT must roll memory back bit-exactly");
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(3);
        let mut core = SamCore::new(&small_cfg(3), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 5e-3, 0.2);
        assert!(checked >= 30);
        // Discrete ANN/LRA selections can flip under FD perturbation,
        // corrupting individual coordinates; a systematic backward bug
        // fails a large fraction (it fails ~100% when seeded in mutation
        // testing), so the 1/8 bound is a strong signal.
        assert!(
            failed * 8 <= checked,
            "{failed}/{checked} gradient checks failed"
        );
    }

    #[test]
    fn episodes_are_independent() {
        // Two identical episodes separated by reset must give identical outputs.
        let mut rng = Rng::new(4);
        let mut core = SamCore::new(&small_cfg(4), &mut rng);
        let (xs, _) = random_episode(4, 3, 4, &mut rng);
        core.reset();
        let y1: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        core.reset();
        let y2: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        for (a, b) in y1.iter().zip(&y2) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5, "episodes not independent");
            }
        }
    }

    #[test]
    fn pooled_episodes_are_bit_identical() {
        // Stronger than `episodes_are_independent`: buffer recycling must
        // not perturb a single bit, episode after episode, including the
        // gradients.
        let mut rng = Rng::new(7);
        let mut core = SamCore::new(&small_cfg(7), &mut rng);
        let (xs, ts) = random_episode(4, 3, 6, &mut rng);
        let mut y = Vec::new();
        let mut first: Vec<Vec<u32>> = Vec::new();
        for ep in 0..4 {
            core.zero_grads();
            core.reset();
            let mut dys = Vec::new();
            let mut bits: Vec<Vec<u32>> = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                core.forward_into(x, &mut y);
                bits.push(y.iter().map(|v| v.to_bits()).collect());
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
            if ep == 0 {
                first = bits;
            } else {
                assert_eq!(first, bits, "episode {ep} diverged bitwise");
            }
        }
    }

    #[test]
    fn tape_bytes_independent_of_memory_size() {
        // The Fig 1b property at unit scale: per-step tape cost must not
        // scale with N.
        let mut sizes = Vec::new();
        for &n in &[32usize, 256, 2048] {
            let mut rng = Rng::new(5);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(5) };
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 8, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
            core.end_episode();
        }
        let spread = (sizes[2] as f64 - sizes[0] as f64).abs() / sizes[0] as f64;
        assert!(spread < 0.1, "tape grows with N: {sizes:?}");
    }

    #[test]
    fn infer_session_matches_train_forward_bitwise() {
        let mut rng = Rng::new(9);
        let mut core = SamCore::new(&small_cfg(9), &mut rng);
        let (xs, _) = random_episode(4, 3, 6, &mut rng);
        let mut st = core.infer_session(None);
        let mut yi = Vec::new();
        for ep in 0..2 {
            core.reset();
            for x in &xs {
                let yt = core.forward(x);
                core.infer_step(&mut st, x, &mut yi);
                for (a, b) in yt.iter().zip(&yi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            core.rollback();
            core.end_episode();
            st.reset();
            assert_eq!(st.tape_bytes(), 0);
        }
    }

    #[test]
    fn infer_batch_is_composition_independent() {
        // The same session stream stepped alone and co-batched with others
        // must produce identical bits (tile padding, see infer_tick docs).
        let mut rng = Rng::new(10);
        let core = SamCore::new(&small_cfg(10), &mut rng);
        let (xs, _) = random_episode(4, 3, 5, &mut rng);
        let mut batch = CtrlBatch::new();
        let mut alone = core.infer_session(Some(42));
        let mut co_a = core.infer_session(Some(42));
        let mut co_b = core.infer_session(Some(43));
        let mut co_c = core.infer_session(Some(44));
        let mut y1 = vec![Vec::new()];
        let mut y3 = vec![Vec::new(), Vec::new(), Vec::new()];
        for x in &xs {
            let xr: &[f32] = x.as_slice();
            {
                let mut s = [&mut alone];
                core.infer_step_batch(&mut batch, &mut s, &[xr], &mut y1);
            }
            {
                let mut s = [&mut co_a, &mut co_b, &mut co_c];
                core.infer_step_batch(&mut batch, &mut s, &[xr, xr, xr], &mut y3);
            }
            for (a, b) in y1[0].iter().zip(&y3[0]) {
                assert_eq!(a.to_bits(), b.to_bits(), "batch composition changed bits");
            }
        }
    }

    #[test]
    fn works_with_kdtree_and_lsh() {
        for ann in [AnnKind::KdForest, AnnKind::Lsh] {
            let cfg = CoreConfig { ann, ..small_cfg(6) };
            let mut rng = Rng::new(6);
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, ts) = random_episode(4, 3, 5, &mut rng);
            let mut dys = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                let y = core.forward(x);
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
        }
    }
}
