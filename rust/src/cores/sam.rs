//! Sparse Access Memory (SAM) — the paper's contribution (§3).
//!
//! Per step and per head:
//!   1. **Write** (§3.2, eq. 5): w^W = α(γ·w̃^R_{t-1} + (1-γ)·𝕀^U) where 𝕀^U
//!      is the least-recently-accessed word; the LRA row is erased
//!      (R_t = 𝕀^U 1ᵀ) then the sparse add w^W a_tᵀ applied. O(K·W) time.
//!   2. **Read** (§3.1, eq. 4): the ANN returns the K most similar words to
//!      the query; w̃^R = softmax(β·cos) over those K; r̃ = Σ w̃^R(sᵢ)M(sᵢ).
//!      O(log N) for the ANN query, O(K·W) for everything else.
//!
//! All memory/ANN/usage/journal state lives in the shared
//! [`SparseMemoryEngine`]: the core owns only its controller, head
//! parameters and the recurrent read state. BPTT (§3.4, Supp Fig 5) is the
//! engine's journaled rollback — O(1) space per step instead of O(N); the
//! carried row-sparse memory gradient also lives engine-side.

use super::addressing::{ContentRead, WriteGate};
use super::{Controller, Core, CoreConfig};
use crate::memory::engine::SparseMemoryEngine;
use crate::nn::param::{HasParams, Param};
use crate::tensor::csr::SparseVec;
use crate::util::rng::Rng;

/// Raw head parameter layout: [q(W), a(W), α̂, γ̂, β̂].
const fn head_dim(word: usize) -> usize {
    2 * word + 3
}

struct HeadStep {
    /// Write-side caches (the journal itself lives on the engine's tape).
    gate: WriteGate,
    /// The w̃^R_{t-1} actually used by this step's write.
    w_read_used: SparseVec,
    write_word: Vec<f32>,
    /// Read-side caches.
    read: ContentRead,
    query: Vec<f32>,
    read_out: Vec<f32>,
}

struct SamStep {
    heads: Vec<HeadStep>,
}

/// The SAM core.
pub struct SamCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: SparseMemoryEngine,
    /// Per-head previous read weights / read words (recurrent memory state).
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<SamStep>,
    // ---- carried backward state ----
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<SparseVec>,
}

impl SamCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> SamCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "sam",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        let engine = SparseMemoryEngine::new_sparse(
            cfg.mem_words,
            cfg.word,
            cfg.k,
            cfg.delta,
            cfg.ann,
            &mut rng,
        );
        SamCore {
            ctrl,
            engine,
            w_read_prev: vec![SparseVec::new(); cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![SparseVec::new(); cfg.heads],
            cfg: cfg.clone(),
        }
    }

    /// Split one head's slice of the raw controller parameters.
    fn parse_head(&self, p: &[f32]) -> (Vec<f32>, Vec<f32>, f32, f32, f32) {
        let w = self.cfg.word;
        (
            p[..w].to_vec(),            // q
            p[w..2 * w].to_vec(),       // a
            p[2 * w],                   // α̂
            p[2 * w + 1],               // γ̂
            p[2 * w + 2],               // β̂
        )
    }

    /// The shared memory engine (read-only) — exposed for the accounting
    /// checks in `benches/fig1_memory.rs` and the parity tests.
    pub fn engine(&self) -> &SparseMemoryEngine {
        &self.engine
    }
}

impl HasParams for SamCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for SamCore {
    fn name(&self) -> &'static str {
        "sam"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        // Engine rollback restores memory + ANN even if the previous
        // episode was abandoned without backward/rollback.
        self.engine.reset();
        for wv in &mut self.w_read_prev {
            *wv = SparseVec::new();
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for d in &mut self.d_wread {
            *d = SparseVec::new();
        }
    }

    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let hd = head_dim(self.cfg.word);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- writes (use previous step's read weights, eq. 5) ---
        for hi in 0..self.cfg.heads {
            let (_q, a, alpha_raw, gamma_raw, _beta) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
            let gate =
                self.engine.sparse_write(alpha_raw, gamma_raw, &self.w_read_prev[hi], &a);
            heads.push(HeadStep {
                gate,
                w_read_used: self.w_read_prev[hi].clone(),
                write_word: a,
                // placeholder read fields, filled below
                read: ContentRead {
                    rows: vec![],
                    sims: vec![],
                    weights: vec![],
                    beta: 0.0,
                    beta_raw: 0.0,
                },
                query: vec![],
                read_out: vec![],
            });
        }

        // --- reads (post-write memory M_t; one batched index traversal
        //     answers every head) ---
        let queries: Vec<(Vec<f32>, f32)> = (0..self.cfg.heads)
            .map(|hi| {
                let (q, _a, _ar, _gr, beta_raw) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
                (q, beta_raw)
            })
            .collect();
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for (hi, tk) in self.engine.read_topk(queries).into_iter().enumerate() {
            self.w_read_prev[hi] = tk.weights;
            heads[hi].read = tk.read;
            heads[hi].query = tk.query;
            heads[hi].read_out = tk.r.clone();
            reads.push(tk.r);
        }

        let y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(SamStep { heads });
        y
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);

        let mut dp = vec![0.0f32; self.cfg.heads * hd];

        // --- read backward (memory is M_t here) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            // r_t also fed step t+1's controller input.
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            // w̃^R_t also fed step t+1's write gate (carried d_wread).
            let dslice = &mut dp[hi * hd..(hi + 1) * hd];
            let mut dbeta_raw = 0.0;
            let mut dq = vec![0.0f32; w];
            self.engine.backward_read_topk(
                &hstep.read,
                &hstep.query,
                &dr,
                &self.d_wread[hi],
                &mut dq,
                &mut dbeta_raw,
            );
            dslice[..w].iter_mut().zip(&dq).for_each(|(a, b)| *a += b);
            dslice[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order, rolling memory back) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let (mut dar, mut dgr) = (0.0f32, 0.0f32);
            let (da, dw_prev) = self.engine.backward_write(
                &hstep.gate,
                &hstep.write_word,
                &hstep.w_read_used,
                &mut dar,
                &mut dgr,
            );
            self.d_wread[hi] = dw_prev;
            let dslice = &mut dp[hi * hd..(hi + 1) * hd];
            dslice[w..2 * w].iter_mut().zip(&da).for_each(|(x, d)| *x += d);
            dslice[2 * w] += dar;
            dslice[2 * w + 1] += dgr;
        }

        // --- controller backward ---
        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        self.tape.clear();
        self.engine.rollback();
    }

    fn end_episode(&mut self) {
        debug_assert!(self.tape.is_empty(), "end_episode with live tape");
        self.engine.end_episode();
    }

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step_bytes: usize = self
            .tape
            .iter()
            .map(|s| {
                s.heads
                    .iter()
                    .map(|h| {
                        h.w_read_used.heap_bytes()
                            + (h.write_word.capacity()
                                + h.query.capacity()
                                + h.read_out.capacity())
                                * 4
                            + h.read.rows.capacity() * 8
                            + h.read.weights.capacity() * 4
                            + h.read.sims.capacity() * 12
                            + h.gate.weights.heap_bytes()
                    })
                    .sum::<usize>()
            })
            .sum();
        step_bytes + self.engine.tape_bytes() + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn forward_shapes_and_tape() {
        let mut rng = Rng::new(1);
        let mut core = SamCore::new(&small_cfg(1), &mut rng);
        core.reset();
        for _ in 0..5 {
            let y = core.forward(&[1.0, 0.0, 1.0, 0.0]);
            assert_eq!(y.len(), 3);
        }
        assert!(core.tape_bytes() > 0);
        core.rollback();
        core.end_episode();
    }

    #[test]
    fn memory_rolls_back_after_backward() {
        let mut rng = Rng::new(2);
        let mut core = SamCore::new(&small_cfg(2), &mut rng);
        core.reset();
        let start = core.engine().snapshot();
        let t = 6;
        let (xs, ts) = random_episode(4, 3, t, &mut rng);
        let mut dys = Vec::new();
        for (x, tt) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, tt).1);
        }
        assert_ne!(core.engine().snapshot(), start, "writes should modify memory");
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        assert_eq!(core.engine().snapshot(), start, "BPTT must roll memory back bit-exactly");
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(3);
        let mut core = SamCore::new(&small_cfg(3), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 5e-3, 0.2);
        assert!(checked >= 30);
        // Discrete ANN/LRA selections can flip under FD perturbation,
        // corrupting individual coordinates; a systematic backward bug
        // fails a large fraction (it fails ~100% when seeded in mutation
        // testing), so the 1/8 bound is a strong signal.
        assert!(
            failed * 8 <= checked,
            "{failed}/{checked} gradient checks failed"
        );
    }

    #[test]
    fn episodes_are_independent() {
        // Two identical episodes separated by reset must give identical outputs.
        let mut rng = Rng::new(4);
        let mut core = SamCore::new(&small_cfg(4), &mut rng);
        let (xs, _) = random_episode(4, 3, 4, &mut rng);
        core.reset();
        let y1: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        core.reset();
        let y2: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        for (a, b) in y1.iter().zip(&y2) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5, "episodes not independent");
            }
        }
    }

    #[test]
    fn tape_bytes_independent_of_memory_size() {
        // The Fig 1b property at unit scale: per-step tape cost must not
        // scale with N.
        let mut sizes = Vec::new();
        for &n in &[32usize, 256, 2048] {
            let mut rng = Rng::new(5);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(5) };
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 8, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
            core.end_episode();
        }
        let spread = (sizes[2] as f64 - sizes[0] as f64).abs() / sizes[0] as f64;
        assert!(spread < 0.1, "tape grows with N: {sizes:?}");
    }

    #[test]
    fn works_with_kdtree_and_lsh() {
        for ann in [AnnKind::KdForest, AnnKind::Lsh] {
            let cfg = CoreConfig { ann, ..small_cfg(6) };
            let mut rng = Rng::new(6);
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, ts) = random_episode(4, 3, 5, &mut rng);
            let mut dys = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                let y = core.forward(x);
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
        }
    }
}
