//! Sparse Access Memory (SAM) — the paper's contribution (§3).
//!
//! Per step and per head:
//!   1. **Write** (§3.2, eq. 5): w^W = α(γ·w̃^R_{t-1} + (1-γ)·𝕀^U) where 𝕀^U
//!      is the least-recently-accessed word from the [`LraRing`]; the LRA
//!      row is erased (R_t = 𝕀^U 1ᵀ) then the sparse add w^W a_tᵀ applied.
//!      O(K·W) time; the prior contents of touched rows go to a journal.
//!   2. **Read** (§3.1, eq. 4): the ANN returns the K most similar words to
//!      the query; w̃^R = softmax(β·cos) over those K; r̃ = Σ w̃^R(sᵢ)M(sᵢ).
//!      O(log N) for the ANN query, O(K·W) for everything else.
//!
//! BPTT (§3.4, Supp Fig 5): backward reverts each step's journal, rolling
//! the memory back in place — O(1) space per step instead of O(N). Memory
//! gradients are row-sparse ([`RowSparse`]): rows appear when a future read
//! touched them and die when the pass crosses the erase that created them.

use super::addressing::{
    content_weights, content_weights_backward, write_gate, write_gate_backward, ContentRead,
    WriteGate,
};
use super::{Controller, Core, CoreConfig};
use crate::ann::{build_index, AnnIndex};
use crate::memory::store::{MemoryStore, StepJournal, WriteOp};
use crate::memory::usage::LraRing;
use crate::tensor::csr::{RowSparse, SparseVec};
use crate::tensor::matrix::dot;
use crate::nn::param::{HasParams, Param};
use crate::util::rng::Rng;
use std::collections::HashSet;

/// Raw head parameter layout: [q(W), a(W), α̂, γ̂, β̂].
const fn head_dim(word: usize) -> usize {
    2 * word + 3
}

struct HeadStep {
    /// Write-side caches.
    gate: WriteGate,
    journal: StepJournal,
    /// The w̃^R_{t-1} actually used by this step's write.
    w_read_used: SparseVec,
    write_word: Vec<f32>,
    /// Read-side caches.
    read: ContentRead,
    query: Vec<f32>,
    read_out: Vec<f32>,
}

struct SamStep {
    heads: Vec<HeadStep>,
}

/// The SAM core.
pub struct SamCore {
    cfg: CoreConfig,
    ctrl: Controller,
    mem: MemoryStore,
    ann: Box<dyn AnnIndex>,
    ring: LraRing,
    /// Per-head previous read weights / read words (recurrent memory state).
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<SamStep>,
    /// Rows whose contents changed this episode (for ANN resync).
    touched: HashSet<usize>,
    /// Seed for the deterministic per-row memory init (see [`init_row`]).
    mem_seed: u64,
    // ---- carried backward state ----
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<SparseVec>,
    dmem: RowSparse,
    ann_dirty: bool,
}

impl SamCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> SamCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "sam",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        let mem_seed = rng.next_u64();
        let mut mem = MemoryStore::zeros(cfg.mem_words, cfg.word);
        for i in 0..cfg.mem_words {
            init_row(mem_seed, i, mem.row_mut(i));
        }
        let mut ann = build_index(cfg.ann, cfg.mem_words, cfg.word, rng.next_u64());
        for i in 0..cfg.mem_words {
            ann.insert(i, mem.row(i));
        }
        SamCore {
            ctrl,
            mem,
            ann,
            mem_seed,
            ring: LraRing::new(cfg.mem_words),
            w_read_prev: vec![SparseVec::new(); cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            touched: HashSet::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![SparseVec::new(); cfg.heads],
            dmem: RowSparse::new(cfg.word),
            ann_dirty: false,
            cfg: cfg.clone(),
        }
    }

    /// Split one head's slice of the raw controller parameters.
    fn parse_head(&self, p: &[f32]) -> (Vec<f32>, Vec<f32>, f32, f32, f32) {
        let w = self.cfg.word;
        (
            p[..w].to_vec(),            // q
            p[w..2 * w].to_vec(),       // a
            p[2 * w],                   // α̂
            p[2 * w + 1],               // γ̂
            p[2 * w + 2],               // β̂
        )
    }

    fn resync_ann(&mut self) {
        for &row in &self.touched {
            self.ann.update(row, self.mem.row(row));
        }
        self.touched.clear();
        self.ann_dirty = false;
    }
}

/// Episode-start contents of memory row `i`: small deterministic noise
/// (std [`MEM_INIT_STD`]) regenerable per row in O(W). A strictly zero
/// memory makes every content similarity tie at episode start, which makes
/// the ANN's top-K selection arbitrary; tiny distinct words break the ties
/// without carrying information. Deterministic regeneration lets `reset`
/// restore an abandoned episode in O(touched) instead of O(N).
pub(crate) const MEM_INIT_STD: f32 = 0.02;

pub(crate) fn init_row(seed: u64, i: usize, out: &mut [f32]) {
    let mut r = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    for v in out {
        *v = r.normal() * MEM_INIT_STD;
    }
}

impl HasParams for SamCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for SamCore {
    fn name(&self) -> &'static str {
        "sam"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        // If the previous episode fully rolled back (the normal train path)
        // the memory already equals its start state and only the ANN and
        // ring need resetting; otherwise restore the touched rows.
        if self.ann_dirty || !self.touched.is_empty() {
            // Memory may have residual episode contents if rollback() was
            // skipped: regenerate the touched rows' init state (O(touched)).
            let rows: Vec<usize> = self.touched.iter().copied().collect();
            for row in rows {
                init_row(self.mem_seed, row, self.mem.row_mut(row));
            }
            self.resync_ann();
        }
        self.ring.reset();
        for wv in &mut self.w_read_prev {
            *wv = SparseVec::new();
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for d in &mut self.d_wread {
            *d = SparseVec::new();
        }
        self.dmem = RowSparse::new(self.cfg.word);
    }

    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let hd = head_dim(self.cfg.word);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- writes (use previous step's read weights, eq. 5) ---
        for hi in 0..self.cfg.heads {
            let (_q, a, alpha_raw, gamma_raw, _beta) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
            let lra_row = self.ring.pop_lra();
            let gate = write_gate(alpha_raw, gamma_raw, &self.w_read_prev[hi], lra_row);
            let op = WriteOp {
                erase_rows: vec![lra_row],
                weights: gate.weights.clone(),
                word: a.clone(),
            };
            let journal = self.mem.apply_write(&op);
            for (i, wv) in gate.weights.iter() {
                if wv.abs() > self.cfg.delta {
                    self.ring.touch(i);
                }
                self.touched.insert(i);
            }
            self.touched.insert(lra_row);
            // Keep the ANN in sync with every changed row (§3.5).
            for row in journal.touched_rows() {
                self.ann.update(row, self.mem.row(row));
            }
            self.ann_dirty = true;
            heads.push(HeadStep {
                gate,
                journal,
                w_read_used: self.w_read_prev[hi].clone(),
                write_word: a,
                // placeholder read fields, filled below
                read: ContentRead {
                    rows: vec![],
                    sims: vec![],
                    weights: vec![],
                    beta: 0.0,
                    beta_raw: 0.0,
                },
                query: vec![],
                read_out: vec![],
            });
        }

        // --- reads (post-write memory M_t) ---
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for hi in 0..self.cfg.heads {
            let (q, _a, _ar, _gr, beta_raw) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
            let neighbors = self.ann.query(&q, self.cfg.k);
            let rows: Vec<usize> = neighbors.iter().map(|&(i, _)| i).collect();
            let read = content_weights(&q, beta_raw, &self.mem, rows);
            let w_sparse = SparseVec::from_pairs(
                read.rows.iter().copied().zip(read.weights.iter().copied()).collect(),
            );
            let mut r = vec![0.0; self.cfg.word];
            self.mem.read_sparse(&w_sparse, &mut r);
            for (i, wv) in w_sparse.iter() {
                if wv > self.cfg.delta {
                    self.ring.touch(i);
                }
            }
            self.w_read_prev[hi] = w_sparse;
            heads[hi].read = read;
            heads[hi].query = q;
            heads[hi].read_out = r.clone();
            reads.push(r);
        }

        let y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(SamStep { heads });
        y
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);

        let mut dp = vec![0.0f32; self.cfg.heads * hd];

        // --- read backward (memory is M_t here) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            // r_t also fed step t+1's controller input.
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            // r̃ = Σ w̃ᵢ M(sᵢ)
            let kn = hstep.read.rows.len();
            let mut dweights = vec![0.0f32; kn];
            for (j, &row) in hstep.read.rows.iter().enumerate() {
                dweights[j] = dot(self.mem.row(row), &dr);
                self.dmem.axpy_row(row, hstep.read.weights[j], &dr);
            }
            // w̃^R_t also fed step t+1's write gate.
            for (j, &row) in hstep.read.rows.iter().enumerate() {
                dweights[j] += self.d_wread[hi].get(row);
            }
            // softmax(β·cos) backward → dq, dβ̂, dM rows.
            let dslice = &mut dp[hi * hd..(hi + 1) * hd];
            let mut dbeta_raw = 0.0;
            let mut dq = vec![0.0f32; w];
            let dmem_ref = &mut self.dmem;
            content_weights_backward(
                &hstep.read,
                &hstep.query,
                &self.mem,
                &dweights,
                &mut dq,
                &mut dbeta_raw,
                |row, d| dmem_ref.axpy_row(row, 1.0, d),
            );
            dslice[..w].iter_mut().zip(&dq).for_each(|(a, b)| *a += b);
            dslice[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order, rolling memory back) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let dslice_start = hi * hd;
            // da and dw^W from dM (w.r.t. memory state after this head's write).
            let mut da = vec![0.0f32; w];
            let mut dw_pairs = Vec::with_capacity(hstep.gate.weights.nnz());
            for (i, wv) in hstep.gate.weights.iter() {
                if let Some(drow) = self.dmem.row(i) {
                    for (daj, dj) in da.iter_mut().zip(drow) {
                        *daj += wv * dj;
                    }
                    dw_pairs.push((i, dot(&hstep.write_word, drow)));
                }
            }
            let dw = SparseVec::from_pairs(dw_pairs);
            // The erased row's pre-write contents don't affect the loss.
            self.dmem.clear_row(hstep.gate.lra_row);
            // Gate backward → dα̂, dγ̂ and grad on w̃^R_{t-1} (carried).
            let (mut dar, mut dgr) = (0.0f32, 0.0f32);
            let dw_prev = write_gate_backward(&hstep.gate, &hstep.w_read_used, &dw, &mut dar, &mut dgr);
            self.d_wread[hi] = dw_prev;
            let dslice = &mut dp[dslice_start..dslice_start + hd];
            dslice[w..2 * w].iter_mut().zip(&da).for_each(|(x, d)| *x += d);
            dslice[2 * w] += dar;
            dslice[2 * w + 1] += dgr;
            // Roll the memory back below this head's write (Supp Fig 5).
            self.mem.revert(&hstep.journal);
        }

        // --- controller backward ---
        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        while let Some(step) = self.tape.pop() {
            for hstep in step.heads.iter().rev() {
                self.mem.revert(&hstep.journal);
            }
        }
    }

    fn end_episode(&mut self) {
        debug_assert!(self.tape.is_empty(), "end_episode with live tape");
        // Memory has rolled back to the episode-start state; resync the ANN
        // for every row the episode touched (O(T log N), Supp A.1).
        self.resync_ann();
    }

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step_bytes: usize = self
            .tape
            .iter()
            .map(|s| {
                s.heads
                    .iter()
                    .map(|h| {
                        h.journal.heap_bytes()
                            + h.w_read_used.heap_bytes()
                            + (h.write_word.capacity()
                                + h.query.capacity()
                                + h.read_out.capacity())
                                * 4
                            + h.read.rows.capacity() * 8
                            + h.read.weights.capacity() * 4
                            + h.read.sims.capacity() * 12
                            + h.gate.weights.heap_bytes()
                    })
                    .sum::<usize>()
            })
            .sum();
        step_bytes + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn forward_shapes_and_tape() {
        let mut rng = Rng::new(1);
        let mut core = SamCore::new(&small_cfg(1), &mut rng);
        core.reset();
        for _ in 0..5 {
            let y = core.forward(&[1.0, 0.0, 1.0, 0.0]);
            assert_eq!(y.len(), 3);
        }
        assert!(core.tape_bytes() > 0);
        core.rollback();
        core.end_episode();
    }

    #[test]
    fn memory_rolls_back_after_backward() {
        let mut rng = Rng::new(2);
        let mut core = SamCore::new(&small_cfg(2), &mut rng);
        core.reset();
        let start = core.mem.snapshot();
        let t = 6;
        let (xs, ts) = random_episode(4, 3, t, &mut rng);
        let mut dys = Vec::new();
        for (x, tt) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, tt).1);
        }
        assert_ne!(core.mem.snapshot(), start, "writes should modify memory");
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        assert_eq!(core.mem.snapshot(), start, "BPTT must roll memory back bit-exactly");
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(3);
        let mut core = SamCore::new(&small_cfg(3), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 5e-3, 0.2);
        assert!(checked >= 30);
        // Discrete ANN/LRA selections can flip under FD perturbation,
        // corrupting individual coordinates; a systematic backward bug
        // fails a large fraction (it fails ~100% when seeded in mutation
        // testing), so the 1/8 bound is a strong signal.
        assert!(
            failed * 8 <= checked,
            "{failed}/{checked} gradient checks failed"
        );
    }

    #[test]
    fn episodes_are_independent() {
        // Two identical episodes separated by reset must give identical outputs.
        let mut rng = Rng::new(4);
        let mut core = SamCore::new(&small_cfg(4), &mut rng);
        let (xs, _) = random_episode(4, 3, 4, &mut rng);
        core.reset();
        let y1: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        core.reset();
        let y2: Vec<Vec<f32>> = xs.iter().map(|x| core.forward(x)).collect();
        core.rollback();
        core.end_episode();
        for (a, b) in y1.iter().zip(&y2) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5, "episodes not independent");
            }
        }
    }

    #[test]
    fn tape_bytes_independent_of_memory_size() {
        // The Fig 1b property at unit scale: per-step tape cost must not
        // scale with N.
        let mut sizes = Vec::new();
        for &n in &[32usize, 256, 2048] {
            let mut rng = Rng::new(5);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(5) };
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 8, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
            core.end_episode();
        }
        let spread = (sizes[2] as f64 - sizes[0] as f64).abs() / sizes[0] as f64;
        assert!(spread < 0.1, "tape grows with N: {sizes:?}");
    }

    #[test]
    fn works_with_kdtree_and_lsh() {
        for ann in [AnnKind::KdForest, AnnKind::Lsh] {
            let cfg = CoreConfig { ann, ..small_cfg(6) };
            let mut rng = Rng::new(6);
            let mut core = SamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, ts) = random_episode(4, 3, 5, &mut rng);
            let mut dys = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                let y = core.forward(x);
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
        }
    }
}
