//! Dense Access Memory (DAM) — the paper's dense control model for SAM
//! (§3.2): identical architecture, but reads are a softmax over *all* N
//! words, writes touch all N entries of w^W, usage is the time-discounted
//! sum U⁽¹⁾, and BPTT caches a full memory snapshot per step. Costs O(N·W)
//! time and space per step — the overhead Figures 1a/1b plot against SAM.
//!
//! The memory itself lives in a dense-mode [`SparseMemoryEngine`] (no ANN,
//! snapshot/restore instead of journals); DAM keeps only its discounted
//! usage U⁽¹⁾ and dense gradient state locally.

use super::addressing::{content_weights, content_weights_backward, ContentRead};
use super::{Controller, Core, CoreConfig};
use crate::memory::engine::SparseMemoryEngine;
use crate::memory::usage::DiscountedUsage;
use crate::nn::act::{dsigmoid, sigmoid};
use crate::nn::param::{HasParams, Param};
use crate::tensor::matrix::{dot, Matrix};
use crate::util::rng::Rng;

const fn head_dim(word: usize) -> usize {
    2 * word + 3 // [q(W), a(W), α̂, γ̂, β̂]
}

struct HeadStep {
    /// Dense write weights and gate scalars.
    w_write: Vec<f32>,
    alpha: f32,
    gamma: f32,
    lra_row: usize,
    w_read_used: Vec<f32>,
    write_word: Vec<f32>,
    /// Read caches.
    read: ContentRead,
    query: Vec<f32>,
}

struct DamStep {
    /// Snapshot of M_{t-1} (pre-write) — the O(N·W)/step BPTT cost.
    mem_before: Vec<f32>,
    heads: Vec<HeadStep>,
}

pub struct DamCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: SparseMemoryEngine,
    usage: DiscountedUsage,
    w_read_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<DamStep>,
    // carried backward state
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<Vec<f32>>,
    dmem: Matrix,
}

impl DamCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> DamCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "dam",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        DamCore {
            ctrl,
            engine: SparseMemoryEngine::new_dense(cfg.mem_words, cfg.word),
            usage: DiscountedUsage::new(cfg.mem_words, cfg.lambda),
            w_read_prev: vec![vec![0.0; cfg.mem_words]; cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![vec![0.0; cfg.mem_words]; cfg.heads],
            dmem: Matrix::zeros(cfg.mem_words, cfg.word),
            cfg: cfg.clone(),
        }
    }

    fn parse_head<'a>(&self, p: &'a [f32]) -> (&'a [f32], &'a [f32], f32, f32, f32) {
        let w = self.cfg.word;
        (&p[..w], &p[w..2 * w], p[2 * w], p[2 * w + 1], p[2 * w + 2])
    }
}

impl HasParams for DamCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for DamCore {
    fn name(&self) -> &'static str {
        "dam"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        self.engine.fill(0.0);
        self.usage.reset();
        for v in &mut self.w_read_prev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.d_wread {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.dmem.fill(0.0);
    }

    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let n = self.cfg.mem_words;
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let hd = head_dim(self.cfg.word);
        let mem_before = self.engine.snapshot();
        self.usage.u.iter_mut().for_each(|u| *u *= self.usage.lambda);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- dense writes (eq. 5 with dense w^R_{t-1} and U⁽¹⁾ argmin) ---
        for hi in 0..self.cfg.heads {
            let (_q, a, ar, gr, _br) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
            let alpha = sigmoid(ar);
            let gamma = sigmoid(gr);
            let lra_row = self.usage.argmin();
            let mut w_write = vec![0.0f32; n];
            for i in 0..n {
                w_write[i] = alpha * gamma * self.w_read_prev[hi][i];
            }
            w_write[lra_row] += alpha * (1.0 - gamma);
            // Erase the least-used row fully (R_t = 𝕀^U 1ᵀ), then dense add.
            self.engine.dense_write(&w_write, a, lra_row);
            // Usage sees this head's write immediately so the next head
            // picks a different least-used slot.
            for i in 0..n {
                self.usage.u[i] += w_write[i];
            }
            heads.push(HeadStep {
                w_write,
                alpha,
                gamma,
                lra_row,
                w_read_used: self.w_read_prev[hi].clone(),
                write_word: a.to_vec(),
                read: ContentRead { rows: vec![], sims: vec![], weights: vec![], beta: 0.0, beta_raw: 0.0 },
                query: vec![],
            });
        }

        // --- dense reads over all N words (eq. 1/2) ---
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for hi in 0..self.cfg.heads {
            let (q, _a, _ar, _gr, br) = self.parse_head(&p[hi * hd..(hi + 1) * hd]);
            let read = content_weights(q, br, self.engine.store(), (0..n).collect());
            let mut r = vec![0.0; self.cfg.word];
            self.engine.read_dense(&read.weights, &mut r);
            for i in 0..n {
                self.usage.u[i] += read.weights[i];
            }
            self.w_read_prev[hi] = read.weights.clone();
            heads[hi].read = read;
            heads[hi].query = q.to_vec();
            reads.push(r);
        }

        let y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(DamStep { mem_before, heads });
        y
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);
        let mut dp = vec![0.0f32; self.cfg.heads * hd];

        // --- read backward (memory currently = M_t) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            let mut dweights = vec![0.0f32; n];
            for i in 0..n {
                dweights[i] = dot(self.engine.store().row(i), &dr) + self.d_wread[hi][i];
                let wv = hstep.read.weights[i];
                if wv != 0.0 {
                    let row = self.dmem.row_mut(i);
                    for (g, &d) in row.iter_mut().zip(&dr) {
                        *g += wv * d;
                    }
                }
            }
            let dslice = &mut dp[hi * hd..(hi + 1) * hd];
            let mut dbeta_raw = 0.0;
            let mut dq = vec![0.0f32; w];
            let dmem_ref = &mut self.dmem;
            content_weights_backward(
                &hstep.read,
                &hstep.query,
                self.engine.store(),
                &dweights,
                &mut dq,
                &mut dbeta_raw,
                |row, d| {
                    let r = dmem_ref.row_mut(row);
                    for (g, &x) in r.iter_mut().zip(d) {
                        *g += x;
                    }
                },
            );
            dslice[..w].iter_mut().zip(&dq).for_each(|(a, b)| *a += b);
            dslice[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let mut da = vec![0.0f32; w];
            let mut dw = vec![0.0f32; n];
            for i in 0..n {
                let wv = hstep.w_write[i];
                let drow = self.dmem.row(i);
                if wv != 0.0 {
                    for (daj, &dj) in da.iter_mut().zip(drow) {
                        *daj += wv * dj;
                    }
                }
                dw[i] = dot(&hstep.write_word, drow);
            }
            // Erased row's pre-write contents are irrelevant.
            self.dmem.row_mut(hstep.lra_row).iter_mut().for_each(|v| *v = 0.0);
            // Gate backward: w^W = α(γ·wp + (1-γ)·e_u).
            let (a, g) = (hstep.alpha, hstep.gamma);
            let mut dalpha = 0.0f32;
            let mut dgamma = 0.0f32;
            for i in 0..n {
                let e_u = if i == hstep.lra_row { 1.0 } else { 0.0 };
                dalpha += dw[i] * (g * hstep.w_read_used[i] + (1.0 - g) * e_u);
                dgamma += dw[i] * a * (hstep.w_read_used[i] - e_u);
                self.d_wread[hi][i] = dw[i] * a * g;
            }
            let dslice = &mut dp[hi * hd..(hi + 1) * hd];
            dslice[w..2 * w].iter_mut().zip(&da).for_each(|(x, d)| *x += d);
            dslice[2 * w] += dalpha * dsigmoid(a);
            dslice[2 * w + 1] += dgamma * dsigmoid(g);
        }

        // Restore M_{t-1} for the next backward step.
        self.engine.restore(&step.mem_before);
        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        if let Some(first) = self.tape.first() {
            let m = first.mem_before.clone();
            self.engine.restore(&m);
        }
        self.tape.clear();
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                s.mem_before.capacity() * 4
                    + s.heads
                        .iter()
                        .map(|h| {
                            (h.w_write.capacity()
                                + h.w_read_used.capacity()
                                + h.read.weights.capacity())
                                * 4
                                + h.read.sims.capacity() * 12
                                + h.read.rows.capacity() * 8
                                + (h.write_word.capacity() + h.query.capacity()) * 4
                        })
                        .sum::<usize>()
            })
            .sum();
        step + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 6,
            mem_words: 12,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(13);
        let mut core = DamCore::new(&small_cfg(13), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.2);
        assert!(checked >= 30);
        // argmin-usage flips can perturb a few coordinates.
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn memory_restored_after_backward() {
        let mut rng = Rng::new(14);
        let mut core = DamCore::new(&small_cfg(14), &mut rng);
        core.reset();
        let start = core.engine.snapshot();
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        assert_eq!(core.engine.snapshot(), start);
    }

    #[test]
    fn tape_grows_linearly_with_n() {
        // The dense model's BPTT tape must scale with memory size (the
        // pathology SAM removes).
        let mut sizes = Vec::new();
        for &n in &[16usize, 64] {
            let mut rng = Rng::new(15);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(15) };
            let mut core = DamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 6, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
        }
        assert!(sizes[1] as f64 > 2.5 * sizes[0] as f64, "{sizes:?}");
    }
}
