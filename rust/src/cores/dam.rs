//! Dense Access Memory (DAM) — the paper's dense control model for SAM
//! (§3.2): identical architecture, but reads are a softmax over *all* N
//! words, writes touch all N entries of w^W, usage is the time-discounted
//! sum U⁽¹⁾, and BPTT caches a full memory snapshot per step. Costs O(N·W)
//! time and space per step — the overhead Figures 1a/1b plot against SAM.
//!
//! The memory itself lives in a dense-mode [`SparseMemoryEngine`] (no ANN,
//! snapshot/restore instead of journals); DAM keeps only its discounted
//! usage U⁽¹⁾ and dense gradient state locally. The per-step O(N·W)
//! *work* is inherent to the dense baseline, but the per-step O(N·W)
//! *allocations* are not: snapshots, write weights and content caches all
//! recycle through the core's [`Workspace`].

use super::addressing::{content_weights_backward_ws, content_weights_into, ContentRead, CosSim};
use super::{Controller, ControllerState, Core, CoreConfig, CtrlBatch};
use crate::memory::engine::SparseMemoryEngine;
use crate::memory::usage::DiscountedUsage;
use crate::nn::act::{dsigmoid, sigmoid};
use crate::nn::param::{HasParams, Param};
use crate::tensor::matrix::{axpy, dot, Matrix};
use crate::tensor::workspace::{Pool, Workspace};
use crate::util::rng::Rng;

const fn head_dim(word: usize) -> usize {
    2 * word + 3 // [q(W), a(W), α̂, γ̂, β̂]
}

struct HeadStep {
    /// Dense write weights and gate scalars.
    w_write: Vec<f32>,
    alpha: f32,
    gamma: f32,
    lra_row: usize,
    w_read_used: Vec<f32>,
    write_word: Vec<f32>,
    /// Read caches.
    read: ContentRead,
    query: Vec<f32>,
}

struct DamStep {
    /// Snapshot of M_{t-1} (pre-write) — the O(N·W)/step BPTT cost.
    mem_before: Vec<f32>,
    heads: Vec<HeadStep>,
}

pub struct DamCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: SparseMemoryEngine,
    usage: DiscountedUsage,
    w_read_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<DamStep>,
    // carried backward state
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<Vec<f32>>,
    dmem: Matrix,
    // pooled / persistent step scratch
    ws: Workspace,
    sim_pool: Pool<CosSim>,
    spare_steps: Vec<DamStep>,
    dp_buf: Vec<f32>,
    dr_buf: Vec<f32>,
    dq_buf: Vec<f32>,
    da_buf: Vec<f32>,
    dw_buf: Vec<f32>,
    dweights_buf: Vec<f32>,
}

impl DamCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> DamCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "dam",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        DamCore {
            ctrl,
            engine: SparseMemoryEngine::new_dense(cfg.mem_words, cfg.word),
            usage: DiscountedUsage::new(cfg.mem_words, cfg.lambda),
            w_read_prev: vec![vec![0.0; cfg.mem_words]; cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![vec![0.0; cfg.mem_words]; cfg.heads],
            dmem: Matrix::zeros(cfg.mem_words, cfg.word),
            ws: Workspace::new(),
            sim_pool: Pool::new(),
            spare_steps: Vec::new(),
            dp_buf: Vec::new(),
            dr_buf: Vec::new(),
            dq_buf: Vec::new(),
            da_buf: Vec::new(),
            dw_buf: Vec::new(),
            dweights_buf: Vec::new(),
            cfg: cfg.clone(),
        }
    }

    // -- forward-only inference (shared weights, detached state) ------------

    /// Open a detached inference session. DAM's memory is zero-initialized
    /// (no seeds), so every session starts identically; `_seed` is accepted
    /// for interface symmetry with the sparse cores.
    pub fn infer_session(&self, _seed: Option<u64>) -> DamSession {
        DamSession {
            ctrl: self.ctrl.new_state(),
            engine: SparseMemoryEngine::new_dense(self.cfg.mem_words, self.cfg.word),
            usage: DiscountedUsage::new(self.cfg.mem_words, self.cfg.lambda),
            w_read_prev: vec![vec![0.0; self.cfg.mem_words]; self.cfg.heads],
            r_prev: vec![vec![0.0; self.cfg.word]; self.cfg.heads],
            ws: Workspace::new(),
            sim_pool: Pool::new(),
        }
    }

    /// One forward-only step: bit-identical to [`Core::forward_into`] on a
    /// freshly reset core, minus the per-step O(N·W) memory snapshot the
    /// training tape needs — serving a dense control model still pays
    /// O(N·W) *work* per step, but no longer O(N·W·T) *space*.
    pub fn infer_step(&self, st: &mut DamSession, x: &[f32], y: &mut Vec<f32>) {
        self.ctrl.infer_step(&mut st.ctrl, x, &st.r_prev);
        self.infer_mem_phase(st);
        self.ctrl.infer_output(&mut st.ctrl, &st.r_prev, y);
    }

    /// Batched serving tick (see [`super::infer_tick`]).
    pub fn infer_step_batch(
        &self,
        batch: &mut CtrlBatch,
        sessions: &mut [&mut DamSession],
        xs: &[&[f32]],
        ys: &mut [Vec<f32>],
    ) {
        super::infer_tick(
            &self.ctrl,
            batch,
            sessions,
            xs,
            ys,
            |s| &mut s.ctrl,
            |s| &s.r_prev,
            |s| self.infer_mem_phase(s),
        );
    }

    /// Dense write + dense read phase of an infer step, consuming the raw
    /// head params in `st.ctrl.p`.
    fn infer_mem_phase(&self, st: &mut DamSession) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        st.usage.u.iter_mut().for_each(|u| *u *= st.usage.lambda);
        for hi in 0..self.cfg.heads {
            let (alpha, gamma) = (
                sigmoid(st.ctrl.p[hi * hd + 2 * w]),
                sigmoid(st.ctrl.p[hi * hd + 2 * w + 1]),
            );
            let lra_row = st.usage.argmin();
            let mut w_write = st.ws.take_f32(n);
            for i in 0..n {
                w_write[i] = alpha * gamma * st.w_read_prev[hi][i];
            }
            w_write[lra_row] += alpha * (1.0 - gamma);
            st.engine
                .dense_write(&w_write, &st.ctrl.p[hi * hd + w..hi * hd + 2 * w], lra_row);
            for i in 0..n {
                st.usage.u[i] += w_write[i];
            }
            st.ws.recycle_f32(w_write);
        }
        for hi in 0..self.cfg.heads {
            let beta_raw = st.ctrl.p[hi * hd + 2 * w + 2];
            let mut rows = st.ws.take_usize(n);
            rows.extend(0..n);
            let read = content_weights_into(
                &st.ctrl.p[hi * hd..hi * hd + w],
                beta_raw,
                st.engine.store(),
                rows,
                st.sim_pool.take(),
                st.ws.take_f32_empty(n),
            );
            st.r_prev[hi].clear();
            st.r_prev[hi].resize(w, 0.0);
            st.engine.read_dense(&read.weights, &mut st.r_prev[hi]);
            for i in 0..n {
                st.usage.u[i] += read.weights[i];
            }
            st.w_read_prev[hi].clear();
            st.w_read_prev[hi].extend_from_slice(&read.weights);
            st.ws.recycle_usize(read.rows);
            st.ws.recycle_f32(read.weights);
            st.sim_pool.recycle(read.sims);
        }
    }

    /// Heap bytes of the trained parameters.
    pub fn params_heap_bytes(&self) -> usize {
        self.ctrl.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.ctrl.params_len()
    }

    /// Recycle a popped tape step's buffers and park its shell. The N·W
    /// snapshot buffer stays in the shell (cleared, capacity kept): no
    /// other DAM buffer shares its capacity class, so pooling it would
    /// strand it and re-allocate a fresh snapshot every step.
    fn recycle_step(&mut self, mut step: DamStep) {
        step.mem_before.clear();
        for h in step.heads.drain(..) {
            self.ws.recycle_f32(h.w_write);
            self.ws.recycle_f32(h.w_read_used);
            self.ws.recycle_f32(h.write_word);
            self.ws.recycle_f32(h.query);
            self.ws.recycle_usize(h.read.rows);
            self.ws.recycle_f32(h.read.weights);
            self.sim_pool.recycle(h.read.sims);
        }
        self.spare_steps.push(step);
    }
}

/// Detached per-session episodic state for DAM serving: controller h/c,
/// a private dense memory (no snapshots), discounted usage and the dense
/// recurrent read state. Parameters live in the shared [`DamCore`].
pub struct DamSession {
    ctrl: ControllerState,
    engine: SparseMemoryEngine,
    usage: DiscountedUsage,
    w_read_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
    ws: Workspace,
    sim_pool: Pool<CosSim>,
}

impl DamSession {
    /// Start a new episode: memory zeroed, usage and recurrent state reset.
    pub fn reset(&mut self) {
        self.ctrl.reset();
        self.engine.reinit();
        self.usage.reset();
        for v in &mut self.w_read_prev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.engine.heap_bytes()
            + self.ws.heap_bytes()
            + self.ctrl.heap_bytes()
            + self.usage.u.capacity() * 4
            + self
                .w_read_prev
                .iter()
                .chain(self.r_prev.iter())
                .map(|v| v.capacity() * 4)
                .sum::<usize>()
    }

    pub fn tape_bytes(&self) -> usize {
        self.engine.tape_bytes()
    }
}

impl HasParams for DamCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for DamCore {
    fn name(&self) -> &'static str {
        "dam"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        while let Some(step) = self.tape.pop() {
            self.recycle_step(step);
        }
        self.engine.fill(0.0);
        self.usage.reset();
        for v in &mut self.w_read_prev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.d_wread {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.dmem.fill(0.0);
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.ctrl.step_hot(x, &self.r_prev);
        let mut step = self
            .spare_steps
            .pop()
            .unwrap_or_else(|| DamStep { mem_before: Vec::new(), heads: Vec::new() });
        debug_assert!(step.heads.is_empty());
        let mut mem_before = std::mem::take(&mut step.mem_before);
        self.engine.snapshot_into(&mut mem_before);
        step.mem_before = mem_before;
        self.usage.u.iter_mut().for_each(|u| *u *= self.usage.lambda);

        // --- dense writes (eq. 5 with dense w^R_{t-1} and U⁽¹⁾ argmin) ---
        for hi in 0..self.cfg.heads {
            let (alpha, gamma, a) = {
                let p = self.ctrl.head_params();
                let ph = &p[hi * hd..(hi + 1) * hd];
                (
                    sigmoid(ph[2 * w]),
                    sigmoid(ph[2 * w + 1]),
                    self.ws.take_f32_copy(&ph[w..2 * w]),
                )
            };
            let lra_row = self.usage.argmin();
            let mut w_write = self.ws.take_f32(n);
            for i in 0..n {
                w_write[i] = alpha * gamma * self.w_read_prev[hi][i];
            }
            w_write[lra_row] += alpha * (1.0 - gamma);
            // Erase the least-used row fully (R_t = 𝕀^U 1ᵀ), then dense add.
            self.engine.dense_write(&w_write, &a, lra_row);
            // Usage sees this head's write immediately so the next head
            // picks a different least-used slot.
            for i in 0..n {
                self.usage.u[i] += w_write[i];
            }
            let w_read_used = self.ws.take_f32_copy(&self.w_read_prev[hi]);
            step.heads.push(HeadStep {
                w_write,
                alpha,
                gamma,
                lra_row,
                w_read_used,
                write_word: a,
                read: ContentRead::empty(),
                query: Vec::new(),
            });
        }

        // --- dense reads over all N words (eq. 1/2) ---
        for hi in 0..self.cfg.heads {
            let (query, beta_raw) = {
                let p = self.ctrl.head_params();
                let ph = &p[hi * hd..(hi + 1) * hd];
                (self.ws.take_f32_copy(&ph[..w]), ph[2 * w + 2])
            };
            let mut rows = self.ws.take_usize(n);
            rows.extend(0..n);
            let read = content_weights_into(
                &query,
                beta_raw,
                self.engine.store(),
                rows,
                self.sim_pool.take(),
                self.ws.take_f32_empty(n),
            );
            self.r_prev[hi].clear();
            self.r_prev[hi].resize(w, 0.0);
            self.engine.read_dense(&read.weights, &mut self.r_prev[hi]);
            for i in 0..n {
                self.usage.u[i] += read.weights[i];
            }
            self.w_read_prev[hi].clear();
            self.w_read_prev[hi].extend_from_slice(&read.weights);
            let hstep = &mut step.heads[hi];
            hstep.read = read;
            hstep.query = query;
        }

        self.ctrl.output_hot(&self.r_prev, y);
        self.tape.push(step);
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.ctrl.backward_output_hot(dy);
        self.dp_buf.clear();
        self.dp_buf.resize(self.cfg.heads * hd, 0.0);

        // --- read backward (memory currently = M_t) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            self.dr_buf.clear();
            self.dr_buf.extend_from_slice(&self.ctrl.dreads()[hi]);
            axpy(&mut self.dr_buf, 1.0, &self.d_r[hi]);
            self.dweights_buf.clear();
            self.dweights_buf.resize(n, 0.0);
            for i in 0..n {
                self.dweights_buf[i] =
                    dot(self.engine.store().row(i), &self.dr_buf) + self.d_wread[hi][i];
                let wv = hstep.read.weights[i];
                if wv != 0.0 {
                    let row = self.dmem.row_mut(i);
                    for (g, &d) in row.iter_mut().zip(&self.dr_buf) {
                        *g += wv * d;
                    }
                }
            }
            self.dq_buf.clear();
            self.dq_buf.resize(w, 0.0);
            let mut dbeta_raw = 0.0;
            let dmem_ref = &mut self.dmem;
            content_weights_backward_ws(
                &hstep.read,
                &hstep.query,
                self.engine.store(),
                &self.dweights_buf,
                &mut self.dq_buf,
                &mut dbeta_raw,
                &mut self.ws,
                |row, d| {
                    let r = dmem_ref.row_mut(row);
                    for (g, &x) in r.iter_mut().zip(d) {
                        *g += x;
                    }
                },
            );
            let dslice = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
            dslice[..w].iter_mut().zip(&self.dq_buf).for_each(|(a, b)| *a += b);
            dslice[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            self.da_buf.clear();
            self.da_buf.resize(w, 0.0);
            self.dw_buf.clear();
            self.dw_buf.resize(n, 0.0);
            for i in 0..n {
                let wv = hstep.w_write[i];
                let drow = self.dmem.row(i);
                if wv != 0.0 {
                    for (daj, &dj) in self.da_buf.iter_mut().zip(drow) {
                        *daj += wv * dj;
                    }
                }
                self.dw_buf[i] = dot(&hstep.write_word, drow);
            }
            // Erased row's pre-write contents are irrelevant.
            self.dmem.row_mut(hstep.lra_row).iter_mut().for_each(|v| *v = 0.0);
            // Gate backward: w^W = α(γ·wp + (1-γ)·e_u).
            let (a, g) = (hstep.alpha, hstep.gamma);
            let mut dalpha = 0.0f32;
            let mut dgamma = 0.0f32;
            for i in 0..n {
                let e_u = if i == hstep.lra_row { 1.0 } else { 0.0 };
                dalpha += self.dw_buf[i] * (g * hstep.w_read_used[i] + (1.0 - g) * e_u);
                dgamma += self.dw_buf[i] * a * (hstep.w_read_used[i] - e_u);
                self.d_wread[hi][i] = self.dw_buf[i] * a * g;
            }
            let dslice = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
            dslice[w..2 * w].iter_mut().zip(&self.da_buf).for_each(|(x, d)| *x += d);
            dslice[2 * w] += dalpha * dsigmoid(a);
            dslice[2 * w + 1] += dgamma * dsigmoid(g);
        }

        // Restore M_{t-1} for the next backward step.
        self.engine.restore(&step.mem_before);
        self.ctrl.backward_step_hot(&self.dp_buf, &mut self.d_r);
        self.recycle_step(step);
    }

    fn rollback(&mut self) {
        if let Some(first) = self.tape.first() {
            let m = first.mem_before.clone();
            self.engine.restore(&m);
        }
        while let Some(step) = self.tape.pop() {
            self.recycle_step(step);
        }
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                s.mem_before.capacity() * 4
                    + s.heads
                        .iter()
                        .map(|h| {
                            (h.w_write.capacity()
                                + h.w_read_used.capacity()
                                + h.read.weights.capacity())
                                * 4
                                + h.read.sims.capacity() * 12
                                + h.read.rows.capacity() * 8
                                + (h.write_word.capacity() + h.query.capacity()) * 4
                        })
                        .sum::<usize>()
            })
            .sum();
        step + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 6,
            mem_words: 12,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(13);
        let mut core = DamCore::new(&small_cfg(13), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.2);
        assert!(checked >= 30);
        // argmin-usage flips can perturb a few coordinates.
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn memory_restored_after_backward() {
        let mut rng = Rng::new(14);
        let mut core = DamCore::new(&small_cfg(14), &mut rng);
        core.reset();
        let start = core.engine.snapshot();
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        assert_eq!(core.engine.snapshot(), start);
    }

    #[test]
    fn pooled_episodes_are_bit_identical() {
        let mut rng = Rng::new(16);
        let mut core = DamCore::new(&small_cfg(16), &mut rng);
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let mut y = Vec::new();
        let mut first: Vec<Vec<u32>> = Vec::new();
        for ep in 0..3 {
            core.zero_grads();
            core.reset();
            let mut dys = Vec::new();
            let mut bits: Vec<Vec<u32>> = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                core.forward_into(x, &mut y);
                bits.push(y.iter().map(|v| v.to_bits()).collect());
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
            if ep == 0 {
                first = bits;
            } else {
                assert_eq!(first, bits, "episode {ep} diverged bitwise");
            }
        }
    }

    #[test]
    fn infer_session_matches_train_forward_bitwise() {
        let mut rng = Rng::new(17);
        let mut core = DamCore::new(&small_cfg(17), &mut rng);
        let (xs, _) = random_episode(4, 3, 5, &mut rng);
        let mut st = core.infer_session(None);
        let mut yi = Vec::new();
        for ep in 0..2 {
            core.reset();
            for x in &xs {
                let yt = core.forward(x);
                core.infer_step(&mut st, x, &mut yi);
                for (a, b) in yt.iter().zip(&yi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            core.rollback();
            core.end_episode();
            st.reset();
            assert_eq!(st.tape_bytes(), 0);
        }
    }

    #[test]
    fn tape_grows_linearly_with_n() {
        // The dense model's BPTT tape must scale with memory size (the
        // pathology SAM removes).
        let mut sizes = Vec::new();
        for &n in &[16usize, 64] {
            let mut rng = Rng::new(15);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(15) };
            let mut core = DamCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 6, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
        }
        assert!(sizes[1] as f64 > 2.5 * sizes[0] as f64, "{sizes:?}");
    }
}
