//! The model cores: LSTM baseline, NTM, DAM, SAM, DNC and SDNC — each a
//! recurrent cell with explicit forward/backward over an episode tape.
//!
//! Control flow (paper §3.3, Supp Fig 6): at each step the controller LSTM
//! receives [x_t, r_{t-1}] and emits head parameters p_t through a linear
//! layer; the memory is written then read; the output is a linear function
//! of [h_t, r_t].

pub mod addressing;
pub mod dam;
pub mod dnc;
pub mod lstm_core;
pub mod ntm;
pub mod sam;
pub mod sdnc;

use crate::ann::AnnKind;
use crate::memory::sharded::SHARD_PARALLEL_MIN_ROWS;
use crate::nn::linear::Linear;
use crate::nn::lstm::{Lstm, LstmState};
use crate::nn::param::{HasParams, Param};
use crate::tensor::matrix::{gemm_nt, gemm_rowsweep, gemv_many, Matrix, GEMM_ROW_TILE};
use crate::tensor::rowcodec::RowFormat;
use crate::util::metrics;
use crate::util::pool::ShardPool;
use crate::util::rng::Rng;

/// Which model to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreKind {
    Lstm,
    Ntm,
    Dam,
    Sam,
    Dnc,
    Sdnc,
}

impl std::str::FromStr for CoreKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "lstm" => Ok(CoreKind::Lstm),
            "ntm" => Ok(CoreKind::Ntm),
            "dam" => Ok(CoreKind::Dam),
            "sam" => Ok(CoreKind::Sam),
            "dnc" => Ok(CoreKind::Dnc),
            "sdnc" => Ok(CoreKind::Sdnc),
            other => Err(format!("unknown core {other:?} (lstm|ntm|dam|sam|dnc|sdnc)")),
        }
    }
}

impl CoreKind {
    pub fn all() -> [CoreKind; 6] {
        [CoreKind::Lstm, CoreKind::Ntm, CoreKind::Dam, CoreKind::Sam, CoreKind::Dnc, CoreKind::Sdnc]
    }

    /// The `Core::name()` string of cores of this kind (checkpoint headers
    /// record it, and loads match against it).
    pub fn as_str(self) -> &'static str {
        match self {
            CoreKind::Lstm => "lstm",
            CoreKind::Ntm => "ntm",
            CoreKind::Dam => "dam",
            CoreKind::Sam => "sam",
            CoreKind::Dnc => "dnc",
            CoreKind::Sdnc => "sdnc",
        }
    }
}

/// Hyper-parameters shared by every core (paper Supp C / E defaults).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    pub x_dim: usize,
    pub y_dim: usize,
    /// Controller LSTM width (paper: 100).
    pub hidden: usize,
    /// Access heads (paper: 4).
    pub heads: usize,
    /// Memory word size (paper: 32).
    pub word: usize,
    /// Memory words N.
    pub mem_words: usize,
    /// Sparse reads per head (paper: K = 4).
    pub k: usize,
    /// ANN backend for SAM/SDNC.
    pub ann: AnnKind,
    /// Usage threshold δ (paper: 0.005).
    pub delta: f32,
    /// DAM usage discount λ.
    pub lambda: f32,
    /// SDNC temporal-link row truncation K_L (paper: 8).
    pub k_l: usize,
    /// Memory shards S for the sparse engines (SAM/SDNC): rows stripe
    /// across S independent stores+ANNs and `query_many` fans out across a
    /// worker pool. 1 (the default) is exactly the unsharded engine; any S
    /// is bit-identical to S=1 for `AnnKind::Linear` (see
    /// `memory::sharded`, rust/tests/shard_parity.rs).
    pub shards: usize,
    /// Memory-row storage codec (`--row-format`). Compact formats (bf16 /
    /// int8) are serve/eval-only: training borrows rows as `&[f32]`, so the
    /// CLI rejects them for `train` (see [`RowFormat::train_legal`]).
    pub row_format: RowFormat,
    pub seed: u64,
}

impl Default for CoreConfig {
    fn default() -> Self {
        CoreConfig {
            x_dim: 8,
            y_dim: 8,
            hidden: 100,
            heads: 4,
            word: 32,
            mem_words: 128,
            k: 4,
            ann: AnnKind::Linear,
            delta: 0.005,
            lambda: 0.99,
            k_l: 8,
            shards: 1,
            row_format: RowFormat::F32,
            seed: 1,
        }
    }
}

/// A recurrent model trained with explicit BPTT:
/// `reset` → T × `forward` → T × `backward` (reverse order) → `end_episode`.
pub trait Core: HasParams + Send {
    fn name(&self) -> &'static str;

    /// Start a new episode (clears recurrent state and the tape).
    fn reset(&mut self);

    /// One step forward into a caller-reused output buffer; records what
    /// backward needs on an internal tape. This is the hot-path entry: the
    /// sparse cores perform zero heap allocations per steady-state call
    /// (rust/tests/zero_alloc.rs).
    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>);

    /// One step forward; records what backward needs on an internal tape.
    /// Allocating convenience over [`Core::forward_into`].
    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let mut y = Vec::with_capacity(self.y_dim());
        self.forward_into(x, &mut y);
        y
    }

    /// One step backward (call once per forward, in reverse order),
    /// accumulating parameter gradients.
    fn backward(&mut self, dy: &[f32]);

    /// Discard the remaining tape without computing gradients, rolling any
    /// in-place memory state back (used after eval-only episodes).
    fn rollback(&mut self);

    /// Called after the last `backward` of an episode (memory rolled back):
    /// re-synchronize auxiliary structures (ANN, usage ring).
    fn end_episode(&mut self);

    fn x_dim(&self) -> usize;
    fn y_dim(&self) -> usize;

    /// Bytes of BPTT state currently held for the episode (the Fig 1b
    /// quantity: what grows with sequence length).
    fn tape_bytes(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Shared controller plumbing
// ---------------------------------------------------------------------------

/// LSTM controller + head-parameter projection + output projection, shared
/// by all memory cores.
///
/// Hot path: the `*_hot` methods compute into persistent per-step buffers
/// (the concatenated input, raw head params, output-side gradients) so a
/// steady-state controller step allocates nothing. The allocating
/// `step`/`output`/`backward_output`/`backward_step` wrappers remain for
/// the dense baselines and tests.
pub struct Controller {
    pub lstm: Lstm,
    /// h → heads × head_dim raw parameters.
    pub head_lin: Linear,
    /// [h, r_1..r_R] → y.
    pub out_lin: Linear,
    pub heads: usize,
    pub word: usize,
    pub head_dim: usize,
    hidden: usize,
    // -- persistent per-step scratch (fixed shapes, reused every step) -----
    /// [x_t, r_{t-1}..] concatenation.
    x_in: Vec<f32>,
    /// [h_t, r_t..] concatenation.
    o_in: Vec<f32>,
    /// Raw head parameters from the last `step_hot`.
    p_buf: Vec<f32>,
    /// dL/dh from the last `backward_output_hot`.
    dh_buf: Vec<f32>,
    /// dL/dr per head from the last `backward_output_hot`.
    dreads: Vec<Vec<f32>>,
    /// d[h,r..] staging for backward_output.
    d_out_buf: Vec<f32>,
    /// dh total staging for backward_step.
    dh_total_buf: Vec<f32>,
    /// d[x,r..] staging for backward_step.
    dx_in_buf: Vec<f32>,
}

impl Controller {
    pub fn new(
        name: &str,
        x_dim: usize,
        y_dim: usize,
        hidden: usize,
        heads: usize,
        word: usize,
        head_dim: usize,
        rng: &mut Rng,
    ) -> Controller {
        Controller {
            lstm: Lstm::new(&format!("{name}.lstm"), x_dim + heads * word, hidden, rng),
            head_lin: Linear::new(&format!("{name}.heads"), hidden, heads * head_dim, rng),
            out_lin: Linear::new(&format!("{name}.out"), hidden + heads * word, y_dim, rng),
            heads,
            word,
            head_dim,
            hidden,
            x_in: Vec::new(),
            o_in: Vec::new(),
            p_buf: Vec::new(),
            dh_buf: Vec::new(),
            dreads: (0..heads).map(|_| Vec::new()).collect(),
            d_out_buf: Vec::new(),
            dh_total_buf: Vec::new(),
            dx_in_buf: Vec::new(),
        }
    }

    pub fn reset(&mut self) {
        self.lstm.reset();
        self.head_lin.clear_cache();
        self.out_lin.clear_cache();
    }

    /// Hot controller step: consume x_t and the previous reads; h_t lands
    /// in `self.lstm.h` (see [`Controller::h`]), the raw head parameters in
    /// [`Controller::head_params`]. Zero allocations in steady state.
    pub fn step_hot(&mut self, x: &[f32], r_prev: &[Vec<f32>]) {
        self.x_in.clear();
        self.x_in.extend_from_slice(x);
        for r in r_prev {
            self.x_in.extend_from_slice(r);
        }
        self.lstm.step_hot(&self.x_in);
        self.head_lin.forward_into(&self.lstm.h, &mut self.p_buf);
    }

    /// h_t after [`Controller::step_hot`].
    pub fn h(&self) -> &[f32] {
        &self.lstm.h
    }

    /// Raw head parameters after [`Controller::step_hot`].
    pub fn head_params(&self) -> &[f32] {
        &self.p_buf
    }

    /// Controller step: consume x_t and the previous reads, produce
    /// (h_t, per-head raw params). Allocating wrapper over
    /// [`Controller::step_hot`].
    pub fn step(&mut self, x: &[f32], r_prev: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
        self.step_hot(x, r_prev);
        (self.lstm.h.clone(), self.p_buf.clone())
    }

    /// Final output y_t = W_out [h_t, r_t..] into a caller-reused buffer,
    /// with h_t taken from the last [`Controller::step_hot`].
    pub fn output_hot(&mut self, reads: &[Vec<f32>], y: &mut Vec<f32>) {
        self.o_in.clear();
        self.o_in.extend_from_slice(&self.lstm.h);
        for r in reads {
            self.o_in.extend_from_slice(r);
        }
        self.out_lin.forward_into(&self.o_in, y);
    }

    /// Final output y_t = W_out [h_t, r_t..] with an explicit h.
    pub fn output(&mut self, h: &[f32], reads: &[Vec<f32>]) -> Vec<f32> {
        self.o_in.clear();
        self.o_in.extend_from_slice(h);
        for r in reads {
            self.o_in.extend_from_slice(r);
        }
        self.out_lin.forward(&self.o_in)
    }

    /// Backward of the output projection into persistent buffers: dL/dh
    /// lands in [`Controller::dh`], dL/dr per head in
    /// [`Controller::dreads`].
    pub fn backward_output_hot(&mut self, dy: &[f32]) {
        self.out_lin.backward_into(dy, &mut self.d_out_buf);
        self.dh_buf.clear();
        self.dh_buf.extend_from_slice(&self.d_out_buf[..self.hidden]);
        for hd in 0..self.heads {
            let seg =
                &self.d_out_buf[self.hidden + hd * self.word..self.hidden + (hd + 1) * self.word];
            self.dreads[hd].clear();
            self.dreads[hd].extend_from_slice(seg);
        }
    }

    /// dL/dh after [`Controller::backward_output_hot`].
    pub fn dh(&self) -> &[f32] {
        &self.dh_buf
    }

    /// dL/dr per head after [`Controller::backward_output_hot`].
    pub fn dreads(&self) -> &[Vec<f32>] {
        &self.dreads
    }

    /// Backward of `output`: returns (dh, dreads-per-head). Allocating
    /// wrapper over [`Controller::backward_output_hot`].
    pub fn backward_output(&mut self, dy: &[f32]) -> (Vec<f32>, Vec<Vec<f32>>) {
        self.backward_output_hot(dy);
        (self.dh_buf.clone(), self.dreads.clone())
    }

    /// Backward of `step` using the dh stored by
    /// [`Controller::backward_output_hot`]: `dp` is the gradient on the raw
    /// head params; d(r_prev) per head is written into `dr_out` (cleared
    /// and refilled). The input gradient is kept in `self.dx_in_buf`
    /// (no core consumes it on the hot path).
    pub fn backward_step_hot(&mut self, dp: &[f32], dr_out: &mut [Vec<f32>]) {
        debug_assert_eq!(dr_out.len(), self.heads);
        self.head_lin.backward_into(dp, &mut self.dh_total_buf);
        for (a, b) in self.dh_total_buf.iter_mut().zip(&self.dh_buf) {
            *a += b;
        }
        self.lstm.backward_into(&self.dh_total_buf, &mut self.dx_in_buf);
        let x_dim = self.dx_in_buf.len() - self.heads * self.word;
        for (hd, dr) in dr_out.iter_mut().enumerate() {
            let seg = &self.dx_in_buf[x_dim + hd * self.word..x_dim + (hd + 1) * self.word];
            dr.clear();
            dr.extend_from_slice(seg);
        }
    }

    /// Backward of `step`: `dh` is the total gradient on h_t, `dp` on the
    /// raw head params. Returns (dx, d_r_prev per head). Allocating wrapper
    /// over [`Controller::backward_step_hot`].
    pub fn backward_step(&mut self, dh: &[f32], dp: &[f32]) -> (Vec<f32>, Vec<Vec<f32>>) {
        self.dh_buf.clear();
        self.dh_buf.extend_from_slice(dh);
        let mut dr: Vec<Vec<f32>> = (0..self.heads).map(|_| Vec::new()).collect();
        self.backward_step_hot(dp, &mut dr);
        let x_dim = self.dx_in_buf.len() - self.heads * self.word;
        (self.dx_in_buf[..x_dim].to_vec(), dr)
    }

    pub fn cache_bytes(&self) -> usize {
        self.lstm.cache_bytes() + self.head_lin.cache_bytes() + self.out_lin.cache_bytes()
    }

    // -- batched-training staging hooks (see `train_tick_forward`) ----------
    //
    // These split `step_hot`/`output_hot`/`backward_output_hot`/
    // `backward_step_hot` at their GEMV seams so the batched trainer can run
    // each projection as one lane-fused kernel across B episode lanes while
    // every per-lane op keeps the exact serial float sequence.

    /// F1: write this lane's [x_t, r_{t-1}..] into `x_row` and h_{t-1} into
    /// `h_row` (the serial `step_hot` gather, landing in batch rows).
    pub fn stage_input_row(
        &self,
        x: &[f32],
        r_prev: &[Vec<f32>],
        x_row: &mut [f32],
        h_row: &mut [f32],
    ) {
        x_row[..x.len()].copy_from_slice(x);
        let mut off = x.len();
        for r in r_prev {
            x_row[off..off + r.len()].copy_from_slice(r);
            off += r.len();
        }
        debug_assert_eq!(off, self.lstm.input);
        h_row.copy_from_slice(&self.lstm.h);
    }

    /// F3: assemble z = (zx + b) + zh in `zx_row` (the serial add order of
    /// `step_with_zx`: bias onto zx, then complete recurrent dots) and run
    /// the taped cell step; h_t lands in `self.lstm.h`.
    pub fn cell_step_row(&mut self, x_row: &[f32], zx_row: &mut [f32], zh_row: &[f32]) {
        for (zv, (bv, zhv)) in zx_row.iter_mut().zip(self.lstm.b.w.data.iter().zip(zh_row)) {
            *zv = (*zv + bv) + zhv;
        }
        self.lstm.step_with_z(x_row, zx_row);
    }

    /// F5: head-projection bookkeeping for the lane-fused head GEMV — push
    /// the activation cache entry and stash the lane's raw head params.
    pub fn note_head_forward(&mut self, p_row: &[f32]) {
        self.head_lin.note_forward(&self.lstm.h);
        self.p_buf.clear();
        self.p_buf.extend_from_slice(p_row);
    }

    /// F7: write [h_t, r_t..] into `o_row` (the serial `output_hot` gather).
    pub fn stage_output_row(&self, reads: &[Vec<f32>], o_row: &mut [f32]) {
        o_row[..self.hidden].copy_from_slice(&self.lstm.h);
        let mut off = self.hidden;
        for r in reads {
            o_row[off..off + r.len()].copy_from_slice(r);
            off += r.len();
        }
    }

    /// F9: output-projection bookkeeping — push the activation cache entry.
    pub fn note_forward_out(&mut self, o_row: &[f32]) {
        self.out_lin.note_forward(o_row);
    }

    /// B3: output-projection backward bookkeeping + the `backward_output_hot`
    /// split of the swept d[h,r..] row into dh / per-head dreads.
    pub fn note_output_backward(&mut self, dy: &[f32], d_o_row: &[f32]) {
        self.out_lin.note_backward(dy);
        self.dh_buf.clear();
        self.dh_buf.extend_from_slice(&d_o_row[..self.hidden]);
        for hd in 0..self.heads {
            let seg = &d_o_row[self.hidden + hd * self.word..self.hidden + (hd + 1) * self.word];
            self.dreads[hd].clear();
            self.dreads[hd].extend_from_slice(seg);
        }
    }

    /// B6: head backward bookkeeping + dh assembly + the elementwise half of
    /// the cell backward. `dh_row` arrives as this lane's dP·W_head sweep
    /// result and gets the stored output-side dh added (the serial
    /// `backward_step_hot` order); the cell's gate gradients land in
    /// `dz_row`.
    pub fn backward_cell_z_row(&mut self, dp: &[f32], dh_row: &mut [f32], dz_row: &mut [f32]) {
        self.head_lin.note_backward(dp);
        for (a, b) in dh_row.iter_mut().zip(&self.dh_buf) {
            *a += b;
        }
        self.lstm.backward_z_into(dh_row, dz_row);
    }

    /// B8: queue the cell's weight-grad rows, carry dh_next, and split
    /// d(r_prev) per head out of the swept dZ·Wx row.
    pub fn finish_backward_row(
        &mut self,
        dz_row: &[f32],
        dh_prev_row: &[f32],
        dx_row: &[f32],
        dr_out: &mut [Vec<f32>],
    ) {
        self.lstm.backward_finish(dz_row, dh_prev_row);
        let x_dim = dx_row.len() - self.heads * self.word;
        for (hd, dr) in dr_out.iter_mut().enumerate() {
            let seg = &dx_row[x_dim + hd * self.word..x_dim + (hd + 1) * self.word];
            dr.clear();
            dr.extend_from_slice(seg);
        }
    }

    // -- forward-only inference (shared weights, detached state) ------------

    /// Fresh zeroed per-session controller state.
    pub fn new_state(&self) -> ControllerState {
        ControllerState {
            lstm: self.lstm.new_state(),
            p: Vec::new(),
            x_in: Vec::new(),
            o_in: Vec::new(),
        }
    }

    /// Forward-only controller step against shared read-only weights:
    /// h_t lands in `st.lstm.h`, the raw head parameters in `st.p`. Same
    /// float-op order as [`Controller::step_hot`] (bit-identical outputs);
    /// zero allocations once `st`'s buffers are warm.
    pub fn infer_step(&self, st: &mut ControllerState, x: &[f32], r_prev: &[Vec<f32>]) {
        st.x_in.clear();
        st.x_in.extend_from_slice(x);
        for r in r_prev {
            st.x_in.extend_from_slice(r);
        }
        self.lstm.infer_step(&mut st.lstm, &st.x_in);
        self.head_lin.infer_into(&st.lstm.h, &mut st.p);
    }

    /// Forward-only output projection y_t = W_out [h_t, r_t..].
    pub fn infer_output(&self, st: &mut ControllerState, reads: &[Vec<f32>], y: &mut Vec<f32>) {
        st.o_in.clear();
        st.o_in.extend_from_slice(&st.lstm.h);
        for r in reads {
            st.o_in.extend_from_slice(r);
        }
        self.out_lin.infer_into(&st.o_in, y);
    }

    /// Heap bytes of the controller's parameters (one Arc-shared copy in
    /// serving, regardless of session count).
    pub fn params_heap_bytes(&self) -> usize {
        self.lstm.params_heap_bytes()
            + self.head_lin.params_heap_bytes()
            + self.out_lin.params_heap_bytes()
    }

    /// Parameter scalar count through `&self` (the `HasParams` walk needs
    /// `&mut`, which an Arc-shared model cannot offer).
    pub fn params_len(&self) -> usize {
        self.lstm.wx.len()
            + self.lstm.wh.len()
            + self.lstm.b.len()
            + self.head_lin.w.len()
            + self.head_lin.b.len()
            + self.out_lin.w.len()
            + self.out_lin.b.len()
    }
}

/// Detached per-session controller state: the mutable half of the
/// parameters/state split. One trained [`Controller`] (read-only, behind an
/// `Arc`) drives any number of these concurrently.
pub struct ControllerState {
    pub lstm: LstmState,
    /// Raw head parameters after the last infer step.
    pub p: Vec<f32>,
    /// [x_t, r_{t-1}..] staging (fixed shape, reused every step).
    x_in: Vec<f32>,
    /// [h_t, r_t..] staging.
    o_in: Vec<f32>,
}

impl ControllerState {
    /// Zero the recurrent state (session episode boundary).
    pub fn reset(&mut self) {
        self.lstm.reset();
    }

    pub fn heap_bytes(&self) -> usize {
        self.lstm.heap_bytes()
            + (self.p.capacity() + self.x_in.capacity() + self.o_in.capacity()) * 4
    }
}

/// Reusable gather/scatter scratch for the batched serving tick. One per
/// `SessionManager`; capacities converge to the largest tick seen.
pub struct CtrlBatch {
    x_in: Matrix,
    h: Matrix,
    z: Matrix,
    zh: Matrix,
    p: Matrix,
    o_in: Matrix,
    y: Matrix,
}

impl Default for CtrlBatch {
    fn default() -> Self {
        CtrlBatch::new()
    }
}

impl CtrlBatch {
    pub fn new() -> CtrlBatch {
        CtrlBatch {
            x_in: Matrix::zeros(0, 0),
            h: Matrix::zeros(0, 0),
            z: Matrix::zeros(0, 0),
            zh: Matrix::zeros(0, 0),
            p: Matrix::zeros(0, 0),
            o_in: Matrix::zeros(0, 0),
            y: Matrix::zeros(0, 0),
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.x_in.heap_bytes()
            + self.h.heap_bytes()
            + self.z.heap_bytes()
            + self.zh.heap_bytes()
            + self.p.heap_bytes()
            + self.o_in.heap_bytes()
            + self.y.heap_bytes()
    }
}

/// Resize a scratch matrix in place (capacity retained, contents zeroed).
fn fit(m: &mut Matrix, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.clear();
    m.data.resize(rows * cols, 0.0);
}

/// One batched serving tick over B same-model sessions: the controller's
/// four projections — input gates `X Wxᵀ`, recurrent gates `H Whᵀ`, head
/// parameters `H' W_headᵀ` and output `[H', R] W_outᵀ` — each run as ONE
/// GEMM across all sessions. Row counts are padded to [`GEMM_ROW_TILE`] so
/// a session's bits never depend on how many other sessions shared its
/// tick (pinned by `gemm_nt_rows_are_batch_size_independent_when_tile_padded`).
/// The memory phase between head params and output is inherently
/// per-session (sparse reads/writes on private state) and runs through the
/// `mem_phase` callback, which consumes `ControllerState::p` and refreshes
/// the session's read vectors.
///
/// Numerics note: the coalesced GEMMs reorder float additions relative to
/// the per-session `gemv` path, so batched outputs match single-step
/// outputs to kernel-reassociation tolerance (~1e-6 relative), not
/// bitwise — the same caveat class as DESIGN.md's blocked-kernel note.
/// Batched outputs ARE bitwise deterministic for a given session stream.
pub fn infer_tick<S, M>(
    ctrl: &Controller,
    batch: &mut CtrlBatch,
    sessions: &mut [&mut S],
    xs: &[&[f32]],
    ys: &mut [Vec<f32>],
    ctrl_state: fn(&mut S) -> &mut ControllerState,
    reads: fn(&S) -> &[Vec<f32>],
    mut mem_phase: M,
) where
    M: FnMut(&mut S),
{
    let b = sessions.len();
    assert_eq!(xs.len(), b);
    assert_eq!(ys.len(), b);
    if b == 0 {
        return;
    }
    let bp = b.div_ceil(GEMM_ROW_TILE) * GEMM_ROW_TILE;
    let in_dim = ctrl.lstm.input;
    let hidden = ctrl.hidden;

    // 1. Gather [x, r_prev..] rows and H_prev (pad rows stay zero).
    fit(&mut batch.x_in, bp, in_dim);
    fit(&mut batch.h, bp, hidden);
    for (i, s) in sessions.iter_mut().enumerate() {
        let x = xs[i];
        {
            let row = batch.x_in.row_mut(i);
            row[..x.len()].copy_from_slice(x);
            let mut off = x.len();
            for r in reads(&**s) {
                row[off..off + r.len()].copy_from_slice(r);
                off += r.len();
            }
            debug_assert_eq!(off, in_dim);
        }
        batch.h.row_mut(i).copy_from_slice(&ctrl_state(&mut **s).lstm.h);
    }

    // 2. Gate pre-activations: Zx = X Wxᵀ and Zh = H Whᵀ, one GEMM each.
    fit(&mut batch.z, bp, 4 * hidden);
    gemm_nt(&mut batch.z, &batch.x_in, &ctrl.lstm.wx.w);
    fit(&mut batch.zh, bp, 4 * hidden);
    gemm_nt(&mut batch.zh, &batch.h, &ctrl.lstm.wh.w);

    // 3. Per-session nonlinearity; the updated h's re-fill batch.h.
    for (i, s) in sessions.iter_mut().enumerate() {
        {
            let zrow = batch.z.row_mut(i);
            for (zv, (bv, zhv)) in zrow
                .iter_mut()
                .zip(ctrl.lstm.b.w.data.iter().zip(batch.zh.row(i)))
            {
                // Same add order as the single-step path: (zx + b) + zh.
                *zv = (*zv + bv) + zhv;
            }
        }
        let st = ctrl_state(&mut **s);
        ctrl.lstm.infer_step_with_z(&mut st.lstm, batch.z.row(i));
        batch.h.row_mut(i).copy_from_slice(&st.lstm.h);
    }

    // 4. Head parameters: P = H' W_headᵀ + b, one GEMM; scatter, then the
    //    per-session memory phase.
    fit(&mut batch.p, bp, ctrl.head_lin.out_dim());
    ctrl.head_lin.infer_batch(&batch.h, &mut batch.p);
    for (i, s) in sessions.iter_mut().enumerate() {
        {
            let st = ctrl_state(&mut **s);
            st.p.clear();
            st.p.extend_from_slice(batch.p.row(i));
        }
        mem_phase(&mut **s);
    }

    // 5. Output: Y = [H', R] W_outᵀ + b, one GEMM; scatter into ys.
    fit(&mut batch.o_in, bp, ctrl.out_lin.in_dim());
    for (i, s) in sessions.iter_mut().enumerate() {
        let row = batch.o_in.row_mut(i);
        row[..hidden].copy_from_slice(&ctrl_state(&mut **s).lstm.h);
        let mut off = hidden;
        for r in reads(&**s) {
            row[off..off + r.len()].copy_from_slice(r);
            off += r.len();
        }
    }
    fit(&mut batch.y, bp, ctrl.out_lin.out_dim());
    ctrl.out_lin.infer_batch(&batch.o_in, &mut batch.y);
    for (i, y) in ys.iter_mut().enumerate() {
        y.clear();
        y.extend_from_slice(batch.y.row(i));
    }
}

// ---------------------------------------------------------------------------
// Batched-episode training (the threads × batch path)
// ---------------------------------------------------------------------------

/// Borrowed lane-0 weight views for the batched training ticks. Every lane
/// holds identical parameter values (the trainer re-broadcasts after each
/// optimizer step), so the fused kernels stream lane 0's weights across all
/// lanes' rows.
pub struct LaneWeights<'a> {
    /// Cell input weights (4H × in_dim).
    pub wx: &'a Matrix,
    /// Cell recurrent weights (4H × H).
    pub wh: &'a Matrix,
    /// Head projection (weights, bias) — `None` for the dense LSTM witness,
    /// which has no head projection and no memory phase.
    pub head: Option<(&'a Matrix, &'a [f32])>,
    /// Output projection (weights, bias).
    pub out: (&'a Matrix, &'a [f32]),
}

/// The seams a core exposes so the batched trainer can drive B independent
/// episode lanes of it in lockstep (see [`train_tick_forward`] /
/// [`train_tick_backward`]). Each lane is a full core instance — private
/// memory, journal, tape — and only the controller's dense projections fuse
/// across lanes. Every per-lane method replays the exact float-op sequence
/// of the serial [`Core`] path, which is what makes batched training
/// bit-identical to serial (rust/tests/batch_parity.rs).
pub trait BatchCore: Core {
    /// Cell input width ([x, r_prev..]).
    fn cell_in_dim(&self) -> usize;
    /// Controller LSTM width.
    fn cell_hidden(&self) -> usize;
    /// Raw head-parameter width (0 for the dense LSTM witness).
    fn head_param_dim(&self) -> usize;
    /// Output-projection input width ([h, r..]).
    fn out_in_dim(&self) -> usize;
    /// Weight views for the fused kernels.
    fn weights(&self) -> LaneWeights<'_>;
    /// F1: write this lane's [x_t, r_{t-1}..] into `x_row` and h_{t-1} into
    /// `h_row`.
    fn stage_input(&self, x: &[f32], x_row: &mut [f32], h_row: &mut [f32]);
    /// F3: assemble z = (zx + b) + zh in `zx_row` (serial add order) and run
    /// the taped cell step; h_t lands in the cell.
    fn cell_step(&mut self, x_row: &[f32], zx_row: &mut [f32], zh_row: &[f32]);
    /// h_t after [`BatchCore::cell_step`].
    fn h(&self) -> &[f32];
    /// F5: head-projection bookkeeping — consume this lane's raw head
    /// params from the fused head GEMV.
    fn note_head_forward(&mut self, _p_row: &[f32]) {}
    /// F6a: memory writes/links + content-query staging — everything up to
    /// the ANN lookup. No-op for memoryless cores.
    fn mem_stage(&mut self) {}
    /// F6b: run the ANN fill staged by [`BatchCore::mem_stage`] (no-op when
    /// nothing is staged). `nested` means the call is already on a
    /// `ShardPool` worker, so the body must stay strictly serial.
    fn ann_fill(&mut self, _nested: bool) {}
    /// Memory rows the staged fill will scan (the merged-dispatch
    /// heuristic); 0 when nothing is staged.
    fn ann_fill_rows(&self) -> usize {
        0
    }
    /// F6c: finish the content reads from the filled neighbour lists
    /// (updates r_t). No-op for memoryless cores.
    fn mem_finish(&mut self) {}
    /// F7: write [h_t, r_t..] into `o_row`.
    fn stage_output(&self, o_row: &mut [f32]);
    /// F9: output-projection bookkeeping — push `o_row` on the activation
    /// cache.
    fn note_forward_out(&mut self, o_row: &[f32]);
    /// B3: output-projection backward bookkeeping + split the swept
    /// `d_o_row` into dh / per-head dreads.
    fn note_output_backward(&mut self, dy: &[f32], d_o_row: &[f32]);
    /// B4: memory backward between the output and cell backwards (consumes
    /// dreads, fills the lane's dp). No-op for memoryless cores.
    fn backward_mem(&mut self) {}
    /// The lane's head-parameter gradient after [`BatchCore::backward_mem`].
    fn dp(&self) -> &[f32] {
        &[]
    }
    /// B6: head backward bookkeeping + dh assembly + the elementwise cell
    /// backward; writes this lane's dZ row. `dh_row` arrives as the lane's
    /// dP·W_head sweep result (or the raw output-side dh when there is no
    /// head projection).
    fn backward_cell_z(&mut self, dh_row: &mut [f32], dz_row: &mut [f32]);
    /// B8: queue the cell's weight-grad rows (`dz_row`), carry dh_next
    /// (`dh_prev_row`), split d(r_prev) from the swept `dx_row`.
    fn finish_backward(&mut self, dz_row: &[f32], dh_prev_row: &[f32], dx_row: &[f32]);
}

/// Reusable gather/scatter scratch for the batched *training* ticks, the
/// training analogue of [`CtrlBatch`]. One per worker lane-group; capacities
/// converge after the first step (the steady-state tick allocates nothing —
/// rust/tests/zero_alloc.rs).
pub struct TrainBatch {
    x_in: Matrix,
    h: Matrix,
    z: Matrix,
    zh: Matrix,
    p: Matrix,
    o_in: Matrix,
    y: Matrix,
    dy: Matrix,
    d_o: Matrix,
    dp: Matrix,
    dh_tot: Matrix,
    dz: Matrix,
    dx_in: Matrix,
    dh_prev: Matrix,
    /// Zero-sized companion slice for the merged-ANN `ShardPool::run2`
    /// dispatch (a `Vec<()>` never allocates).
    fill_dummy: Vec<()>,
}

impl Default for TrainBatch {
    fn default() -> Self {
        TrainBatch::new()
    }
}

impl TrainBatch {
    pub fn new() -> TrainBatch {
        TrainBatch {
            x_in: Matrix::zeros(0, 0),
            h: Matrix::zeros(0, 0),
            z: Matrix::zeros(0, 0),
            zh: Matrix::zeros(0, 0),
            p: Matrix::zeros(0, 0),
            o_in: Matrix::zeros(0, 0),
            y: Matrix::zeros(0, 0),
            dy: Matrix::zeros(0, 0),
            d_o: Matrix::zeros(0, 0),
            dp: Matrix::zeros(0, 0),
            dh_tot: Matrix::zeros(0, 0),
            dz: Matrix::zeros(0, 0),
            dx_in: Matrix::zeros(0, 0),
            dh_prev: Matrix::zeros(0, 0),
            fill_dummy: Vec::new(),
        }
    }

    /// Lane `lane`'s output row after [`train_tick_forward`].
    pub fn y_row(&self, lane: usize) -> &[f32] {
        self.y.row(lane)
    }

    /// Size + zero the dY staging ahead of a backward tick.
    pub fn stage_dy(&mut self, lanes: usize, y_dim: usize) {
        fit(&mut self.dy, lanes, y_dim);
    }

    /// Lane `lane`'s dY row — write the loss gradient here after
    /// [`TrainBatch::stage_dy`]; idle lanes stay zero.
    pub fn dy_row_mut(&mut self, lane: usize) -> &mut [f32] {
        self.dy.row_mut(lane)
    }

    pub fn heap_bytes(&self) -> usize {
        self.x_in.heap_bytes()
            + self.h.heap_bytes()
            + self.z.heap_bytes()
            + self.zh.heap_bytes()
            + self.p.heap_bytes()
            + self.o_in.heap_bytes()
            + self.y.heap_bytes()
            + self.dy.heap_bytes()
            + self.d_o.heap_bytes()
            + self.dp.heap_bytes()
            + self.dh_tot.heap_bytes()
            + self.dz.heap_bytes()
            + self.dx_in.heap_bytes()
            + self.dh_prev.heap_bytes()
    }
}

/// One batched *training* tick over B lanes (independent episodes) of the
/// same core kind: each controller projection — input gates, recurrent
/// gates, head parameters, output — runs as ONE lane-fused kernel
/// ([`gemv_many`]) across all lanes, with the per-lane nonlinearity / tape /
/// memory phases in between. The ANN lookups of all lanes are merged into a
/// single `ShardPool` dispatch when the combined scan is large enough.
///
/// Unlike the serving tick ([`infer_tick`]: micro-kernel GEMMs, tolerance
/// contract), the training tick uses the order-preserving lane-fused
/// kernels, so every lane's episode is bit-identical to running it through
/// the serial [`Core::forward_into`] / [`Core::backward`] path at any B and
/// any worker count — the contract pinned by rust/tests/batch_parity.rs and
/// documented in DESIGN.md "Batched training".
///
/// `xs[l] = None` marks a lane idle this step (episodes in a batch may have
/// different lengths): its rows stay zero, every per-lane phase skips it,
/// and the fused kernels' arithmetic on its zero rows is never observed.
/// Lane outputs land in [`TrainBatch::y_row`].
pub fn train_tick_forward<C: BatchCore>(
    lanes: &mut [C],
    batch: &mut TrainBatch,
    xs: &[Option<&[f32]>],
) {
    let l = lanes.len();
    assert!(l > 0, "train_tick_forward needs at least one lane");
    assert_eq!(xs.len(), l);
    let in_dim = lanes[0].cell_in_dim();
    let hidden = lanes[0].cell_hidden();
    let p_dim = lanes[0].head_param_dim();
    let o_dim = lanes[0].out_in_dim();
    let y_dim = lanes[0].y_dim();
    metrics::TRAIN_TICKS.inc();
    // Phase boundaries follow the F1..F9 comments; sections a comment
    // merges (F5+F6a, F6b+F6c) observe into the first phase's histogram
    // per sub-section, so every µs of the tick lands in exactly one phase.
    let mut clock = metrics::PhaseClock::start();

    // F1: gather [x, r_prev..] and h_{t-1} rows.
    fit(&mut batch.x_in, l, in_dim);
    fit(&mut batch.h, l, hidden);
    for (i, lane) in lanes.iter_mut().enumerate() {
        if let Some(x) = xs[i] {
            lane.stage_input(x, batch.x_in.row_mut(i), batch.h.row_mut(i));
        }
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[0]);

    // F2: gate pre-activations, lane-fused: Zx = lanes·Wxᵀ, Zh = lanes·Whᵀ.
    fit(&mut batch.z, l, 4 * hidden);
    fit(&mut batch.zh, l, 4 * hidden);
    {
        let w = lanes[0].weights();
        gemv_many(&mut batch.z, w.wx, &batch.x_in);
        gemv_many(&mut batch.zh, w.wh, &batch.h);
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[1]);

    // F3: per-lane z assembly + gate nonlinearity + tape push; the updated
    // h's re-fill batch.h for the head projection.
    for (i, lane) in lanes.iter_mut().enumerate() {
        if xs[i].is_none() {
            continue;
        }
        lane.cell_step(batch.x_in.row(i), batch.z.row_mut(i), batch.zh.row(i));
        batch.h.row_mut(i).copy_from_slice(lane.h());
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[2]);

    // F4–F6: head parameters + the memory phase (skipped wholesale by the
    // dense witness, which has neither).
    fit(&mut batch.p, l, p_dim);
    if p_dim > 0 {
        {
            let w = lanes[0].weights();
            let (hw, hb) = w.head.expect("head_param_dim > 0 without head weights");
            for i in 0..l {
                if xs[i].is_some() {
                    batch.p.row_mut(i).copy_from_slice(hb);
                }
            }
            // F4: P = bias + H'·W_headᵀ, lane-fused.
            gemv_many(&mut batch.p, hw, &batch.h);
        }
        clock.lap(&metrics::TRAIN_FWD_PHASE_US[3]);
        // F5 + F6a: per-lane head bookkeeping, then memory writes/links and
        // content-query staging (timed as F5; the remaining F6 sub-phases
        // observe into f6 below).
        for (i, lane) in lanes.iter_mut().enumerate() {
            if xs[i].is_none() {
                continue;
            }
            lane.note_head_forward(batch.p.row(i));
            lane.mem_stage();
        }
        clock.lap(&metrics::TRAIN_FWD_PHASE_US[4]);
        // F6b: the merged ANN fill — one pool dispatch across all lanes'
        // staged queries when the combined scan is worth fanning out;
        // otherwise each lane fills through its engine's own path (which
        // still shard-parallelizes a single big memory). Fills write
        // disjoint per-engine neighbour lists, so dispatch shape never
        // affects bits.
        let active = xs.iter().filter(|x| x.is_some()).count();
        let rows: usize = lanes.iter().map(|c| c.ann_fill_rows()).sum();
        if active > 1 && rows >= SHARD_PARALLEL_MIN_ROWS {
            batch.fill_dummy.resize(l, ());
            ShardPool::global().run2(lanes, &mut batch.fill_dummy, &(), |_i, lane, _d, _ctx| {
                lane.ann_fill(true);
            });
        } else {
            for lane in lanes.iter_mut() {
                lane.ann_fill(false);
            }
        }
        // F6c: finish the reads.
        for (i, lane) in lanes.iter_mut().enumerate() {
            if xs[i].is_none() {
                continue;
            }
            lane.mem_finish();
        }
        clock.lap(&metrics::TRAIN_FWD_PHASE_US[5]);
    }

    // F7: gather [h_t, r_t..] rows + output bias rows.
    fit(&mut batch.o_in, l, o_dim);
    fit(&mut batch.y, l, y_dim);
    for (i, lane) in lanes.iter_mut().enumerate() {
        if xs[i].is_none() {
            continue;
        }
        lane.stage_output(batch.o_in.row_mut(i));
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[6]);
    {
        let w = lanes[0].weights();
        let (ow, ob) = w.out;
        for i in 0..l {
            if xs[i].is_some() {
                batch.y.row_mut(i).copy_from_slice(ob);
            }
        }
        // F8: Y = bias + O·W_outᵀ, lane-fused.
        gemv_many(&mut batch.y, ow, &batch.o_in);
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[7]);
    // F9: per-lane output bookkeeping.
    for (i, lane) in lanes.iter_mut().enumerate() {
        if xs[i].is_none() {
            continue;
        }
        lane.note_forward_out(batch.o_in.row(i));
    }
    clock.lap(&metrics::TRAIN_FWD_PHASE_US[8]);
}

/// The backward half of the batched training tick: call once per forward
/// tick, in reverse step order, with the loss gradients staged via
/// [`TrainBatch::stage_dy`] / [`TrainBatch::dy_row_mut`] (idle lanes' rows
/// left zero and `active[l] = false`). The three weight sweeps — dY·W_out,
/// dP·W_head, dZ·{Wx,Wh} — each run as one lane-fused [`gemm_rowsweep`];
/// zero rows are skipped wholesale by its `!= 0.0` guard, so idle lanes
/// cost nothing.
pub fn train_tick_backward<C: BatchCore>(
    lanes: &mut [C],
    batch: &mut TrainBatch,
    active: &[bool],
) {
    let l = lanes.len();
    assert!(l > 0, "train_tick_backward needs at least one lane");
    assert_eq!(active.len(), l);
    assert_eq!(batch.dy.rows, l, "stage_dy must size dY before the backward tick");
    let in_dim = lanes[0].cell_in_dim();
    let hidden = lanes[0].cell_hidden();
    let p_dim = lanes[0].head_param_dim();
    let o_dim = lanes[0].out_in_dim();
    let mut clock = metrics::PhaseClock::start();

    // B2: d[h,r..] = dY·W_out, lane-fused.
    fit(&mut batch.d_o, l, o_dim);
    {
        let w = lanes[0].weights();
        gemm_rowsweep(&mut batch.d_o, &batch.dy, w.out.0);
    }
    clock.lap(&metrics::TRAIN_BWD_PHASE_US[0]);
    // B3 + B4: per-lane output bookkeeping (split dh/dreads) + memory
    // backward (fills the lane's dp). One fused loop; the two phases are
    // timed per lane so the memory backward (B4, usually the dominant
    // cost) stays separable from the bookkeeping (B3).
    for (i, lane) in lanes.iter_mut().enumerate() {
        if !active[i] {
            continue;
        }
        let mut lane_clock = metrics::PhaseClock::start();
        lane.note_output_backward(batch.dy.row(i), batch.d_o.row(i));
        lane_clock.lap(&metrics::TRAIN_BWD_PHASE_US[1]);
        lane.backward_mem();
        lane_clock.lap(&metrics::TRAIN_BWD_PHASE_US[2]);
    }
    clock = metrics::PhaseClock::start();
    // B5: dH = dP·W_head, lane-fused, when the core has a head projection;
    // the dense witness feeds d_o straight to the cell.
    fit(&mut batch.dz, l, 4 * hidden);
    if p_dim > 0 {
        fit(&mut batch.dp, l, p_dim);
        for (i, lane) in lanes.iter_mut().enumerate() {
            if active[i] {
                batch.dp.row_mut(i).copy_from_slice(lane.dp());
            }
        }
        fit(&mut batch.dh_tot, l, hidden);
        {
            let w = lanes[0].weights();
            let (hw, _) = w.head.expect("head_param_dim > 0 without head weights");
            gemm_rowsweep(&mut batch.dh_tot, &batch.dp, hw);
        }
    }
    clock.lap(&metrics::TRAIN_BWD_PHASE_US[3]);
    // B6: per-lane dh assembly + elementwise cell backward → dZ rows.
    for (i, lane) in lanes.iter_mut().enumerate() {
        if !active[i] {
            continue;
        }
        let dh_row =
            if p_dim > 0 { batch.dh_tot.row_mut(i) } else { batch.d_o.row_mut(i) };
        lane.backward_cell_z(dh_row, batch.dz.row_mut(i));
    }
    clock.lap(&metrics::TRAIN_BWD_PHASE_US[4]);
    // B7: input/recurrent sweeps, lane-fused: dX_in = dZ·Wx, dH_prev = dZ·Wh.
    fit(&mut batch.dx_in, l, in_dim);
    fit(&mut batch.dh_prev, l, hidden);
    {
        let w = lanes[0].weights();
        gemm_rowsweep(&mut batch.dx_in, &batch.dz, w.wx);
        gemm_rowsweep(&mut batch.dh_prev, &batch.dz, w.wh);
    }
    clock.lap(&metrics::TRAIN_BWD_PHASE_US[5]);
    // B8: per-lane finish — queue the cell's weight-grad rows, carry
    // dh_next, split d(r_prev).
    for (i, lane) in lanes.iter_mut().enumerate() {
        if !active[i] {
            continue;
        }
        lane.finish_backward(batch.dz.row(i), batch.dh_prev.row(i), batch.dx_in.row(i));
    }
    clock.lap(&metrics::TRAIN_BWD_PHASE_US[6]);
}

impl HasParams for Controller {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.lstm.visit_params(f);
        self.head_lin.visit_params(f);
        self.out_lin.visit_params(f);
    }
}

/// Build a core of the requested kind.
pub fn build_core(kind: CoreKind, cfg: &CoreConfig, rng: &mut Rng) -> Box<dyn Core> {
    match kind {
        CoreKind::Lstm => Box::new(lstm_core::LstmCore::new(cfg, rng)),
        CoreKind::Ntm => Box::new(ntm::NtmCore::new(cfg, rng)),
        CoreKind::Dam => Box::new(dam::DamCore::new(cfg, rng)),
        CoreKind::Sam => Box::new(sam::SamCore::new(cfg, rng)),
        CoreKind::Dnc => Box::new(dnc::DncCore::new(cfg, rng)),
        CoreKind::Sdnc => Box::new(sdnc::SdncCore::new(cfg, rng)),
    }
}

pub mod grad_check {
    //! Shared finite-difference gradient checker for cores, used by the
    //! per-core unit tests and the `rust/tests/grad_check.rs` integration
    //! suite (hence not `#[cfg(test)]`). Discrete structure (top-K
    //! selection, LRA argmin) can flip under perturbation, so the checker
    //! requires a high fraction of sampled coordinates to agree rather
    //! than all of them.

    use super::*;
    use crate::nn::loss::sigmoid_xent;

    /// Episode loss: Σ_t sigmoid-xent(y_t, targets_t).
    pub fn episode_loss(core: &mut dyn Core, xs: &[Vec<f32>], ts: &[Vec<f32>]) -> f32 {
        core.reset();
        let mut loss = 0.0;
        for (x, t) in xs.iter().zip(ts) {
            let y = core.forward(x);
            loss += sigmoid_xent(&y, t).0;
        }
        core.rollback();
        core.end_episode();
        loss
    }

    /// Run fwd+bwd, then FD-check `samples_per_param` coords of every param.
    /// Returns (checked, failed) counts.
    pub fn check_core_gradients(
        core: &mut dyn Core,
        xs: &[Vec<f32>],
        ts: &[Vec<f32>],
        rng: &mut Rng,
        samples_per_param: usize,
        eps: f32,
        tol_rel: f32,
    ) -> (usize, usize) {
        // Analytic gradients.
        core.zero_grads();
        core.reset();
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(ts) {
            let y = core.forward(x);
            dys.push(sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();

        // Collect (param index, coord, analytic grad) samples.
        let mut samples: Vec<(usize, usize, f32)> = Vec::new();
        {
            let mut pi = 0;
            core.visit_params(&mut |p| {
                for _ in 0..samples_per_param.min(p.len()) {
                    let k = rng.below(p.len());
                    samples.push((pi, k, p.g.data[k]));
                }
                pi += 1;
            });
        }

        let mut failed = 0;
        for &(pi, k, an) in &samples {
            let mut orig = 0.0;
            let mut idx = 0;
            core.visit_params(&mut |p| {
                if idx == pi {
                    orig = p.w.data[k];
                    p.w.data[k] = orig + eps;
                }
                idx += 1;
            });
            let lp = episode_loss(core, xs, ts);
            idx = 0;
            core.visit_params(&mut |p| {
                if idx == pi {
                    p.w.data[k] = orig - eps;
                }
                idx += 1;
            });
            let lm = episode_loss(core, xs, ts);
            idx = 0;
            core.visit_params(&mut |p| {
                if idx == pi {
                    p.w.data[k] = orig;
                }
                idx += 1;
            });
            let fd = (lp - lm) / (2.0 * eps);
            let denom = fd.abs().max(an.abs()).max(0.05);
            if (fd - an).abs() / denom > tol_rel {
                failed += 1;
            }
        }
        (samples.len(), failed)
    }

    /// Deterministic random episode for gradient tests.
    pub fn random_episode(
        x_dim: usize,
        y_dim: usize,
        t_len: usize,
        rng: &mut Rng,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let xs = (0..t_len)
            .map(|_| (0..x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
            .collect();
        let ts = (0..t_len)
            .map(|_| (0..y_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
            .collect();
        (xs, ts)
    }
}
