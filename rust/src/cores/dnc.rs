//! Differentiable Neural Computer (Graves et al. 2016) — dense temporal
//! linkage baseline for the SDNC (Supp D).
//!
//! Reads mix three modes per head (3-way softmax): content lookup,
//! following the temporal link matrix forward (f = L·w^r_{t-1}) and
//! backward (b = Lᵀ·w^r_{t-1}). The linkage L ∈ [0,1]^{N×N} and precedence
//! p are updated densely per step (eq. 11/13) — the O(N²) time and O(N²·T)
//! BPTT-space costs that Fig 7 measures against the SDNC.
//!
//! Writes use the same usage-interpolation scheme as DAM (the paper's SDNC
//! "used the same usage tracking as in SAM"; our dense DNC mirrors that
//! with the dense U⁽¹⁾ tracker). As in the paper's SDNC, gradients are not
//! passed through the linkage construction (Supp D.1), but do flow through
//! the read mixture into w^r_{t-1}, queries and memory.

use super::addressing::{content_weights, content_weights_backward, ContentRead};
use super::{Controller, ControllerState, Core, CoreConfig};
use crate::memory::store::MemoryStore;
use crate::memory::usage::DiscountedUsage;
use crate::nn::act::{dsigmoid, sigmoid};
use crate::nn::param::{HasParams, Param};
use crate::tensor::matrix::{dot, softmax_backward, softmax_inplace, Matrix};
use crate::util::rng::Rng;

/// Head params: [q(W), a(W), α̂, γ̂, β̂, mode(3)] — modes (backward, content, forward).
const fn head_dim(word: usize) -> usize {
    2 * word + 6
}

struct HeadStep {
    // write
    w_write: Vec<f32>,
    alpha: f32,
    gamma: f32,
    lra_row: usize,
    write_word: Vec<f32>,
    // read
    read: ContentRead,
    query: Vec<f32>,
    modes: Vec<f32>, // softmaxed (3)
    fwd: Vec<f32>,
    bwd: Vec<f32>,
    w_read: Vec<f32>,
    w_read_used: Vec<f32>,
}

struct DncStep {
    mem_before: Vec<f32>,
    /// L_t snapshot — needed to route read gradients; O(N²) per step.
    link: Matrix,
    heads: Vec<HeadStep>,
}

pub struct DncCore {
    cfg: CoreConfig,
    ctrl: Controller,
    mem: MemoryStore,
    usage: DiscountedUsage,
    link: Matrix,
    precedence: Vec<f32>,
    w_read_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<DncStep>,
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<Vec<f32>>,
    dmem: Matrix,
}

impl DncCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> DncCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "dnc",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        let n = cfg.mem_words;
        DncCore {
            ctrl,
            mem: MemoryStore::zeros(n, cfg.word),
            usage: DiscountedUsage::new(n, cfg.lambda),
            link: Matrix::zeros(n, n),
            precedence: vec![0.0; n],
            w_read_prev: vec![vec![0.0; n]; cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![vec![0.0; n]; cfg.heads],
            dmem: Matrix::zeros(n, cfg.word),
            cfg: cfg.clone(),
        }
    }

    /// Open a detached inference session (zero memory/linkage — same as a
    /// freshly reset training core).
    pub fn infer_session(&self, _seed: Option<u64>) -> DncSession {
        let n = self.cfg.mem_words;
        DncSession {
            ctrl: self.ctrl.new_state(),
            mem: MemoryStore::zeros(n, self.cfg.word),
            usage: DiscountedUsage::new(n, self.cfg.lambda),
            link: Matrix::zeros(n, n),
            precedence: vec![0.0; n],
            w_read_prev: vec![vec![0.0; n]; self.cfg.heads],
            r_prev: vec![vec![0.0; self.cfg.word]; self.cfg.heads],
        }
    }

    /// One forward-only step: bit-identical to [`Core::forward_into`] on a
    /// freshly reset core, minus the O(N·W) memory snapshot and O(N²) link
    /// snapshot of the training tape. (Dense baseline: allocating.)
    pub fn infer_step(&self, st: &mut DncSession, x: &[f32], y: &mut Vec<f32>) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.ctrl.infer_step(&mut st.ctrl, x, &st.r_prev);
        st.usage.u.iter_mut().for_each(|u| *u *= st.usage.lambda);

        // --- writes (DAM-style dense interpolation, eq. 5) ---
        let mut w_agg = vec![0.0f32; n];
        for hi in 0..self.cfg.heads {
            let (alpha, gamma) = (
                sigmoid(st.ctrl.p[hi * hd + 2 * w]),
                sigmoid(st.ctrl.p[hi * hd + 2 * w + 1]),
            );
            let lra_row = st.usage.argmin();
            let mut w_write = vec![0.0f32; n];
            for i in 0..n {
                w_write[i] = alpha * gamma * st.w_read_prev[hi][i];
            }
            w_write[lra_row] += alpha * (1.0 - gamma);
            st.mem.row_mut(lra_row).iter_mut().for_each(|v| *v = 0.0);
            let a = &st.ctrl.p[hi * hd + w..hi * hd + 2 * w];
            for i in 0..n {
                let wv = w_write[i];
                if wv != 0.0 {
                    let row = st.mem.row_mut(i);
                    for (m, &av) in row.iter_mut().zip(a) {
                        *m += wv * av;
                    }
                }
            }
            for i in 0..n {
                st.usage.u[i] += w_write[i];
                w_agg[i] += w_write[i];
            }
        }

        // --- temporal linkage update (eq. 11, 13): dense O(N²) ---
        let s: f32 = w_agg.iter().sum();
        if s > 1.0 {
            w_agg.iter_mut().for_each(|x| *x /= s);
        }
        let p_prev = st.precedence.clone();
        for i in 0..n {
            let wi = w_agg[i];
            let lrow = st.link.row_mut(i);
            for j in 0..n {
                if i == j {
                    lrow[j] = 0.0;
                } else {
                    lrow[j] = (1.0 - wi - w_agg[j]) * lrow[j] + wi * p_prev[j];
                }
            }
        }
        let sum_w: f32 = w_agg.iter().sum();
        for i in 0..n {
            st.precedence[i] = (1.0 - sum_w) * p_prev[i] + w_agg[i];
        }

        // --- reads: 3-way mode mix over content / forward / backward ---
        for hi in 0..self.cfg.heads {
            let ph_lo = hi * hd;
            let beta_raw = st.ctrl.p[ph_lo + 2 * w + 2];
            let mut modes = st.ctrl.p[ph_lo + 2 * w + 3..ph_lo + 2 * w + 6].to_vec();
            softmax_inplace(&mut modes);
            let read = content_weights(
                &st.ctrl.p[ph_lo..ph_lo + w],
                beta_raw,
                &st.mem,
                (0..n).collect(),
            );
            let wp = &st.w_read_prev[hi];
            let mut fwd = vec![0.0f32; n];
            let mut bwd = vec![0.0f32; n];
            for i in 0..n {
                fwd[i] = dot(st.link.row(i), wp);
            }
            for j in 0..n {
                let lrow = st.link.row(j);
                let wj = wp[j];
                if wj != 0.0 {
                    for i in 0..n {
                        bwd[i] += lrow[i] * wj;
                    }
                }
            }
            let mut w_read = vec![0.0f32; n];
            for i in 0..n {
                w_read[i] = modes[0] * bwd[i] + modes[1] * read.weights[i] + modes[2] * fwd[i];
            }
            let mut r = vec![0.0; w];
            st.mem.read_dense(&w_read, &mut r);
            for i in 0..n {
                st.usage.u[i] += w_read[i];
            }
            st.w_read_prev[hi] = w_read;
            st.r_prev[hi] = r;
        }

        self.ctrl.infer_output(&mut st.ctrl, &st.r_prev, y);
    }

    pub fn params_heap_bytes(&self) -> usize {
        self.ctrl.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.ctrl.params_len()
    }
}

/// Detached per-session state for DNC serving (dense link matrix included —
/// O(N²) per session, which is exactly why the SDNC is the serving core).
pub struct DncSession {
    ctrl: ControllerState,
    mem: MemoryStore,
    usage: DiscountedUsage,
    link: Matrix,
    precedence: Vec<f32>,
    w_read_prev: Vec<Vec<f32>>,
    r_prev: Vec<Vec<f32>>,
}

impl DncSession {
    pub fn reset(&mut self) {
        self.ctrl.reset();
        self.mem.fill(0.0);
        self.usage.reset();
        self.link.fill(0.0);
        self.precedence.iter_mut().for_each(|x| *x = 0.0);
        for v in &mut self.w_read_prev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.mem.heap_bytes()
            + self.ctrl.heap_bytes()
            + self.link.heap_bytes()
            + self.usage.u.capacity() * 4
            + self.precedence.capacity() * 4
            + self
                .w_read_prev
                .iter()
                .chain(self.r_prev.iter())
                .map(|v| v.capacity() * 4)
                .sum::<usize>()
    }

    pub fn tape_bytes(&self) -> usize {
        0
    }
}

impl HasParams for DncCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for DncCore {
    fn name(&self) -> &'static str {
        "dnc"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        self.mem.fill(0.0);
        self.usage.reset();
        self.link.fill(0.0);
        self.precedence.iter_mut().for_each(|x| *x = 0.0);
        for v in &mut self.w_read_prev {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for v in &mut self.d_wread {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.dmem.fill(0.0);
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let mem_before = self.mem.snapshot();
        self.usage.u.iter_mut().for_each(|u| *u *= self.usage.lambda);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- writes (DAM-style dense interpolation, eq. 5) ---
        let mut w_agg = vec![0.0f32; n];
        for hi in 0..self.cfg.heads {
            let ph = &p[hi * hd..(hi + 1) * hd];
            let a = &ph[w..2 * w];
            let alpha = sigmoid(ph[2 * w]);
            let gamma = sigmoid(ph[2 * w + 1]);
            let lra_row = self.usage.argmin();
            let mut w_write = vec![0.0f32; n];
            for i in 0..n {
                w_write[i] = alpha * gamma * self.w_read_prev[hi][i];
            }
            w_write[lra_row] += alpha * (1.0 - gamma);
            self.mem.row_mut(lra_row).iter_mut().for_each(|v| *v = 0.0);
            for i in 0..n {
                let wv = w_write[i];
                if wv != 0.0 {
                    let row = self.mem.row_mut(i);
                    for (m, &av) in row.iter_mut().zip(a) {
                        *m += wv * av;
                    }
                }
            }
            for i in 0..n {
                self.usage.u[i] += w_write[i];
                w_agg[i] += w_write[i];
            }
            heads.push(HeadStep {
                w_write,
                alpha,
                gamma,
                lra_row,
                write_word: a.to_vec(),
                read: ContentRead { rows: vec![], sims: vec![], weights: vec![], beta: 0.0, beta_raw: 0.0 },
                query: vec![],
                modes: vec![],
                fwd: vec![],
                bwd: vec![],
                w_read: vec![],
                w_read_used: self.w_read_prev[hi].clone(),
            });
        }

        // --- temporal linkage update (eq. 11, 13): dense O(N²) ---
        let s: f32 = w_agg.iter().sum();
        if s > 1.0 {
            w_agg.iter_mut().for_each(|x| *x /= s);
        }
        let p_prev = self.precedence.clone();
        for i in 0..n {
            let wi = w_agg[i];
            let lrow = self.link.row_mut(i);
            for j in 0..n {
                if i == j {
                    lrow[j] = 0.0;
                } else {
                    lrow[j] = (1.0 - wi - w_agg[j]) * lrow[j] + wi * p_prev[j];
                }
            }
        }
        let sum_w: f32 = w_agg.iter().sum();
        for i in 0..n {
            self.precedence[i] = (1.0 - sum_w) * p_prev[i] + w_agg[i];
        }

        // --- reads: 3-way mode mix over content / forward / backward ---
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for hi in 0..self.cfg.heads {
            let ph = &p[hi * hd..(hi + 1) * hd];
            let query = ph[..w].to_vec();
            let beta_raw = ph[2 * w + 2];
            let mut modes = ph[2 * w + 3..2 * w + 6].to_vec();
            softmax_inplace(&mut modes);
            let read = content_weights(&query, beta_raw, &self.mem, (0..n).collect());
            // f = L w_prev, b = Lᵀ w_prev (eq. 15/16)
            let wp = &self.w_read_prev[hi];
            let mut fwd = vec![0.0f32; n];
            let mut bwd = vec![0.0f32; n];
            for i in 0..n {
                fwd[i] = dot(self.link.row(i), wp);
            }
            for j in 0..n {
                // bwd = Lᵀ wp
                let lrow = self.link.row(j);
                let wj = wp[j];
                if wj != 0.0 {
                    for i in 0..n {
                        bwd[i] += lrow[i] * wj;
                    }
                }
            }
            let mut w_read = vec![0.0f32; n];
            for i in 0..n {
                w_read[i] = modes[0] * bwd[i] + modes[1] * read.weights[i] + modes[2] * fwd[i];
            }
            let mut r = vec![0.0; w];
            self.mem.read_dense(&w_read, &mut r);
            for i in 0..n {
                self.usage.u[i] += w_read[i];
            }
            let hstep = &mut heads[hi];
            hstep.read = read;
            hstep.query = query;
            hstep.modes = modes;
            hstep.fwd = fwd;
            hstep.bwd = bwd;
            hstep.w_read = w_read.clone();
            self.w_read_prev[hi] = w_read;
            reads.push(r);
        }

        *y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(DncStep { mem_before, link: self.link.clone(), heads });
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let n = self.cfg.mem_words;
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);
        let mut dp = vec![0.0f32; self.cfg.heads * hd];

        // --- read backward (memory = M_t, linkage = L_t from the tape) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            // r = Σ w_read(i) M_t(i); w_read also feeds t+1 (write gate +
            // linkage reads), whose gradient arrived in d_wread.
            let mut dw_read = vec![0.0f32; n];
            for i in 0..n {
                dw_read[i] = dot(self.mem.row(i), &dr) + self.d_wread[hi][i];
                let wv = hstep.w_read[i];
                if wv != 0.0 {
                    let row = self.dmem.row_mut(i);
                    for (g, &d) in row.iter_mut().zip(&dr) {
                        *g += wv * d;
                    }
                }
            }
            // mode mixture backward
            let mut dmodes = vec![0.0f32; 3];
            let mut dwc = vec![0.0f32; n];
            let mut dfwd = vec![0.0f32; n];
            let mut dbwd = vec![0.0f32; n];
            for i in 0..n {
                dmodes[0] += dw_read[i] * hstep.bwd[i];
                dmodes[1] += dw_read[i] * hstep.read.weights[i];
                dmodes[2] += dw_read[i] * hstep.fwd[i];
                dbwd[i] = dw_read[i] * hstep.modes[0];
                dwc[i] = dw_read[i] * hstep.modes[1];
                dfwd[i] = dw_read[i] * hstep.modes[2];
            }
            let mut dmode_logits = vec![0.0f32; 3];
            softmax_backward(&hstep.modes, &dmodes, &mut dmode_logits);
            let ph = &mut dp[hi * hd..(hi + 1) * hd];
            for k in 0..3 {
                ph[2 * w + 3 + k] += dmode_logits[k];
            }
            // f = L wp → dwp += Lᵀ dfwd; b = Lᵀ wp → dwp += L dbwd.
            // (No gradient through L itself, per Supp D.1.)
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..n {
                    acc += step.link.get(i, j) * dfwd[i];
                }
                acc += dot(step.link.row(j), &dbwd);
                self.d_wread[hi][j] = acc; // overwritten below by write-gate term
            }
            // content backward
            let mut dq = vec![0.0f32; w];
            let mut dbeta_raw = 0.0f32;
            let dmem_ref = &mut self.dmem;
            content_weights_backward(
                &hstep.read,
                &hstep.query,
                &self.mem,
                &dwc,
                &mut dq,
                &mut dbeta_raw,
                |row, d| {
                    let r = dmem_ref.row_mut(row);
                    for (g, &x) in r.iter_mut().zip(d) {
                        *g += x;
                    }
                },
            );
            ph[..w].iter_mut().zip(&dq).for_each(|(a, b)| *a += b);
            ph[2 * w + 2] += dbeta_raw;
        }

        // --- write backward (reverse head order) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let mut da = vec![0.0f32; w];
            let mut dw = vec![0.0f32; n];
            for i in 0..n {
                let wv = hstep.w_write[i];
                let drow = self.dmem.row(i);
                if wv != 0.0 {
                    for (daj, &dj) in da.iter_mut().zip(drow) {
                        *daj += wv * dj;
                    }
                }
                dw[i] = dot(&hstep.write_word, drow);
            }
            self.dmem.row_mut(hstep.lra_row).iter_mut().for_each(|v| *v = 0.0);
            let (a, g) = (hstep.alpha, hstep.gamma);
            let mut dalpha = 0.0f32;
            let mut dgamma = 0.0f32;
            for i in 0..n {
                let e_u = if i == hstep.lra_row { 1.0 } else { 0.0 };
                dalpha += dw[i] * (g * hstep.w_read_used[i] + (1.0 - g) * e_u);
                dgamma += dw[i] * a * (hstep.w_read_used[i] - e_u);
                // w_read_prev feeds both the write gate AND next step's
                // linkage reads; the linkage part was set above (at t+1's
                // backward), so accumulate here.
                self.d_wread[hi][i] += dw[i] * a * g;
            }
            let ph = &mut dp[hi * hd..(hi + 1) * hd];
            ph[w..2 * w].iter_mut().zip(&da).for_each(|(x, d)| *x += d);
            ph[2 * w] += dalpha * dsigmoid(a);
            ph[2 * w + 1] += dgamma * dsigmoid(g);
        }

        self.mem.restore(&step.mem_before);
        self.link = step.link; // becomes L_t; L_{t-1} is on the next tape entry
        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        if let Some(first) = self.tape.first() {
            let m = first.mem_before.clone();
            self.mem.restore(&m);
        }
        self.tape.clear();
    }

    fn end_episode(&mut self) {}

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                s.mem_before.capacity() * 4
                    + s.link.data.capacity() * 4
                    + s.heads
                        .iter()
                        .map(|h| {
                            (h.w_write.capacity()
                                + h.write_word.capacity()
                                + h.read.weights.capacity()
                                + h.query.capacity()
                                + h.fwd.capacity()
                                + h.bwd.capacity()
                                + h.w_read.capacity()
                                + h.w_read_used.capacity())
                                * 4
                                + h.read.sims.capacity() * 12
                                + h.read.rows.capacity() * 8
                        })
                        .sum::<usize>()
            })
            .sum();
        step + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 5,
            mem_words: 8,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(33);
        let mut core = DncCore::new(&small_cfg(33), &mut rng);
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.25);
        assert!(checked >= 30);
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn infer_session_matches_train_forward_bitwise() {
        let mut rng = Rng::new(36);
        let mut core = DncCore::new(&small_cfg(36), &mut rng);
        let (xs, _) = random_episode(4, 3, 5, &mut rng);
        let mut st = core.infer_session(None);
        let mut yi = Vec::new();
        for ep in 0..2 {
            core.reset();
            for x in &xs {
                let yt = core.forward(x);
                core.infer_step(&mut st, x, &mut yi);
                for (a, b) in yt.iter().zip(&yi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            core.rollback();
            core.end_episode();
            st.reset();
            assert_eq!(st.tape_bytes(), 0);
        }
    }

    #[test]
    fn linkage_diag_zero_and_bounded() {
        let mut rng = Rng::new(34);
        let mut core = DncCore::new(&small_cfg(34), &mut rng);
        core.reset();
        for _ in 0..6 {
            core.forward(&[1.0, 0.0, 1.0, 0.0]);
        }
        for i in 0..8 {
            assert_eq!(core.link.get(i, i), 0.0);
            for j in 0..8 {
                let v = core.link.get(i, j);
                assert!((-0.01..=1.01).contains(&v), "L[{i},{j}]={v}");
            }
        }
        core.rollback();
    }

    #[test]
    fn memory_restored_after_backward() {
        let mut rng = Rng::new(35);
        let mut core = DncCore::new(&small_cfg(35), &mut rng);
        core.reset();
        let start = core.mem.snapshot();
        let (xs, ts) = random_episode(4, 3, 3, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        assert_eq!(core.mem.snapshot(), start);
    }

    #[test]
    fn tape_grows_quadratically_with_n() {
        let mut sizes = Vec::new();
        for &n in &[16usize, 64] {
            let mut rng = Rng::new(36);
            let cfg = CoreConfig { mem_words: n, ..small_cfg(36) };
            let mut core = DncCore::new(&cfg, &mut rng);
            core.reset();
            let (xs, _) = random_episode(4, 3, 4, &mut rng);
            for x in &xs {
                core.forward(x);
            }
            sizes.push(core.tape_bytes());
            core.rollback();
        }
        // 4x memory words -> ~16x linkage bytes; require at least 4x total
        // (controller caches dilute the pure-linkage ratio at tiny N).
        assert!(sizes[1] as f64 > 4.0 * sizes[0] as f64, "{sizes:?}");
    }
}
