//! Content-based addressing (paper §2.1, eq. 2) and the SAM write-weight
//! interpolation (eq. 5) — forward *and* hand-derived backward, shared by
//! all cores. Dense variants cost O(N·W); sparse variants cost O(K·W).

use crate::memory::store::RowSource;
use crate::nn::act::{dsigmoid, dsoftplus, sigmoid, softplus};
use crate::tensor::csr::SparseVec;
use crate::tensor::matrix::{dot, norm, softmax_inplace, softmax_backward};
use crate::tensor::workspace::Workspace;

/// Norm floor in the cosine denominator. Keeps similarity (and its
/// gradients) bounded when memory rows are near zero — which is every row
/// at episode start, since the memory initializes to zeros.
pub const NORM_FLOOR: f32 = 0.1;

/// Cosine similarity plus cached norms for the backward pass.
/// d(q,m) = q·m / (max(|q|,f)·max(|m|,f)).
#[derive(Debug, Clone)]
pub struct CosSim {
    pub value: f32,
    pub nq: f32,
    pub nm: f32,
}

pub fn cos_sim(q: &[f32], m: &[f32]) -> CosSim {
    let nq = norm(q);
    let nm = norm(m);
    let d = nq.max(NORM_FLOOR) * nm.max(NORM_FLOOR);
    CosSim { value: dot(q, m) / d, nq, nm }
}

/// Accumulate d(cos)/dq and d(cos)/dm given upstream dcos.
pub fn cos_sim_backward(
    q: &[f32],
    m: &[f32],
    sim: &CosSim,
    dcos: f32,
    dq: &mut [f32],
    dm: &mut [f32],
) {
    let d = sim.nq.max(NORM_FLOOR) * sim.nm.max(NORM_FLOOR);
    let inv_d = 1.0 / d;
    // The self-norm terms only exist where the norm is above the floor
    // (below it the denominator is constant in that vector).
    let q_scale = if sim.nq > NORM_FLOOR { sim.value * sim.nm.max(NORM_FLOOR) / sim.nq } else { 0.0 };
    let m_scale = if sim.nm > NORM_FLOOR { sim.value * sim.nq.max(NORM_FLOOR) / sim.nm } else { 0.0 };
    for j in 0..q.len() {
        dq[j] += dcos * (m[j] - q_scale * q[j]) * inv_d;
        dm[j] += dcos * (q[j] - m_scale * m[j]) * inv_d;
    }
}

/// Forward cache of a content read over an explicit candidate row set.
/// For dense models the candidates are 0..N; for SAM they are the K rows
/// the ANN returned.
#[derive(Debug, Clone)]
pub struct ContentRead {
    /// Candidate memory rows, in weight order with `weights`.
    pub rows: Vec<usize>,
    pub sims: Vec<CosSim>,
    /// softmax(β · sims) over the candidates.
    pub weights: Vec<f32>,
    /// β = softplus(β̂) + 1 and its pre-activation.
    pub beta: f32,
    pub beta_raw: f32,
}

impl ContentRead {
    /// A placeholder with no candidates (tape-slot initialization).
    pub fn empty() -> ContentRead {
        ContentRead { rows: Vec::new(), sims: Vec::new(), weights: Vec::new(), beta: 0.0, beta_raw: 0.0 }
    }
}

/// Compute content weights softmax(β·cos(q, M(rows))) over `rows`.
/// Generic over [`RowSource`] so the candidate rows may live in one
/// [`MemoryStore`] or be striped across a sharded engine's stores — the
/// math reads rows one at a time either way.
pub fn content_weights(
    q: &[f32],
    beta_raw: f32,
    mem: &impl RowSource,
    rows: Vec<usize>,
) -> ContentRead {
    content_weights_into(q, beta_raw, mem, rows, Vec::new(), Vec::new())
}

/// `content_weights` assembling into caller-recycled `sims`/`weights`
/// buffers (cleared here), so a pooled step computes a content read with
/// zero allocations. Values and op order identical to [`content_weights`].
pub fn content_weights_into(
    q: &[f32],
    beta_raw: f32,
    mem: &impl RowSource,
    rows: Vec<usize>,
    mut sims: Vec<CosSim>,
    mut weights: Vec<f32>,
) -> ContentRead {
    let beta = softplus(beta_raw) + 1.0;
    sims.clear();
    // Per row, one fused (q·m, m·m) pass through the RowSource — for f32
    // stores these are the identical dot() calls cos_sim always made
    // (bit-identical), for compact stores the decode happens inside the
    // kernel. |q| is hoisted: it was recomputed per row before, but it is
    // the same dot(q,q) every time, so the bits don't change.
    let nq = norm(q);
    for &i in &rows {
        let (dqm, nmsq) = mem.row_dot_normsq(i, q);
        let nm = nmsq.sqrt();
        let d = nq.max(NORM_FLOOR) * nm.max(NORM_FLOOR);
        sims.push(CosSim { value: dqm / d, nq, nm });
    }
    weights.clear();
    for s in &sims {
        weights.push(beta * s.value);
    }
    softmax_inplace(&mut weights);
    ContentRead { rows, sims, weights, beta, beta_raw }
}

/// Batched `content_weights` over every head's (query, β̂) pair — the
/// step-level entry point paired with `AnnIndex::query_many`, so a
/// multi-head read computes all its softmaxes from one candidate-selection
/// traversal. `rows_per_query[i]` is the candidate set for `queries[i]`.
pub fn content_weights_many(
    queries: &[(Vec<f32>, f32)],
    mem: &impl RowSource,
    rows_per_query: Vec<Vec<usize>>,
) -> Vec<ContentRead> {
    assert_eq!(queries.len(), rows_per_query.len());
    queries
        .iter()
        .zip(rows_per_query)
        .map(|((q, beta_raw), rows)| content_weights(q, *beta_raw, mem, rows))
        .collect()
}

/// Gradients of `content_weights`: given dL/dweights, accumulate dq,
/// dβ̂ and per-row memory grads via the callback (row, dmem_row_fn).
pub fn content_weights_backward(
    cr: &ContentRead,
    q: &[f32],
    mem: &impl RowSource,
    dweights: &[f32],
    dq: &mut [f32],
    dbeta_raw: &mut f32,
    dmem: impl FnMut(usize, &[f32]),
) {
    let mut ws = Workspace::new();
    content_weights_backward_ws(cr, q, mem, dweights, dq, dbeta_raw, &mut ws, dmem);
}

/// [`content_weights_backward`] with its scratch (softmax dlogits, per-row
/// memory-grad staging) drawn from a workspace instead of fresh Vecs.
#[allow(clippy::too_many_arguments)]
pub fn content_weights_backward_ws(
    cr: &ContentRead,
    q: &[f32],
    mem: &impl RowSource,
    dweights: &[f32],
    dq: &mut [f32],
    dbeta_raw: &mut f32,
    ws: &mut Workspace,
    mut dmem: impl FnMut(usize, &[f32]),
) {
    let k = cr.rows.len();
    let mut dlogits = ws.take_f32(k);
    softmax_backward(&cr.weights, dweights, &mut dlogits);
    let mut dbeta = 0.0f32;
    let mut dm_row = ws.take_f32(q.len());
    for (j, &row) in cr.rows.iter().enumerate() {
        dbeta += dlogits[j] * cr.sims[j].value;
        let dsim = dlogits[j] * cr.beta;
        if dsim != 0.0 {
            dm_row.iter_mut().for_each(|x| *x = 0.0);
            cos_sim_backward(q, mem.row(row), &cr.sims[j], dsim, dq, &mut dm_row);
            dmem(row, &dm_row);
        }
    }
    *dbeta_raw += dbeta * dsoftplus(cr.beta_raw);
    ws.recycle_f32(dlogits);
    ws.recycle_f32(dm_row);
}

/// Forward cache for the SAM/DAM write interpolation (eq. 5):
/// w^W = α · (γ · w^R_prev + (1-γ) · 𝕀_u), α = σ(α̂), γ = σ(γ̂).
#[derive(Debug, Clone)]
pub struct WriteGate {
    pub alpha: f32,
    pub gamma: f32,
    pub alpha_raw: f32,
    pub gamma_raw: f32,
    /// The least-recently-accessed target row u.
    pub lra_row: usize,
    /// Resulting sparse write weights.
    pub weights: SparseVec,
}

pub fn write_gate(alpha_raw: f32, gamma_raw: f32, w_read_prev: &SparseVec, lra_row: usize) -> WriteGate {
    let mut ws = Workspace::new();
    write_gate_ws(alpha_raw, gamma_raw, w_read_prev, lra_row, &mut ws)
}

/// [`write_gate`] with the weight vector assembled from workspace pools.
/// Note: if lra_row already appears in w_read_prev the contributions add,
/// which matches evaluating eq. 5 at that index.
pub fn write_gate_ws(
    alpha_raw: f32,
    gamma_raw: f32,
    w_read_prev: &SparseVec,
    lra_row: usize,
    ws: &mut Workspace,
) -> WriteGate {
    let alpha = sigmoid(alpha_raw);
    let gamma = sigmoid(gamma_raw);
    let mut pairs = ws.take_pairs();
    pairs.extend(w_read_prev.iter().map(|(i, v)| (i, alpha * gamma * v)));
    pairs.push((lra_row, alpha * (1.0 - gamma)));
    let mut weights = ws.take_sparse();
    weights.assign_from_pairs(&mut pairs);
    ws.recycle_pairs(pairs);
    WriteGate { alpha, gamma, alpha_raw, gamma_raw, lra_row, weights }
}

/// Backward of `write_gate`. `dw` is dL/d(weights) aligned to
/// `gate.weights`. Accumulates dα̂, dγ̂ and returns dL/d(w^R_prev).
pub fn write_gate_backward(
    gate: &WriteGate,
    w_read_prev: &SparseVec,
    dw: &SparseVec,
    dalpha_raw: &mut f32,
    dgamma_raw: &mut f32,
) -> SparseVec {
    let mut ws = Workspace::new();
    write_gate_backward_ws(gate, w_read_prev, dw, dalpha_raw, dgamma_raw, &mut ws)
}

/// [`write_gate_backward`] returning a workspace-pooled gradient vector.
pub fn write_gate_backward_ws(
    gate: &WriteGate,
    w_read_prev: &SparseVec,
    dw: &SparseVec,
    dalpha_raw: &mut f32,
    dgamma_raw: &mut f32,
    ws: &mut Workspace,
) -> SparseVec {
    let (a, g) = (gate.alpha, gate.gamma);
    let mut dalpha = 0.0f32;
    let mut dgamma = 0.0f32;
    // Term from the previously-read component. w_read_prev is sorted, so
    // the gradient support can be pushed directly without a from_pairs sort.
    let mut dw_prev = ws.take_sparse();
    for (i, v) in w_read_prev.iter() {
        let dwi = dw.get(i);
        dalpha += dwi * g * v;
        dgamma += dwi * a * v;
        dw_prev.push(i, dwi * a * g);
    }
    // Term from the LRA indicator.
    let dwu = dw.get(gate.lra_row);
    dalpha += dwu * (1.0 - g);
    dgamma -= dwu * a;
    *dalpha_raw += dalpha * dsigmoid(a);
    *dgamma_raw += dgamma * dsigmoid(g);
    dw_prev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::store::MemoryStore;
    use crate::util::rng::Rng;

    #[test]
    fn cos_sim_backward_matches_fd() {
        let mut rng = Rng::new(1);
        let q: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        let m: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        let s = cos_sim(&q, &m);
        let mut dq = vec![0.0; 6];
        let mut dm = vec![0.0; 6];
        cos_sim_backward(&q, &m, &s, 1.0, &mut dq, &mut dm);
        let eps = 1e-3;
        for j in 0..6 {
            let mut qp = q.clone();
            qp[j] += eps;
            let mut qm_ = q.clone();
            qm_[j] -= eps;
            let fd = (cos_sim(&qp, &m).value - cos_sim(&qm_, &m).value) / (2.0 * eps);
            assert!((fd - dq[j]).abs() < 1e-3, "dq[{j}] fd={fd} an={}", dq[j]);
            let mut mp = m.clone();
            mp[j] += eps;
            let mut mm = m.clone();
            mm[j] -= eps;
            let fd = (cos_sim(&q, &mp).value - cos_sim(&q, &mm).value) / (2.0 * eps);
            assert!((fd - dm[j]).abs() < 1e-3, "dm[{j}] fd={fd} an={}", dm[j]);
        }
    }

    #[test]
    fn content_weights_backward_matches_fd() {
        let mut rng = Rng::new(2);
        let (n, w) = (5, 4);
        let mut mem = MemoryStore::zeros(n, w);
        for i in 0..n {
            for j in 0..w {
                mem.row_mut(i)[j] = rng.normal();
            }
        }
        let q: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
        let beta_raw = 0.4f32;
        let rows: Vec<usize> = (0..n).collect();
        let probe: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

        let loss = |q: &[f32], beta_raw: f32, mem: &MemoryStore| -> f32 {
            let cr = content_weights(q, beta_raw, mem, rows.clone());
            cr.weights.iter().zip(&probe).map(|(a, b)| a * b).sum()
        };

        let cr = content_weights(&q, beta_raw, &mem, rows.clone());
        let mut dq = vec![0.0; w];
        let mut dbeta_raw = 0.0;
        let mut dmem_acc = vec![vec![0.0f32; w]; n];
        content_weights_backward(&cr, &q, &mem, &probe, &mut dq, &mut dbeta_raw, |r, d| {
            for j in 0..w {
                dmem_acc[r][j] += d[j];
            }
        });

        let eps = 1e-3;
        for j in 0..w {
            let mut qp = q.clone();
            qp[j] += eps;
            let mut qm = q.clone();
            qm[j] -= eps;
            let fd = (loss(&qp, beta_raw, &mem) - loss(&qm, beta_raw, &mem)) / (2.0 * eps);
            assert!((fd - dq[j]).abs() < 2e-3, "dq[{j}] fd={fd} an={}", dq[j]);
        }
        {
            let fd = (loss(&q, beta_raw + eps, &mem) - loss(&q, beta_raw - eps, &mem)) / (2.0 * eps);
            assert!((fd - dbeta_raw).abs() < 2e-3, "dbeta fd={fd} an={dbeta_raw}");
        }
        for r in 0..n {
            for j in 0..w {
                let orig = mem.row(r)[j];
                mem.row_mut(r)[j] = orig + eps;
                let lp = loss(&q, beta_raw, &mem);
                mem.row_mut(r)[j] = orig - eps;
                let lm = loss(&q, beta_raw, &mem);
                mem.row_mut(r)[j] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                assert!(
                    (fd - dmem_acc[r][j]).abs() < 2e-3,
                    "dM[{r},{j}] fd={fd} an={}",
                    dmem_acc[r][j]
                );
            }
        }
    }

    #[test]
    fn write_gate_backward_matches_fd() {
        let w_prev = SparseVec::from_pairs(vec![(2, 0.5), (7, 0.3), (9, 0.2)]);
        let lra = 4usize;
        let (ar0, gr0) = (0.3f32, -0.6f32);
        let probe = SparseVec::from_pairs(vec![(2, 0.7), (4, -0.5), (7, 0.2), (9, 1.0)]);
        let loss = |ar: f32, gr: f32, wp: &SparseVec| -> f32 {
            let g = write_gate(ar, gr, wp, lra);
            g.weights.iter().map(|(i, v)| v * probe.get(i)).sum()
        };
        let gate = write_gate(ar0, gr0, &w_prev, lra);
        // dL/dw aligned to gate.weights = probe restricted to its support.
        let dw = SparseVec::from_pairs(
            gate.weights.iter().map(|(i, _)| (i, probe.get(i))).collect(),
        );
        let (mut dar, mut dgr) = (0.0, 0.0);
        let dw_prev = write_gate_backward(&gate, &w_prev, &dw, &mut dar, &mut dgr);
        let eps = 1e-3;
        let fd_a = (loss(ar0 + eps, gr0, &w_prev) - loss(ar0 - eps, gr0, &w_prev)) / (2.0 * eps);
        assert!((fd_a - dar).abs() < 1e-3, "dalpha fd={fd_a} an={dar}");
        let fd_g = (loss(ar0, gr0 + eps, &w_prev) - loss(ar0, gr0 - eps, &w_prev)) / (2.0 * eps);
        assert!((fd_g - dgr).abs() < 1e-3, "dgamma fd={fd_g} an={dgr}");
        for (pos, (i, v)) in w_prev.iter().enumerate() {
            let mut wp = w_prev.clone();
            wp.val[pos] = v + eps;
            let lp = loss(ar0, gr0, &wp);
            wp.val[pos] = v - eps;
            let lm = loss(ar0, gr0, &wp);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dw_prev.get(i)).abs() < 1e-3, "dw_prev[{i}]");
        }
    }

    #[test]
    fn write_gate_lra_overlapping_read_support() {
        // lra row inside the read support must combine, not duplicate.
        let w_prev = SparseVec::from_pairs(vec![(3, 1.0)]);
        let g = write_gate(10.0, 0.0, &w_prev, 3); // α≈1, γ=0.5
        assert_eq!(g.weights.nnz(), 1);
        let v = g.weights.get(3);
        assert!((v - (0.5 + 0.5)).abs() < 1e-3, "v={v}");
    }
}
