//! Sparse Differentiable Neural Computer (SDNC, Supp D): SAM's sparse
//! read/write machinery plus *sparse* temporal linkage.
//!
//! Instead of the DNC's dense L ∈ [0,1]^{N×N}, two row-truncated sparse
//! matrices are maintained (eq. 17-20): N_t ≈ L and P_t ≈ Lᵀ, each row
//! capped at K_L non-zeros, plus a K_L-sparse precedence p_t. Because
//! P = Nᵀ, the link-following reads are sparse row gathers:
//!     f_t = N_t·w^r_{t-1} = Σ_j w^r(j)·P_t(j,:)   (eq. 21)
//!     b_t = P_t·w^r_{t-1} = Σ_j w^r(j)·N_t(j,:)   (eq. 22)
//! both O(K·K_L). As in the paper, gradients are not passed through the
//! linkage matrices (Supp D.1), but do flow through the read mixture.
//!
//! Memory, ANN, LRA ring, write journals and the carried memory gradient
//! all live in the shared [`SparseMemoryEngine`]; the SDNC keeps only its
//! temporal-link state (N/P/precedence and their per-step journals) local.

use super::addressing::{ContentRead, WriteGate};
use super::{Controller, Core, CoreConfig};
use crate::memory::engine::SparseMemoryEngine;
use crate::nn::param::{HasParams, Param};
use crate::tensor::csr::{SparseLinkMatrix, SparseVec};
use crate::tensor::matrix::{softmax_backward, softmax_inplace};
use crate::util::rng::Rng;
use std::collections::{HashMap, HashSet};

/// Head params: [q(W), a(W), α̂, γ̂, β̂, mode(3)] — modes (backward, content, forward).
const fn head_dim(word: usize) -> usize {
    2 * word + 6
}

struct HeadStep {
    gate: WriteGate,
    w_read_used: SparseVec,
    write_word: Vec<f32>,
    read: ContentRead,
    query: Vec<f32>,
    modes: Vec<f32>,
    fwd: SparseVec,
    bwd: SparseVec,
    w_read: SparseVec,
}

/// Saved linkage rows for rollback (None = the row did not exist).
struct LinkJournal {
    n_rows: Vec<(usize, Option<SparseVec>)>,
    p_rows: Vec<(usize, Option<SparseVec>)>,
    precedence: SparseVec,
}

struct SdncStep {
    heads: Vec<HeadStep>,
    links: LinkJournal,
}

pub struct SdncCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: SparseMemoryEngine,
    n_link: SparseLinkMatrix,
    p_link: SparseLinkMatrix,
    precedence: SparseVec,
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<SdncStep>,
    // carried backward state
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<SparseVec>,
}

impl SdncCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> SdncCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "sdnc",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        let engine = SparseMemoryEngine::new_sparse(
            cfg.mem_words,
            cfg.word,
            cfg.k,
            cfg.delta,
            cfg.ann,
            &mut rng,
        );
        SdncCore {
            ctrl,
            engine,
            n_link: SparseLinkMatrix::new(cfg.k_l),
            p_link: SparseLinkMatrix::new(cfg.k_l),
            precedence: SparseVec::new(),
            w_read_prev: vec![SparseVec::new(); cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![SparseVec::new(); cfg.heads],
            cfg: cfg.clone(),
        }
    }

    /// f/b link-follow: Σ_j w(j)·rows(j,:) over a row-sparse matrix.
    fn follow(link: &SparseLinkMatrix, w: &SparseVec) -> SparseVec {
        let mut pairs = Vec::new();
        for (j, wj) in w.iter() {
            if let Some(row) = link.row(j) {
                for (i, v) in row.iter() {
                    pairs.push((i, wj * v));
                }
            }
        }
        SparseVec::from_pairs(pairs)
    }

    /// Apply the sparse linkage update for aggregate write weights `w`,
    /// returning the journal of replaced rows. (eq. 17-20)
    fn update_links(&mut self, w: &SparseVec) -> LinkJournal {
        let mut journal = LinkJournal {
            n_rows: Vec::new(),
            p_rows: Vec::new(),
            precedence: self.precedence.clone(),
        };
        let p_prev = self.precedence.clone();
        // N rows: N(i,:) = (1-w(i))·N(i,:) + w(i)·p_prev,   i ∈ supp(w), j ≠ i.
        for (i, wi) in w.iter() {
            let old = self.n_link.row(i).cloned();
            let mut row = old.clone().unwrap_or_default();
            row.scale(1.0 - wi);
            let mut row = row.add_scaled(wi, &p_prev);
            // zero diagonal
            if let Ok(pos) = row.idx.binary_search(&i) {
                row.idx.remove(pos);
                row.val.remove(pos);
            }
            journal.n_rows.push((i, old));
            self.n_link.set_row(i, row);
        }
        // P rows: P(i,j) = (1-w(j))·P(i,j) + w(j)·p_prev(i) for j ∈ supp(w).
        // Affected rows: supp(p_prev) ∪ {i : P(i,j) ≠ 0 for some j ∈ supp(w)}
        //              = supp(p_prev) ∪ ∪_{j∈supp(w)} supp(N_old(j,:)).
        let mut affected: HashSet<usize> = p_prev.idx.iter().copied().collect();
        for (j, _) in w.iter() {
            for (old_j, old_row) in journal.n_rows.iter() {
                if *old_j == j {
                    if let Some(r) = old_row {
                        affected.extend(r.idx.iter().copied());
                    }
                }
            }
        }
        let mut affected: Vec<usize> = affected.into_iter().collect();
        affected.sort_unstable();
        for i in affected {
            let old = self.p_link.row(i).cloned();
            let mut row: HashMap<usize, f32> =
                old.as_ref().map(|r| r.iter().collect()).unwrap_or_default();
            for (j, wj) in w.iter() {
                if i == j {
                    continue; // diagonal stays zero
                }
                let cur = row.get(&j).copied().unwrap_or(0.0);
                let nv = (1.0 - wj) * cur + wj * p_prev.get(i);
                if nv != 0.0 {
                    row.insert(j, nv);
                } else {
                    row.remove(&j);
                }
            }
            journal.p_rows.push((i, old));
            self.p_link.set_row(i, SparseVec::from_pairs(row.into_iter().collect()));
        }
        // precedence: p = (1-Σw)·p_prev + w, truncated to K_L.
        let sum_w = w.sum().min(1.0);
        let mut p = p_prev.clone();
        p.scale(1.0 - sum_w);
        let mut p = p.add(w);
        p.truncate_top_k(self.cfg.k_l);
        self.precedence = p;
        journal
    }

    fn revert_links(&mut self, journal: LinkJournal) {
        for (i, old) in journal.p_rows.into_iter().rev() {
            match old {
                Some(row) => self.p_link.set_row(i, row),
                None => self.p_link.set_row(i, SparseVec::new()),
            }
        }
        for (i, old) in journal.n_rows.into_iter().rev() {
            match old {
                Some(row) => self.n_link.set_row(i, row),
                None => self.n_link.set_row(i, SparseVec::new()),
            }
        }
        self.precedence = journal.precedence;
    }
}

impl HasParams for SdncCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for SdncCore {
    fn name(&self) -> &'static str {
        "sdnc"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        self.tape.clear();
        self.engine.reset();
        self.n_link = SparseLinkMatrix::new(self.cfg.k_l);
        self.p_link = SparseLinkMatrix::new(self.cfg.k_l);
        self.precedence = SparseVec::new();
        for v in &mut self.w_read_prev {
            *v = SparseVec::new();
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for d in &mut self.d_wread {
            *d = SparseVec::new();
        }
    }

    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (h, p) = self.ctrl.step(x, &self.r_prev);
        let mut heads = Vec::with_capacity(self.cfg.heads);

        // --- SAM-style sparse writes (engine journals + syncs the ANN) ---
        let mut w_agg = SparseVec::new();
        for hi in 0..self.cfg.heads {
            let ph = &p[hi * hd..(hi + 1) * hd];
            let a = ph[w..2 * w].to_vec();
            let (ar, gr) = (ph[2 * w], ph[2 * w + 1]);
            let gate = self.engine.sparse_write(ar, gr, &self.w_read_prev[hi], &a);
            w_agg = w_agg.add(&gate.weights);
            heads.push(HeadStep {
                gate,
                w_read_used: self.w_read_prev[hi].clone(),
                write_word: a,
                read: ContentRead { rows: vec![], sims: vec![], weights: vec![], beta: 0.0, beta_raw: 0.0 },
                query: vec![],
                modes: vec![],
                fwd: SparseVec::new(),
                bwd: SparseVec::new(),
                w_read: SparseVec::new(),
            });
        }

        // --- sparse temporal linkage update (eq. 17-20) ---
        let s = w_agg.sum();
        if s > 1.0 {
            w_agg.scale(1.0 / s);
        }
        let links = self.update_links(&w_agg);

        // --- reads: 3-way mix of content / forward-link / backward-link,
        //     content candidates from one batched ANN traversal ---
        let queries: Vec<(Vec<f32>, f32)> = (0..self.cfg.heads)
            .map(|hi| {
                let ph = &p[hi * hd..(hi + 1) * hd];
                (ph[..w].to_vec(), ph[2 * w + 2])
            })
            .collect();
        let content_reads = self.engine.content_read_many(&queries);
        let mut reads = Vec::with_capacity(self.cfg.heads);
        for (hi, ((query, _beta_raw), read)) in
            queries.into_iter().zip(content_reads).enumerate()
        {
            let ph = &p[hi * hd..(hi + 1) * hd];
            let mut modes = ph[2 * w + 3..2 * w + 6].to_vec();
            softmax_inplace(&mut modes);
            let wp = &self.w_read_prev[hi];
            let fwd = Self::follow(&self.p_link, wp); // f = Σ w(j)·P(j,:) = N·w
            let bwd = Self::follow(&self.n_link, wp); // b = Σ w(j)·N(j,:) = Nᵀ·w = P·w
            let mut w_read = SparseVec::from_pairs(
                read.rows
                    .iter()
                    .copied()
                    .zip(read.weights.iter().map(|&v| v * modes[1]))
                    .collect(),
            );
            w_read = w_read.add_scaled(modes[0], &bwd).add_scaled(modes[2], &fwd);
            w_read.truncate_top_k(self.cfg.k + 2 * self.cfg.k_l);
            let r = self.engine.read_mixture(&w_read);
            self.w_read_prev[hi] = w_read.clone();
            let hstep = &mut heads[hi];
            hstep.read = read;
            hstep.query = query;
            hstep.modes = modes;
            hstep.fwd = fwd;
            hstep.bwd = bwd;
            hstep.w_read = w_read;
            reads.push(r);
        }

        let y = self.ctrl.output(&h, &reads);
        self.r_prev = reads;
        self.tape.push(SdncStep { heads, links });
        y
    }

    fn backward(&mut self, dy: &[f32]) {
        let step = self.tape.pop().expect("backward without forward");
        let w = self.cfg.word;
        let hd = head_dim(w);
        let (dh, dreads) = self.ctrl.backward_output(dy);
        let mut dp = vec![0.0f32; self.cfg.heads * hd];
        // Linkage contribution to the carried d_wread, accumulated before
        // the write-gate contribution is added below.
        let mut d_wread_next: Vec<SparseVec> = vec![SparseVec::new(); self.cfg.heads];

        // --- read backward (memory = M_t, links = N_t/P_t) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            let mut dr = dreads[hi].clone();
            for (a, b) in dr.iter_mut().zip(&self.d_r[hi]) {
                *a += b;
            }
            // dL/dw_read over supp(w_read), plus the carried gradient from
            // step t+1's uses of w_read (gate + linkage).
            let dw_read =
                self.engine.backward_sparse_read(&hstep.w_read, &dr, &self.d_wread[hi]);
            // mode mixture backward
            let dmodes = vec![
                dw_read.dot_sparse(&hstep.bwd),
                hstep
                    .read
                    .rows
                    .iter()
                    .zip(&hstep.read.weights)
                    .map(|(&i, &v)| v * dw_read.get(i))
                    .sum::<f32>(),
                dw_read.dot_sparse(&hstep.fwd),
            ];
            let mut dmode_logits = vec![0.0f32; 3];
            softmax_backward(&hstep.modes, &dmodes, &mut dmode_logits);
            let ph = &mut dp[hi * hd..(hi + 1) * hd];
            for k in 0..3 {
                ph[2 * w + 3 + k] += dmode_logits[k];
            }
            // content path
            let dweights: Vec<f32> = hstep
                .read
                .rows
                .iter()
                .map(|&i| hstep.modes[1] * dw_read.get(i))
                .collect();
            let mut dq = vec![0.0f32; w];
            let mut dbeta_raw = 0.0f32;
            self.engine.backward_content(
                &hstep.read,
                &hstep.query,
                &dweights,
                &mut dq,
                &mut dbeta_raw,
            );
            ph[..w].iter_mut().zip(&dq).for_each(|(a, b)| *a += b);
            ph[2 * w + 2] += dbeta_raw;
            // linkage path: f = Σ_j wp(j)·P(j,:) ⇒ dwp(j) = P(j,:)·df;
            //               b = Σ_j wp(j)·N(j,:) ⇒ dwp(j) = N(j,:)·db.
            let mut df = dw_read.clone();
            df.scale(hstep.modes[2]);
            let mut db = dw_read.clone();
            db.scale(hstep.modes[0]);
            let wp = &hstep.w_read_used; // NOTE: wp at read time == w_read_prev before this step's reads
            let mut pairs = Vec::with_capacity(wp.nnz());
            for (j, _) in wp.iter() {
                let mut g = 0.0;
                if let Some(prow) = self.p_link.row(j) {
                    g += prow.dot_sparse(&df);
                }
                if let Some(nrow) = self.n_link.row(j) {
                    g += nrow.dot_sparse(&db);
                }
                pairs.push((j, g));
            }
            d_wread_next[hi] = SparseVec::from_pairs(pairs);
        }

        // --- write backward (reverse head order, rolling memory back) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let (mut dar, mut dgr) = (0.0f32, 0.0f32);
            let (da, dw_prev) = self.engine.backward_write(
                &hstep.gate,
                &hstep.write_word,
                &hstep.w_read_used,
                &mut dar,
                &mut dgr,
            );
            self.d_wread[hi] = d_wread_next[hi].add(&dw_prev);
            let ph = &mut dp[hi * hd..(hi + 1) * hd];
            ph[w..2 * w].iter_mut().zip(&da).for_each(|(x, d)| *x += d);
            ph[2 * w] += dar;
            ph[2 * w + 1] += dgr;
        }

        // Roll the linkage back to N_{t-1}/P_{t-1}.
        self.revert_links(step.links);

        let (_dx, dr_prev) = self.ctrl.backward_step(&dh, &dp);
        self.d_r = dr_prev;
    }

    fn rollback(&mut self) {
        self.engine.rollback();
        while let Some(step) = self.tape.pop() {
            self.revert_links(step.links);
        }
    }

    fn end_episode(&mut self) {
        debug_assert!(self.tape.is_empty());
        self.engine.end_episode();
    }

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                let link_bytes: usize = s
                    .links
                    .n_rows
                    .iter()
                    .chain(s.links.p_rows.iter())
                    .map(|(_, r)| r.as_ref().map(|x| x.heap_bytes()).unwrap_or(0) + 24)
                    .sum::<usize>()
                    + s.links.precedence.heap_bytes();
                link_bytes
                    + s.heads
                        .iter()
                        .map(|h| {
                            h.w_read_used.heap_bytes()
                                + h.w_read.heap_bytes()
                                + h.fwd.heap_bytes()
                                + h.bwd.heap_bytes()
                                + h.gate.weights.heap_bytes()
                                + (h.write_word.capacity() + h.query.capacity()) * 4
                                + h.read.rows.capacity() * 8
                                + h.read.weights.capacity() * 4
                                + h.read.sims.capacity() * 12
                        })
                        .sum::<usize>()
            })
            .sum();
        step + self.engine.tape_bytes() + self.ctrl.cache_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 5,
            mem_words: 16,
            k: 3,
            k_l: 4,
            ann: AnnKind::Linear,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(43);
        let mut core = SdncCore::new(&small_cfg(43), &mut rng);
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.25);
        assert!(checked >= 30);
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn memory_and_links_roll_back() {
        let mut rng = Rng::new(44);
        let mut core = SdncCore::new(&small_cfg(44), &mut rng);
        core.reset();
        let start = core.engine.snapshot();
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        assert!(core.n_link.nnz() > 0, "writes should populate the linkage");
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        assert_eq!(core.engine.snapshot(), start);
        assert_eq!(core.n_link.nnz(), 0, "linkage must roll back to empty");
        assert_eq!(core.p_link.nnz(), 0);
        assert_eq!(core.precedence.nnz(), 0);
    }

    /// The sparse linkage must approximate the dense DNC linkage on the
    /// common support: simulate both for a few steps of random sparse
    /// writes and compare f/b reads.
    #[test]
    fn sparse_links_track_dense_reference() {
        let n = 12;
        let k_l = 12; // no truncation -> should match the dense recurrence
        let mut rng = Rng::new(45);
        let mut core = SdncCore::new(&CoreConfig { mem_words: n, k_l, ..small_cfg(45) }, &mut rng);
        // dense reference
        let mut l_dense = vec![vec![0.0f32; n]; n];
        let mut p_dense = vec![0.0f32; n];
        for _ in 0..8 {
            let k = rng.int_in(1, 3);
            let idx = rng.sample_indices(n, k);
            let mut w = SparseVec::from_pairs(
                idx.iter().map(|&i| (i, rng.uniform() * 0.5)).collect(),
            );
            let s = w.sum();
            if s > 1.0 {
                w.scale(1.0 / s);
            }
            core.update_links(&w);
            // dense update
            let wd = w.to_dense(n);
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        l_dense[i][j] = 0.0;
                    } else {
                        l_dense[i][j] =
                            (1.0 - wd[i] - wd[j]) * l_dense[i][j] + wd[i] * p_dense[j];
                    }
                }
            }
            let sum_w: f32 = wd.iter().sum();
            for i in 0..n {
                p_dense[i] = (1.0 - sum_w) * p_dense[i] + wd[i];
            }
        }
        // Compare N against the "decay only on write rows" sparse recurrence:
        // rows never written stay zero in both. For written rows the sparse
        // N uses (1-w(i)) where dense L uses (1-w(i)-w(j)); tolerance is
        // loose to cover that deliberate approximation (eq. 19 vs 13).
        let wp = SparseVec::from_pairs((0..n).map(|i| (i, 1.0 / n as f32)).collect());
        let f_sparse = SdncCore::follow(&core.p_link, &wp).to_dense(n);
        let mut f_dense = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                f_dense[i] += l_dense[i][j] * wp.get(j);
            }
        }
        for i in 0..n {
            assert!(
                (f_sparse[i] - f_dense[i]).abs() < 0.05,
                "f[{i}] sparse={} dense={}",
                f_sparse[i],
                f_dense[i]
            );
        }
    }

    #[test]
    fn linkage_rows_bounded_by_kl() {
        let mut rng = Rng::new(46);
        let cfg = small_cfg(46);
        let mut core = SdncCore::new(&cfg, &mut rng);
        core.reset();
        let (xs, _) = random_episode(4, 3, 10, &mut rng);
        for x in &xs {
            core.forward(x);
        }
        for (_, row) in core.n_link.rows.iter() {
            assert!(row.nnz() <= cfg.k_l);
        }
        for (_, row) in core.p_link.rows.iter() {
            assert!(row.nnz() <= cfg.k_l);
        }
        core.rollback();
        core.end_episode();
    }
}
