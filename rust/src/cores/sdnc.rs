//! Sparse Differentiable Neural Computer (SDNC, Supp D): SAM's sparse
//! read/write machinery plus *sparse* temporal linkage.
//!
//! Instead of the DNC's dense L ∈ [0,1]^{N×N}, two row-truncated sparse
//! matrices are maintained (eq. 17-20): N_t ≈ L and P_t ≈ Lᵀ, each row
//! capped at K_L non-zeros, plus a K_L-sparse precedence p_t. Because
//! P = Nᵀ, the link-following reads are sparse row gathers:
//!     f_t = N_t·w^r_{t-1} = Σ_j w^r(j)·P_t(j,:)   (eq. 21)
//!     b_t = P_t·w^r_{t-1} = Σ_j w^r(j)·N_t(j,:)   (eq. 22)
//! both O(K·K_L). As in the paper, gradients are not passed through the
//! linkage matrices (Supp D.1), but do flow through the read mixture.
//!
//! Memory, ANN, LRA ring, write journals and the carried memory gradient
//! all live in the shared [`ShardedMemoryEngine`] (S memory shards with a
//! parallel fan-out query; `CoreConfig::shards = 1` is exactly the single
//! engine); the SDNC keeps only its temporal-link state (N/P/precedence
//! and their per-step journals) local — linkage is over *global* row ids,
//! so sharding is invisible to it.
//!
//! **Zero-allocation steps**: linkage journals move the replaced rows (no
//! clones), the N/P row updates are sorted two-pointer merges into pooled
//! vectors (replacing the old per-step HashMap/HashSet scratch), and every
//! tape buffer recycles through the core's [`Workspace`] during backward
//! (rust/tests/zero_alloc.rs).

use super::addressing::{ContentRead, WriteGate};
use super::{BatchCore, Controller, ControllerState, Core, CoreConfig, CtrlBatch, LaneWeights};
use crate::memory::sharded::ShardedMemoryEngine;
use crate::nn::param::{HasParams, Param};
use crate::tensor::csr::{SparseLinkMatrix, SparseVec};
use crate::tensor::matrix::{axpy, softmax_backward, softmax_inplace};
use crate::tensor::workspace::Workspace;
use crate::util::rng::Rng;

/// Head params: [q(W), a(W), α̂, γ̂, β̂, mode(3)] — modes (backward, content, forward).
const fn head_dim(word: usize) -> usize {
    2 * word + 6
}

struct HeadStep {
    gate: WriteGate,
    /// w̃^R_{t-1}: moved off the recurrent state at write time; the read
    /// phase's link-follows and the backward pass both read it from here.
    w_read_used: SparseVec,
    write_word: Vec<f32>,
    read: ContentRead,
    query: Vec<f32>,
    modes: [f32; 3],
    fwd: SparseVec,
    bwd: SparseVec,
    w_read: SparseVec,
}

/// Saved linkage rows for rollback, captured *by move* (None = the row did
/// not exist before this step).
#[derive(Default)]
struct LinkJournal {
    n_rows: Vec<(usize, Option<SparseVec>)>,
    p_rows: Vec<(usize, Option<SparseVec>)>,
    precedence: SparseVec,
}

struct SdncStep {
    heads: Vec<HeadStep>,
    links: LinkJournal,
}

pub struct SdncCore {
    cfg: CoreConfig,
    ctrl: Controller,
    engine: ShardedMemoryEngine,
    /// Engine seeds recorded for [`SdncCore::infer_session`] parity.
    mem_seed: u64,
    ann_seed: u64,
    n_link: SparseLinkMatrix,
    p_link: SparseLinkMatrix,
    precedence: SparseVec,
    w_read_prev: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    tape: Vec<SdncStep>,
    /// The step under construction between `mem_stage_phase` and
    /// `mem_finish_phase` (the batched tick interleaves other lanes'
    /// phases in between; `None` on the serial path outside a step).
    staged_step: Option<SdncStep>,
    // carried backward state
    d_r: Vec<Vec<f32>>,
    d_wread: Vec<SparseVec>,
    /// Linkage contribution to next step's carried d_wread, staged per head
    /// during the read backward before the gate contribution folds in.
    d_wread_next: Vec<SparseVec>,
    // pooled / persistent step scratch
    ws: Workspace,
    queries: Vec<Vec<f32>>,
    betas: Vec<f32>,
    content_tmp: Vec<ContentRead>,
    spare_steps: Vec<SdncStep>,
    dp_buf: Vec<f32>,
    dr_buf: Vec<f32>,
    dq_buf: Vec<f32>,
    da_buf: Vec<f32>,
    dweights_buf: Vec<f32>,
    /// P-row affected-set staging for `update_links_into` (persistent: its
    /// size varies step to step, which defeats the pool's capacity classes).
    affected_buf: Vec<usize>,
}

impl SdncCore {
    pub fn new(cfg: &CoreConfig, rng: &mut Rng) -> SdncCore {
        let mut rng = Rng::new(cfg.seed ^ rng.next_u64());
        let ctrl = Controller::new(
            "sdnc",
            cfg.x_dim,
            cfg.y_dim,
            cfg.hidden,
            cfg.heads,
            cfg.word,
            head_dim(cfg.word),
            &mut rng,
        );
        // Same seed draw order as `SparseMemoryEngine::new_sparse`.
        let mem_seed = rng.next_u64();
        let ann_seed = rng.next_u64();
        let engine = ShardedMemoryEngine::new_sparse_from_seeds_fmt(
            cfg.mem_words,
            cfg.word,
            cfg.k,
            cfg.delta,
            cfg.ann,
            mem_seed,
            ann_seed,
            cfg.shards,
            cfg.row_format,
        );
        SdncCore {
            ctrl,
            engine,
            mem_seed,
            ann_seed,
            n_link: SparseLinkMatrix::new(cfg.k_l),
            p_link: SparseLinkMatrix::new(cfg.k_l),
            precedence: SparseVec::new(),
            w_read_prev: vec![SparseVec::new(); cfg.heads],
            r_prev: vec![vec![0.0; cfg.word]; cfg.heads],
            tape: Vec::new(),
            staged_step: None,
            d_r: vec![vec![0.0; cfg.word]; cfg.heads],
            d_wread: vec![SparseVec::new(); cfg.heads],
            d_wread_next: vec![SparseVec::new(); cfg.heads],
            ws: Workspace::new(),
            queries: vec![Vec::new(); cfg.heads],
            betas: vec![0.0; cfg.heads],
            content_tmp: Vec::new(),
            spare_steps: Vec::new(),
            dp_buf: Vec::new(),
            dr_buf: Vec::new(),
            dq_buf: Vec::new(),
            da_buf: Vec::new(),
            dweights_buf: Vec::new(),
            affected_buf: Vec::new(),
            cfg: cfg.clone(),
        }
    }

    /// f/b link-follow pair list: Σ_j w(j)·rows(j,:) over a row-sparse
    /// matrix, as (index, value) pairs for `assign_from_pairs` (duplicate
    /// indices combine by addition there, matching the old `from_pairs`).
    fn follow_pairs(link: &SparseLinkMatrix, w: &SparseVec, pairs: &mut Vec<(usize, f32)>) {
        pairs.clear();
        for (j, wj) in w.iter() {
            if let Some(row) = link.row(j) {
                for (i, v) in row.iter() {
                    pairs.push((i, wj * v));
                }
            }
        }
    }

    /// out = (1-wi)·old + wi·p_prev with the diagonal entry dropped
    /// (eq. 19's sparse N-row update as a sorted union merge).
    fn merge_n_row(
        old: Option<&SparseVec>,
        wi: f32,
        p_prev: &SparseVec,
        diag: usize,
        out: &mut SparseVec,
    ) {
        out.clear();
        let empty = SparseVec::new();
        let a = old.unwrap_or(&empty);
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.nnz() || j < p_prev.nnz() {
            let ai = if i < a.nnz() { a.idx[i] } else { usize::MAX };
            let pj = if j < p_prev.nnz() { p_prev.idx[j] } else { usize::MAX };
            if ai < pj {
                if ai != diag {
                    out.push(ai, (1.0 - wi) * a.val[i]);
                }
                i += 1;
            } else if pj < ai {
                if pj != diag {
                    out.push(pj, wi * p_prev.val[j]);
                }
                j += 1;
            } else {
                if ai != diag {
                    out.push(ai, (1.0 - wi) * a.val[i] + wi * p_prev.val[j]);
                }
                i += 1;
                j += 1;
            }
        }
    }

    /// Row i of the P update (eq. 20): entries at j ∈ supp(w), j ≠ i become
    /// (1-w(j))·P(i,j) + w(j)·p_prev(i) (dropped if exactly zero); all
    /// other entries of the old row survive unchanged. Sorted union merge —
    /// replaces the old per-row HashMap rebuild, same values.
    fn merge_p_row(
        old: Option<&SparseVec>,
        w: &SparseVec,
        p_prev_i: f32,
        diag: usize,
        out: &mut SparseVec,
    ) {
        out.clear();
        let empty = SparseVec::new();
        let a = old.unwrap_or(&empty);
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.nnz() || j < w.nnz() {
            let ai = if i < a.nnz() { a.idx[i] } else { usize::MAX };
            let wj = if j < w.nnz() { w.idx[j] } else { usize::MAX };
            if ai < wj {
                out.push(ai, a.val[i]);
                i += 1;
            } else if wj < ai {
                if wj != diag {
                    let nv = w.val[j] * p_prev_i;
                    if nv != 0.0 {
                        out.push(wj, nv);
                    }
                }
                j += 1;
            } else {
                if ai == diag {
                    // Diagonal updates are skipped: the old entry survives.
                    out.push(ai, a.val[i]);
                } else {
                    let nv = (1.0 - w.val[j]) * a.val[i] + w.val[j] * p_prev_i;
                    if nv != 0.0 {
                        out.push(ai, nv);
                    }
                }
                i += 1;
                j += 1;
            }
        }
    }

    /// Apply the sparse linkage update for aggregate write weights `w`,
    /// journaling replaced rows *by move* into `journal`. (eq. 17-20)
    fn update_links_into(&mut self, w: &SparseVec, journal: &mut LinkJournal) {
        debug_assert!(journal.n_rows.is_empty() && journal.p_rows.is_empty());
        // Old precedence moves into the journal and serves as p_prev below.
        journal.precedence = std::mem::replace(&mut self.precedence, self.ws.take_sparse());
        // N rows: N(i,:) = (1-w(i))·N(i,:) + w(i)·p_prev,  i ∈ supp(w), j ≠ i.
        for (i, wi) in w.iter() {
            let old = self.n_link.take_row(i);
            let mut new_row = self.ws.take_sparse();
            Self::merge_n_row(old.as_ref(), wi, &journal.precedence, i, &mut new_row);
            if let Some(displaced) = self.n_link.set_row_recycling(i, new_row) {
                self.ws.recycle_sparse(displaced);
            }
            journal.n_rows.push((i, old));
        }
        // P rows: affected = supp(p_prev) ∪ ∪_{j∈supp(w)} supp(N_old(j,:)).
        let mut affected = std::mem::take(&mut self.affected_buf);
        affected.clear();
        affected.extend(journal.precedence.idx.iter().copied());
        for (j, _) in w.iter() {
            for (old_j, old_row) in journal.n_rows.iter() {
                if *old_j == j {
                    if let Some(r) = old_row {
                        affected.extend(r.idx.iter().copied());
                    }
                }
            }
        }
        affected.sort_unstable();
        affected.dedup();
        for &i in affected.iter() {
            let old = self.p_link.take_row(i);
            let mut new_row = self.ws.take_sparse();
            Self::merge_p_row(old.as_ref(), w, journal.precedence.get(i), i, &mut new_row);
            if let Some(displaced) = self.p_link.set_row_recycling(i, new_row) {
                self.ws.recycle_sparse(displaced);
            }
            journal.p_rows.push((i, old));
        }
        self.affected_buf = affected;
        // precedence: p = (1-Σw)·p_prev + w, truncated to K_L.
        let sum_w = w.sum().min(1.0);
        let mut newp = std::mem::take(&mut self.precedence);
        w.add_scaled_into(1.0 - sum_w, &journal.precedence, &mut newp);
        newp.truncate_top_k(self.cfg.k_l);
        self.precedence = newp;
    }

    /// Test shim for the dense-reference linkage property test.
    #[cfg(test)]
    fn update_links(&mut self, w: &SparseVec) {
        let mut journal = LinkJournal::default();
        self.update_links_into(w, &mut journal);
    }

    /// Roll the linkage back one step, draining the journal and recycling
    /// every displaced row buffer.
    fn revert_links(&mut self, journal: &mut LinkJournal) {
        while let Some((i, old)) = journal.p_rows.pop() {
            if let Some(cur) = self.p_link.take_row(i) {
                self.ws.recycle_sparse(cur);
            }
            if let Some(row) = old {
                self.p_link.set_row(i, row);
            }
        }
        while let Some((i, old)) = journal.n_rows.pop() {
            if let Some(cur) = self.n_link.take_row(i) {
                self.ws.recycle_sparse(cur);
            }
            if let Some(row) = old {
                self.n_link.set_row(i, row);
            }
        }
        let prev = std::mem::take(&mut journal.precedence);
        let cur = std::mem::replace(&mut self.precedence, prev);
        self.ws.recycle_sparse(cur);
    }

    // -- forward-only inference (shared weights, detached state) ------------

    /// Open a detached inference session (see [`crate::cores::sam::SamCore::infer_session`]
    /// for the seed contract: `None` = bit-parity with the trained core).
    pub fn infer_session(&self, seed: Option<u64>) -> SdncSession {
        let (mem_seed, ann_seed) = match seed {
            None => (self.mem_seed, self.ann_seed),
            Some(s) => {
                let mut r = Rng::new(s);
                (r.next_u64(), r.next_u64())
            }
        };
        SdncSession {
            ctrl: self.ctrl.new_state(),
            engine: ShardedMemoryEngine::new_sparse_from_seeds_fmt(
                self.cfg.mem_words,
                self.cfg.word,
                self.cfg.k,
                self.cfg.delta,
                self.cfg.ann,
                mem_seed,
                ann_seed,
                self.cfg.shards,
                self.cfg.row_format,
            ),
            n_link: SparseLinkMatrix::new(self.cfg.k_l),
            p_link: SparseLinkMatrix::new(self.cfg.k_l),
            precedence: SparseVec::new(),
            w_read_prev: vec![SparseVec::new(); self.cfg.heads],
            w_read_used: vec![SparseVec::new(); self.cfg.heads],
            r_prev: vec![vec![0.0; self.cfg.word]; self.cfg.heads],
            ws: Workspace::new(),
            queries: vec![Vec::new(); self.cfg.heads],
            betas: vec![0.0; self.cfg.heads],
            content_tmp: Vec::new(),
            affected_buf: Vec::new(),
        }
    }

    /// One forward-only step: bit-identical to [`Core::forward_into`] on a
    /// freshly reset core for matching seeds, with no journals (memory or
    /// linkage) and zero tape bytes.
    pub fn infer_step(&self, st: &mut SdncSession, x: &[f32], y: &mut Vec<f32>) {
        self.ctrl.infer_step(&mut st.ctrl, x, &st.r_prev);
        self.infer_mem_phase(st);
        self.ctrl.infer_output(&mut st.ctrl, &st.r_prev, y);
    }

    /// Batched serving tick (see [`super::infer_tick`]).
    pub fn infer_step_batch(
        &self,
        batch: &mut CtrlBatch,
        sessions: &mut [&mut SdncSession],
        xs: &[&[f32]],
        ys: &mut [Vec<f32>],
    ) {
        super::infer_tick(
            &self.ctrl,
            batch,
            sessions,
            xs,
            ys,
            |s| &mut s.ctrl,
            |s| &s.r_prev,
            |s| self.infer_mem_phase(s),
        );
    }

    /// Memory + linkage phase of an infer step: SAM-style journal-free
    /// writes, the sparse temporal-link update with displaced rows recycled
    /// instead of journaled, then the 3-way mixed reads.
    fn infer_mem_phase(&self, st: &mut SdncSession) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        // --- writes (aggregate weights feed the link update, eq. 17-20) ---
        let mut w_agg = st.ws.take_sparse();
        for hi in 0..self.cfg.heads {
            let (ar, gr) = (st.ctrl.p[hi * hd + 2 * w], st.ctrl.p[hi * hd + 2 * w + 1]);
            st.w_read_used[hi] = std::mem::take(&mut st.w_read_prev[hi]);
            let wts = st.engine.infer_write(
                ar,
                gr,
                &st.w_read_used[hi],
                &st.ctrl.p[hi * hd + w..hi * hd + 2 * w],
                &mut st.ws,
            );
            let mut merged = st.ws.take_sparse();
            w_agg.add_into(&wts, &mut merged);
            std::mem::swap(&mut w_agg, &mut merged);
            st.ws.recycle_sparse(merged);
            st.ws.recycle_sparse(wts);
        }
        let s = w_agg.sum();
        if s > 1.0 {
            w_agg.scale(1.0 / s);
        }
        self.infer_update_links(st, &w_agg);
        st.ws.recycle_sparse(w_agg);

        // --- reads: 3-way mix of content / forward-link / backward-link ---
        for hi in 0..self.cfg.heads {
            st.queries[hi].clear();
            st.queries[hi].extend_from_slice(&st.ctrl.p[hi * hd..hi * hd + w]);
            st.betas[hi] = st.ctrl.p[hi * hd + 2 * w + 2];
        }
        debug_assert!(st.content_tmp.is_empty());
        let mut crs = std::mem::take(&mut st.content_tmp);
        st.engine.content_read_many_into(&st.queries, &st.betas, &mut crs, &mut st.ws);
        for (hi, read) in crs.drain(..).enumerate() {
            let mut modes = [
                st.ctrl.p[hi * hd + 2 * w + 3],
                st.ctrl.p[hi * hd + 2 * w + 4],
                st.ctrl.p[hi * hd + 2 * w + 5],
            ];
            softmax_inplace(&mut modes);
            let mut fwd = st.ws.take_sparse();
            let mut bwd = st.ws.take_sparse();
            let mut pairs = st.ws.take_pairs();
            {
                let wp = &st.w_read_used[hi];
                Self::follow_pairs(&st.p_link, wp, &mut pairs);
                fwd.assign_from_pairs(&mut pairs);
                Self::follow_pairs(&st.n_link, wp, &mut pairs);
                bwd.assign_from_pairs(&mut pairs);
            }
            pairs.clear();
            pairs.extend(
                read.rows
                    .iter()
                    .copied()
                    .zip(read.weights.iter().map(|&v| v * modes[1])),
            );
            let mut content_part = st.ws.take_sparse();
            content_part.assign_from_pairs(&mut pairs);
            st.ws.recycle_pairs(pairs);
            let mut mixed = st.ws.take_sparse();
            content_part.add_scaled_into(modes[0], &bwd, &mut mixed);
            let mut w_read = st.ws.take_sparse();
            mixed.add_scaled_into(modes[2], &fwd, &mut w_read);
            st.ws.recycle_sparse(content_part);
            st.ws.recycle_sparse(mixed);
            w_read.truncate_top_k(self.cfg.k + 2 * self.cfg.k_l);
            st.engine.read_mixture_into(&w_read, &mut st.r_prev[hi]);
            let old = std::mem::replace(&mut st.w_read_prev[hi], w_read);
            st.ws.recycle_sparse(old);
            st.ws.recycle_sparse(fwd);
            st.ws.recycle_sparse(bwd);
            st.engine.recycle_content_read(read, &mut st.ws);
            let used = std::mem::take(&mut st.w_read_used[hi]);
            st.ws.recycle_sparse(used);
        }
        st.content_tmp = crs;
    }

    /// The sparse linkage update (eq. 17-20) without journaling: displaced
    /// N/P rows and the old precedence recycle into the session workspace
    /// instead of onto a rollback tape. Same merge math and row-visit order
    /// as [`SdncCore::update_links_into`], so values are bit-identical.
    fn infer_update_links(&self, st: &mut SdncSession, w: &SparseVec) {
        let p_prev = std::mem::replace(&mut st.precedence, st.ws.take_sparse());
        let mut affected = std::mem::take(&mut st.affected_buf);
        affected.clear();
        affected.extend(p_prev.idx.iter().copied());
        for (i, wi) in w.iter() {
            let old = st.n_link.take_row(i);
            if let Some(r) = &old {
                affected.extend(r.idx.iter().copied());
            }
            let mut new_row = st.ws.take_sparse();
            Self::merge_n_row(old.as_ref(), wi, &p_prev, i, &mut new_row);
            if let Some(displaced) = st.n_link.set_row_recycling(i, new_row) {
                st.ws.recycle_sparse(displaced);
            }
            if let Some(old) = old {
                st.ws.recycle_sparse(old);
            }
        }
        affected.sort_unstable();
        affected.dedup();
        for &i in affected.iter() {
            let old = st.p_link.take_row(i);
            let mut new_row = st.ws.take_sparse();
            Self::merge_p_row(old.as_ref(), w, p_prev.get(i), i, &mut new_row);
            if let Some(displaced) = st.p_link.set_row_recycling(i, new_row) {
                st.ws.recycle_sparse(displaced);
            }
            if let Some(old) = old {
                st.ws.recycle_sparse(old);
            }
        }
        st.affected_buf = affected;
        let sum_w = w.sum().min(1.0);
        let mut newp = std::mem::take(&mut st.precedence);
        w.add_scaled_into(1.0 - sum_w, &p_prev, &mut newp);
        newp.truncate_top_k(self.cfg.k_l);
        st.precedence = newp;
        st.ws.recycle_sparse(p_prev);
    }

    /// Heap bytes of the trained parameters.
    pub fn params_heap_bytes(&self) -> usize {
        self.ctrl.params_heap_bytes()
    }

    pub fn params_len(&self) -> usize {
        self.ctrl.params_len()
    }

    /// Recycle a popped tape step's buffers and park its shell.
    fn recycle_step(&mut self, mut step: SdncStep) {
        debug_assert!(step.links.n_rows.is_empty() && step.links.p_rows.is_empty());
        for h in step.heads.drain(..) {
            self.ws.recycle_f32(h.write_word);
            self.ws.recycle_f32(h.query);
            self.ws.recycle_sparse(h.gate.weights);
            self.ws.recycle_sparse(h.w_read_used);
            self.ws.recycle_sparse(h.fwd);
            self.ws.recycle_sparse(h.bwd);
            self.ws.recycle_sparse(h.w_read);
            self.engine.recycle_content_read(h.read, &mut self.ws);
        }
        self.spare_steps.push(step);
    }

    // -- memory-phase seams (shared by the serial path and the batched
    //    training tick; consume the raw head params in `self.ctrl`) --------

    /// F6a: per-head gated writes aggregating the link-update weights, the
    /// sparse temporal-linkage update (eq. 17-20, journaled into the step),
    /// and content-query staging — everything up to the ANN lookup.
    fn mem_stage_phase(&mut self) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        let mut step = self.spare_steps.pop().unwrap_or_else(|| SdncStep {
            heads: Vec::new(),
            links: LinkJournal::default(),
        });
        debug_assert!(step.heads.is_empty());

        // --- SAM-style sparse writes (engine journals + syncs the ANN) ---
        let mut w_agg = self.ws.take_sparse();
        for hi in 0..self.cfg.heads {
            let (ar, gr) = {
                let p = self.ctrl.head_params();
                (p[hi * hd + 2 * w], p[hi * hd + 2 * w + 1])
            };
            let a = {
                let p = self.ctrl.head_params();
                self.ws.take_f32_copy(&p[hi * hd + w..hi * hd + 2 * w])
            };
            let gate =
                self.engine.sparse_write(ar, gr, &self.w_read_prev[hi], &a, &mut self.ws);
            let mut merged = self.ws.take_sparse();
            w_agg.add_into(&gate.weights, &mut merged);
            std::mem::swap(&mut w_agg, &mut merged);
            self.ws.recycle_sparse(merged);
            step.heads.push(HeadStep {
                gate,
                w_read_used: std::mem::take(&mut self.w_read_prev[hi]),
                write_word: a,
                // placeholder read fields, filled by `mem_finish_phase`
                read: ContentRead::empty(),
                query: Vec::new(),
                modes: [0.0; 3],
                fwd: SparseVec::new(),
                bwd: SparseVec::new(),
                w_read: SparseVec::new(),
            });
        }

        // --- sparse temporal linkage update (eq. 17-20) ---
        let s = w_agg.sum();
        if s > 1.0 {
            w_agg.scale(1.0 / s);
        }
        self.update_links_into(&w_agg, &mut step.links);
        self.ws.recycle_sparse(w_agg);

        for hi in 0..self.cfg.heads {
            let p = self.ctrl.head_params();
            self.queries[hi].clear();
            self.queries[hi].extend_from_slice(&p[hi * hd..hi * hd + w]);
            self.betas[hi] = p[hi * hd + 2 * w + 2];
        }
        self.staged_step = Some(step);
    }

    /// F6b: run the ANN lookup over the staged queries into the engine's
    /// neighbour lists. `nested` keeps the fill strictly serial (the batched
    /// tick's merged dispatch already runs each lane on a pool worker).
    fn ann_fill_phase(&mut self, nested: bool) {
        if self.staged_step.is_none() {
            return;
        }
        self.engine.ann_fill_neigh(&self.queries, nested);
    }

    /// F6c: finish the reads from the filled neighbour lists — the 3-way
    /// mix of content / forward-link / backward-link per head (eq. 21-22) —
    /// update the recurrent read state and push the completed step.
    fn mem_finish_phase(&mut self) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        let mut step = self.staged_step.take().expect("mem_finish without mem_stage");
        debug_assert!(self.content_tmp.is_empty());
        let mut crs = std::mem::take(&mut self.content_tmp);
        self.engine.content_read_many_from_neigh(
            &self.queries,
            &self.betas,
            &mut crs,
            &mut self.ws,
        );
        for (hi, read) in crs.drain(..).enumerate() {
            let mut modes = {
                let p = self.ctrl.head_params();
                [p[hi * hd + 2 * w + 3], p[hi * hd + 2 * w + 4], p[hi * hd + 2 * w + 5]]
            };
            softmax_inplace(&mut modes);
            let mut fwd = self.ws.take_sparse();
            let mut bwd = self.ws.take_sparse();
            let mut pairs = self.ws.take_pairs();
            {
                let wp = &step.heads[hi].w_read_used;
                // f = Σ w(j)·P(j,:) = N·w ; b = Σ w(j)·N(j,:) = Nᵀ·w = P·w
                Self::follow_pairs(&self.p_link, wp, &mut pairs);
                fwd.assign_from_pairs(&mut pairs);
                Self::follow_pairs(&self.n_link, wp, &mut pairs);
                bwd.assign_from_pairs(&mut pairs);
            }
            // w_read = modes[1]·content + modes[0]·bwd + modes[2]·fwd.
            pairs.clear();
            pairs.extend(
                read.rows
                    .iter()
                    .copied()
                    .zip(read.weights.iter().map(|&v| v * modes[1])),
            );
            let mut content_part = self.ws.take_sparse();
            content_part.assign_from_pairs(&mut pairs);
            self.ws.recycle_pairs(pairs);
            let mut mixed = self.ws.take_sparse();
            content_part.add_scaled_into(modes[0], &bwd, &mut mixed);
            let mut w_read = self.ws.take_sparse();
            mixed.add_scaled_into(modes[2], &fwd, &mut w_read);
            self.ws.recycle_sparse(content_part);
            self.ws.recycle_sparse(mixed);
            w_read.truncate_top_k(self.cfg.k + 2 * self.cfg.k_l);
            self.engine.read_mixture_into(&w_read, &mut self.r_prev[hi]);
            self.w_read_prev[hi] = self.ws.take_sparse_copy(&w_read);
            let hstep = &mut step.heads[hi];
            hstep.read = read;
            hstep.query = self.ws.take_f32_copy(&self.queries[hi]);
            hstep.modes = modes;
            hstep.fwd = fwd;
            hstep.bwd = bwd;
            hstep.w_read = w_read;
        }
        self.content_tmp = crs;
        self.tape.push(step);
    }

    /// B4: memory backward for one step — read backward (mode mixture,
    /// content path, link follows) over M_t/N_t/P_t, write backward in
    /// reverse head order rolling memory back, then the linkage rollback to
    /// N_{t-1}/P_{t-1} — filling `self.dp_buf` with the raw head-parameter
    /// gradient.
    fn backward_mem_phase(&mut self, step: &mut SdncStep) {
        let w = self.cfg.word;
        let hd = head_dim(w);
        self.dp_buf.clear();
        self.dp_buf.resize(self.cfg.heads * hd, 0.0);

        // --- read backward (memory = M_t, links = N_t/P_t) ---
        for (hi, hstep) in step.heads.iter().enumerate() {
            self.dr_buf.clear();
            self.dr_buf.extend_from_slice(&self.ctrl.dreads()[hi]);
            axpy(&mut self.dr_buf, 1.0, &self.d_r[hi]);
            // dL/dw_read over supp(w_read), plus the carried gradient from
            // step t+1's uses of w_read (gate + linkage).
            let dw_read = self.engine.backward_sparse_read(
                &hstep.w_read,
                &self.dr_buf,
                &self.d_wread[hi],
                &mut self.ws,
            );
            // mode mixture backward
            let dmodes = [
                dw_read.dot_sparse(&hstep.bwd),
                hstep
                    .read
                    .rows
                    .iter()
                    .zip(&hstep.read.weights)
                    .map(|(&i, &v)| v * dw_read.get(i))
                    .sum::<f32>(),
                dw_read.dot_sparse(&hstep.fwd),
            ];
            let mut dmode_logits = [0.0f32; 3];
            softmax_backward(&hstep.modes, &dmodes, &mut dmode_logits);
            {
                let ph = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
                for k in 0..3 {
                    ph[2 * w + 3 + k] += dmode_logits[k];
                }
            }
            // content path
            self.dweights_buf.clear();
            self.dweights_buf.extend(
                hstep.read.rows.iter().map(|&i| hstep.modes[1] * dw_read.get(i)),
            );
            self.dq_buf.clear();
            self.dq_buf.resize(w, 0.0);
            let mut dbeta_raw = 0.0f32;
            self.engine.backward_content(
                &hstep.read,
                &hstep.query,
                &self.dweights_buf,
                &mut self.dq_buf,
                &mut dbeta_raw,
                &mut self.ws,
            );
            {
                let ph = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
                ph[..w].iter_mut().zip(&self.dq_buf).for_each(|(a, b)| *a += b);
                ph[2 * w + 2] += dbeta_raw;
            }
            // linkage path: f = Σ_j wp(j)·P(j,:) ⇒ dwp(j) = P(j,:)·df;
            //               b = Σ_j wp(j)·N(j,:) ⇒ dwp(j) = N(j,:)·db.
            let mut df = self.ws.take_sparse_copy(&dw_read);
            df.scale(hstep.modes[2]);
            let mut db = self.ws.take_sparse_copy(&dw_read);
            db.scale(hstep.modes[0]);
            let mut dnext = self.ws.take_sparse();
            // wp at read time == w_read_prev before this step's reads.
            for (j, _) in hstep.w_read_used.iter() {
                let mut g = 0.0;
                if let Some(prow) = self.p_link.row(j) {
                    g += prow.dot_sparse(&df);
                }
                if let Some(nrow) = self.n_link.row(j) {
                    g += nrow.dot_sparse(&db);
                }
                dnext.push(j, g);
            }
            let old = std::mem::replace(&mut self.d_wread_next[hi], dnext);
            self.ws.recycle_sparse(old);
            self.ws.recycle_sparse(df);
            self.ws.recycle_sparse(db);
            self.ws.recycle_sparse(dw_read);
        }

        // --- write backward (reverse head order, rolling memory back) ---
        for hi in (0..self.cfg.heads).rev() {
            let hstep = &step.heads[hi];
            let (mut dar, mut dgr) = (0.0f32, 0.0f32);
            self.da_buf.clear();
            self.da_buf.resize(w, 0.0);
            let dw_prev = self.engine.backward_write_into(
                &hstep.gate,
                &hstep.write_word,
                &hstep.w_read_used,
                &mut dar,
                &mut dgr,
                &mut self.da_buf,
                &mut self.ws,
            );
            let mut total = self.ws.take_sparse();
            self.d_wread_next[hi].add_into(&dw_prev, &mut total);
            self.ws.recycle_sparse(dw_prev);
            let old = std::mem::replace(&mut self.d_wread[hi], total);
            self.ws.recycle_sparse(old);
            let ph = &mut self.dp_buf[hi * hd..(hi + 1) * hd];
            ph[w..2 * w].iter_mut().zip(&self.da_buf).for_each(|(x, d)| *x += d);
            ph[2 * w] += dar;
            ph[2 * w + 1] += dgr;
        }

        // Roll the linkage back to N_{t-1}/P_{t-1}.
        let mut links = std::mem::take(&mut step.links);
        self.revert_links(&mut links);
        step.links = links;
    }
}

/// Detached per-session episodic state for SDNC serving: controller h/c,
/// private memory engine (no journals), sparse temporal-link state and the
/// buffer pools. Parameters live in the shared [`SdncCore`].
pub struct SdncSession {
    ctrl: ControllerState,
    engine: ShardedMemoryEngine,
    n_link: SparseLinkMatrix,
    p_link: SparseLinkMatrix,
    precedence: SparseVec,
    w_read_prev: Vec<SparseVec>,
    /// w̃^R_{t-1} staged per head for this step's write gate + link follows.
    w_read_used: Vec<SparseVec>,
    r_prev: Vec<Vec<f32>>,
    ws: Workspace,
    queries: Vec<Vec<f32>>,
    betas: Vec<f32>,
    content_tmp: Vec<ContentRead>,
    affected_buf: Vec<usize>,
}

impl SdncSession {
    /// Start a new episode: memory re-seeded, linkage cleared, recurrent
    /// state zeroed. Allocation-free once the pools are warm.
    pub fn reset(&mut self) {
        self.ctrl.reset();
        self.engine.reinit();
        for (_, r) in self.n_link.rows.drain() {
            self.ws.recycle_sparse(r);
        }
        for (_, r) in self.p_link.rows.drain() {
            self.ws.recycle_sparse(r);
        }
        let old = std::mem::take(&mut self.precedence);
        self.ws.recycle_sparse(old);
        for hi in 0..self.w_read_prev.len() {
            let old = std::mem::take(&mut self.w_read_prev[hi]);
            self.ws.recycle_sparse(old);
            let old = std::mem::take(&mut self.w_read_used[hi]);
            self.ws.recycle_sparse(old);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        let links: usize = self
            .n_link
            .rows
            .values()
            .chain(self.p_link.rows.values())
            .map(|r| r.heap_bytes() + 64)
            .sum();
        self.engine.heap_bytes()
            + self.ws.heap_bytes()
            + self.ctrl.heap_bytes()
            + links
            + self.precedence.heap_bytes()
            + self
                .w_read_prev
                .iter()
                .chain(self.w_read_used.iter())
                .map(|v| v.heap_bytes())
                .sum::<usize>()
            + self.r_prev.iter().map(|r| r.capacity() * 4).sum::<usize>()
            + self.queries.iter().map(|q| q.capacity() * 4).sum::<usize>()
    }

    pub fn tape_bytes(&self) -> usize {
        self.engine.tape_bytes()
    }
}

impl HasParams for SdncCore {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.ctrl.visit_params(f);
    }
}

impl Core for SdncCore {
    fn name(&self) -> &'static str {
        "sdnc"
    }

    fn reset(&mut self) {
        self.ctrl.reset();
        // Abandoned episodes: revert outstanding linkage journals in
        // reverse order, recycling as we go, then clear defensively.
        if let Some(mut step) = self.staged_step.take() {
            let mut links = std::mem::take(&mut step.links);
            self.revert_links(&mut links);
            step.links = links;
            self.recycle_step(step);
        }
        while let Some(mut step) = self.tape.pop() {
            let mut links = std::mem::take(&mut step.links);
            self.revert_links(&mut links);
            step.links = links;
            self.recycle_step(step);
        }
        self.engine.reset(&mut self.ws);
        let n_rows: Vec<SparseVec> = self.n_link.rows.drain().map(|(_, r)| r).collect();
        for r in n_rows {
            self.ws.recycle_sparse(r);
        }
        let p_rows: Vec<SparseVec> = self.p_link.rows.drain().map(|(_, r)| r).collect();
        for r in p_rows {
            self.ws.recycle_sparse(r);
        }
        let old = std::mem::take(&mut self.precedence);
        self.ws.recycle_sparse(old);
        for hi in 0..self.cfg.heads {
            let old = std::mem::take(&mut self.w_read_prev[hi]);
            self.ws.recycle_sparse(old);
            let old = std::mem::take(&mut self.d_wread[hi]);
            self.ws.recycle_sparse(old);
            let old = std::mem::take(&mut self.d_wread_next[hi]);
            self.ws.recycle_sparse(old);
        }
        for r in &mut self.r_prev {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
        for r in &mut self.d_r {
            r.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        self.ctrl.step_hot(x, &self.r_prev);
        // The same memory-phase seams the batched tick drives, back to back.
        self.mem_stage_phase();
        self.ann_fill_phase(false);
        self.mem_finish_phase();
        self.ctrl.output_hot(&self.r_prev, y);
    }

    fn backward(&mut self, dy: &[f32]) {
        let mut step = self.tape.pop().expect("backward without forward");
        self.ctrl.backward_output_hot(dy);
        self.backward_mem_phase(&mut step);
        self.ctrl.backward_step_hot(&self.dp_buf, &mut self.d_r);
        self.recycle_step(step);
    }

    fn rollback(&mut self) {
        self.engine.rollback_ws(&mut self.ws);
        while let Some(mut step) = self.tape.pop() {
            let mut links = std::mem::take(&mut step.links);
            self.revert_links(&mut links);
            step.links = links;
            self.recycle_step(step);
        }
    }

    fn end_episode(&mut self) {
        debug_assert!(self.tape.is_empty());
        self.engine.end_episode();
    }

    fn x_dim(&self) -> usize {
        self.cfg.x_dim
    }

    fn y_dim(&self) -> usize {
        self.cfg.y_dim
    }

    fn tape_bytes(&self) -> usize {
        let step: usize = self
            .tape
            .iter()
            .map(|s| {
                let link_bytes: usize = s
                    .links
                    .n_rows
                    .iter()
                    .chain(s.links.p_rows.iter())
                    .map(|(_, r)| r.as_ref().map(|x| x.heap_bytes()).unwrap_or(0) + 24)
                    .sum::<usize>()
                    + s.links.precedence.heap_bytes();
                link_bytes
                    + s.heads
                        .iter()
                        .map(|h| {
                            h.w_read_used.heap_bytes()
                                + h.w_read.heap_bytes()
                                + h.fwd.heap_bytes()
                                + h.bwd.heap_bytes()
                                + h.gate.weights.heap_bytes()
                                + (h.write_word.capacity() + h.query.capacity()) * 4
                                + h.read.rows.capacity() * 8
                                + h.read.weights.capacity() * 4
                                + h.read.sims.capacity() * 12
                        })
                        .sum::<usize>()
            })
            .sum();
        step + self.engine.tape_bytes() + self.ctrl.cache_bytes()
    }
}

/// Batched-training seams: the controller hooks delegate to the shared
/// [`Controller`] staging methods; the memory phases are the same
/// `mem_*_phase`/`backward_mem_phase` bodies the serial path runs back to
/// back (one code path, bit-identical by construction).
impl BatchCore for SdncCore {
    fn cell_in_dim(&self) -> usize {
        self.ctrl.lstm.input
    }

    fn cell_hidden(&self) -> usize {
        self.ctrl.lstm.hidden
    }

    fn head_param_dim(&self) -> usize {
        self.cfg.heads * head_dim(self.cfg.word)
    }

    fn out_in_dim(&self) -> usize {
        self.ctrl.out_lin.in_dim()
    }

    fn weights(&self) -> LaneWeights<'_> {
        LaneWeights {
            wx: &self.ctrl.lstm.wx.w,
            wh: &self.ctrl.lstm.wh.w,
            head: Some((&self.ctrl.head_lin.w.w, &self.ctrl.head_lin.b.w.data)),
            out: (&self.ctrl.out_lin.w.w, &self.ctrl.out_lin.b.w.data),
        }
    }

    fn stage_input(&self, x: &[f32], x_row: &mut [f32], h_row: &mut [f32]) {
        self.ctrl.stage_input_row(x, &self.r_prev, x_row, h_row);
    }

    fn cell_step(&mut self, x_row: &[f32], zx_row: &mut [f32], zh_row: &[f32]) {
        self.ctrl.cell_step_row(x_row, zx_row, zh_row);
    }

    fn h(&self) -> &[f32] {
        self.ctrl.h()
    }

    fn note_head_forward(&mut self, p_row: &[f32]) {
        self.ctrl.note_head_forward(p_row);
    }

    fn mem_stage(&mut self) {
        self.mem_stage_phase();
    }

    fn ann_fill(&mut self, nested: bool) {
        self.ann_fill_phase(nested);
    }

    fn ann_fill_rows(&self) -> usize {
        if self.staged_step.is_some() {
            self.cfg.mem_words
        } else {
            0
        }
    }

    fn mem_finish(&mut self) {
        self.mem_finish_phase();
    }

    fn stage_output(&self, o_row: &mut [f32]) {
        self.ctrl.stage_output_row(&self.r_prev, o_row);
    }

    fn note_forward_out(&mut self, o_row: &[f32]) {
        self.ctrl.note_forward_out(o_row);
    }

    fn note_output_backward(&mut self, dy: &[f32], d_o_row: &[f32]) {
        self.ctrl.note_output_backward(dy, d_o_row);
    }

    fn backward_mem(&mut self) {
        let mut step = self.tape.pop().expect("backward without forward");
        self.backward_mem_phase(&mut step);
        self.recycle_step(step);
    }

    fn dp(&self) -> &[f32] {
        &self.dp_buf
    }

    fn backward_cell_z(&mut self, dh_row: &mut [f32], dz_row: &mut [f32]) {
        self.ctrl.backward_cell_z_row(&self.dp_buf, dh_row, dz_row);
    }

    fn finish_backward(&mut self, dz_row: &[f32], dh_prev_row: &[f32], dx_row: &[f32]) {
        self.ctrl.finish_backward_row(dz_row, dh_prev_row, dx_row, &mut self.d_r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::grad_check::*;

    fn small_cfg(seed: u64) -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 10,
            heads: 2,
            word: 5,
            mem_words: 16,
            k: 3,
            k_l: 4,
            ann: AnnKind::Linear,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn gradients_match_fd() {
        let mut rng = Rng::new(43);
        let mut core = SdncCore::new(&small_cfg(43), &mut rng);
        let (xs, ts) = random_episode(4, 3, 4, &mut rng);
        let (checked, failed) =
            check_core_gradients(&mut core, &xs, &ts, &mut rng, 6, 1e-2, 0.25);
        assert!(checked >= 30);
        assert!(failed * 10 <= checked, "{failed}/{checked} failed");
    }

    #[test]
    fn memory_and_links_roll_back() {
        let mut rng = Rng::new(44);
        let mut core = SdncCore::new(&small_cfg(44), &mut rng);
        core.reset();
        let start = core.engine.snapshot();
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let mut dys = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let y = core.forward(x);
            dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
        }
        assert!(core.n_link.nnz() > 0, "writes should populate the linkage");
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        assert_eq!(core.engine.snapshot(), start);
        assert_eq!(core.n_link.nnz(), 0, "linkage must roll back to empty");
        assert_eq!(core.p_link.nnz(), 0);
        assert_eq!(core.precedence.nnz(), 0);
    }

    #[test]
    fn pooled_episodes_are_bit_identical() {
        let mut rng = Rng::new(48);
        let mut core = SdncCore::new(&small_cfg(48), &mut rng);
        let (xs, ts) = random_episode(4, 3, 5, &mut rng);
        let mut y = Vec::new();
        let mut first: Vec<Vec<u32>> = Vec::new();
        for ep in 0..4 {
            core.zero_grads();
            core.reset();
            let mut dys = Vec::new();
            let mut bits: Vec<Vec<u32>> = Vec::new();
            for (x, t) in xs.iter().zip(&ts) {
                core.forward_into(x, &mut y);
                bits.push(y.iter().map(|v| v.to_bits()).collect());
                dys.push(crate::nn::loss::sigmoid_xent(&y, t).1);
            }
            for dy in dys.iter().rev() {
                core.backward(dy);
            }
            core.end_episode();
            if ep == 0 {
                first = bits;
            } else {
                assert_eq!(first, bits, "episode {ep} diverged bitwise");
            }
        }
    }

    #[test]
    fn infer_session_matches_train_forward_bitwise() {
        let mut rng = Rng::new(51);
        let mut core = SdncCore::new(&small_cfg(51), &mut rng);
        let (xs, _) = random_episode(4, 3, 6, &mut rng);
        let mut st = core.infer_session(None);
        let mut yi = Vec::new();
        for ep in 0..2 {
            core.reset();
            for x in &xs {
                let yt = core.forward(x);
                core.infer_step(&mut st, x, &mut yi);
                for (a, b) in yt.iter().zip(&yi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            core.rollback();
            core.end_episode();
            st.reset();
            assert_eq!(st.tape_bytes(), 0);
            assert_eq!(st.n_link.rows.len(), 0, "reset must clear the linkage");
        }
    }

    /// The sparse linkage must approximate the dense DNC linkage on the
    /// common support: simulate both for a few steps of random sparse
    /// writes and compare f/b reads.
    #[test]
    fn sparse_links_track_dense_reference() {
        let n = 12;
        let k_l = 12; // no truncation -> should match the dense recurrence
        let mut rng = Rng::new(45);
        let mut core = SdncCore::new(&CoreConfig { mem_words: n, k_l, ..small_cfg(45) }, &mut rng);
        // dense reference
        let mut l_dense = vec![vec![0.0f32; n]; n];
        let mut p_dense = vec![0.0f32; n];
        for _ in 0..8 {
            let k = rng.int_in(1, 3);
            let idx = rng.sample_indices(n, k);
            let mut w = SparseVec::from_pairs(
                idx.iter().map(|&i| (i, rng.uniform() * 0.5)).collect(),
            );
            let s = w.sum();
            if s > 1.0 {
                w.scale(1.0 / s);
            }
            core.update_links(&w);
            // dense update
            let wd = w.to_dense(n);
            for i in 0..n {
                for j in 0..n {
                    if i == j {
                        l_dense[i][j] = 0.0;
                    } else {
                        l_dense[i][j] =
                            (1.0 - wd[i] - wd[j]) * l_dense[i][j] + wd[i] * p_dense[j];
                    }
                }
            }
            let sum_w: f32 = wd.iter().sum();
            for i in 0..n {
                p_dense[i] = (1.0 - sum_w) * p_dense[i] + wd[i];
            }
        }
        // Compare N against the "decay only on write rows" sparse recurrence:
        // rows never written stay zero in both. For written rows the sparse
        // N uses (1-w(i)) where dense L uses (1-w(i)-w(j)); tolerance is
        // loose to cover that deliberate approximation (eq. 19 vs 13).
        let wp = SparseVec::from_pairs((0..n).map(|i| (i, 1.0 / n as f32)).collect());
        let mut pairs = Vec::new();
        SdncCore::follow_pairs(&core.p_link, &wp, &mut pairs);
        let f_sparse = SparseVec::from_pairs(pairs).to_dense(n);
        let mut f_dense = vec![0.0f32; n];
        for (i, fd) in f_dense.iter_mut().enumerate() {
            for j in 0..n {
                *fd += l_dense[i][j] * wp.get(j);
            }
        }
        for i in 0..n {
            assert!(
                (f_sparse[i] - f_dense[i]).abs() < 0.05,
                "f[{i}] sparse={} dense={}",
                f_sparse[i],
                f_dense[i]
            );
        }
    }

    #[test]
    fn linkage_rows_bounded_by_kl() {
        let mut rng = Rng::new(46);
        let cfg = small_cfg(46);
        let mut core = SdncCore::new(&cfg, &mut rng);
        core.reset();
        let (xs, _) = random_episode(4, 3, 10, &mut rng);
        for x in &xs {
            core.forward(x);
        }
        for (_, row) in core.n_link.rows.iter() {
            assert!(row.nnz() <= cfg.k_l);
        }
        for (_, row) in core.p_link.rows.iter() {
            assert!(row.nnz() <= cfg.k_l);
        }
        core.rollback();
        core.end_episode();
    }

    #[test]
    fn merge_p_row_matches_map_reference() {
        // Pin the merge against the old HashMap-based row rebuild.
        let old = SparseVec::from_pairs(vec![(1, 0.3), (4, 0.2), (7, 0.5)]);
        let w = SparseVec::from_pairs(vec![(2, 0.4), (4, 0.5), (5, 0.0), (9, 0.25)]);
        let p_prev_i = 0.6;
        let diag = 4usize;
        let mut got = SparseVec::new();
        SdncCore::merge_p_row(Some(&old), &w, p_prev_i, diag, &mut got);
        // reference via map semantics
        let mut map: std::collections::HashMap<usize, f32> = old.iter().collect();
        for (j, wj) in w.iter() {
            if j == diag {
                continue;
            }
            let cur = map.get(&j).copied().unwrap_or(0.0);
            let nv = (1.0 - wj) * cur + wj * p_prev_i;
            if nv != 0.0 {
                map.insert(j, nv);
            } else {
                map.remove(&j);
            }
        }
        let want = SparseVec::from_pairs(map.into_iter().collect());
        assert_eq!(got, want);
    }
}
