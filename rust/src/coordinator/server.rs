//! Multi-threaded TCP inference server: newline-delimited JSON over a
//! session protocol, backed by the shared-weight serving runtime
//! (`serving::SessionManager` + `serving::BatchScheduler`). Python is
//! never involved — this is the L3 request path.
//!
//! Architecture: one accept thread (also runs idle-session expiry) feeds a
//! connection queue drained by a pool of worker threads. A worker reads
//! one line from a connection with a short timeout; a timeout **parks**
//! the connection back on the queue instead of closing it — an idle
//! keep-alive client no longer loses its connection (or the sessions it
//! expected to keep), and no worker is ever pinned by a silent socket.
//! Because session state lives in the `SessionManager`, any worker can
//! serve any connection's next request. Steps route through the
//! `BatchScheduler`, so concurrent sessions' controller math coalesces
//! into one GEMM per tick. Sessions are connection-scoped: step/reset/
//! close are rejected for ids the connection did not open.
//!
//! Known scaling limit: parked connections are polled by blocking reads
//! (one `read_timeout` slice per connection per worker), so aggregate poll
//! throughput is `workers / read_timeout` and tail latency grows with the
//! idle-connection count. Fine up to a few hundred mostly-idle clients;
//! beyond that the queue wants readiness-based multiplexing (epoll) —
//! the ConnQueue seam is where that would slot in.
//!
//! Protocol (one JSON object per line):
//!   → {"open": true}                        open a session (manager-seeded memory)
//!   → {"open": {"seed": 7}}                 open with an explicit memory seed
//!   → {"session": id, "input": [f32…]}      one step of one session
//!   → {"reset": id}                         restart the session's episode
//!   → {"close": id}                         close a session
//!   → {"inputs": [[f32…], …]}               stateless episode (open-step-close)
//!   → {"ping": true}  /  {"stats": true}    health / accounting
//!   → {"metrics": true}                     Prometheus text exposition
//!   ← {"session": id} / {"session": id, "output": [f32…]} / {"closed": b}
//!     {"outputs": [[f32…], …]} / {"pong": true}
//!     {"metrics": "# TYPE sam_serve_steps_total counter\n…"}
//!     {"error": "…", "retryable": false}
//!     {"error": "overloaded", "retryable": true, "retry_after_ms": n}
//!     {"error": "unavailable", "retryable": true}   (scheduler stopped/dead)
//!
//! Sessions opened over a connection are closed when that connection goes
//! away (EOF or error), never when it merely idles.
//!
//! Graceful degradation: every error reply carries a `retryable` flag
//! (true only for transient conditions — currently overload shedding, when
//! the byte budget is exhausted AND spilling to disk is failing, so
//! admitting a session could only destroy another one). Response writes
//! retry transient socket errors with capped exponential backoff before
//! the connection is declared dead. With `--spill-dir`, the session table
//! demotes/rehydrates through checksummed spill files (serving/spill.rs)
//! and a cold restart reloads every surviving session before accepting.

use crate::serving::{BatchScheduler, InferModel, SessionConfig, SessionError, SessionManager};
use crate::util::json::Json;
use crate::util::metrics;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Server knobs (defaults match `sam serve`'s flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads draining the connection queue.
    pub workers: usize,
    /// Per-read timeout after which an idle connection is parked.
    pub read_timeout: Duration,
    /// Batch-coalescing tick of the step scheduler.
    pub tick: Duration,
    /// Largest number of steps coalesced into one tick.
    pub max_batch: usize,
    /// Session-table policy (byte budget, idle expiry, seed stream).
    pub session: SessionConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            read_timeout: Duration::from_millis(25),
            tick: Duration::from_micros(200),
            max_batch: 64,
            session: SessionConfig::default(),
        }
    }
}

/// Hard cap on one request line (a 1 MiB JSON step is already absurd).
const MAX_LINE_BYTES: usize = 1 << 20;

/// One client connection plus the sessions it opened (closed with it).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    sessions: Vec<u64>,
    line: String,
}

/// Blocking MPMC queue of parked connections.
struct ConnQueue {
    q: Mutex<VecDeque<Conn>>,
    cv: Condvar,
}

impl ConnQueue {
    fn new() -> ConnQueue {
        ConnQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, c: Conn) {
        self.q.lock().unwrap().push_back(c);
        self.cv.notify_one();
    }

    /// Pop with a bounded wait so workers can observe `stop`.
    fn pop(&self, wait: Duration) -> Option<Conn> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            let (guard, _) = self.cv.wait_timeout(q, wait).unwrap();
            q = guard;
        }
        q.pop_front()
    }
}

/// Shared per-request context handed to [`handle_request`].
pub struct ServerCtx {
    pub mgr: Arc<SessionManager>,
    pub sched: Arc<BatchScheduler>,
}

/// Serve `model` on `addr`: builds the session manager from
/// `cfg.session` and runs [`serve`]. The `sam serve` entry point.
pub fn serve_model(
    model: Arc<dyn InferModel>,
    addr: &str,
    cfg: &ServeConfig,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mgr = Arc::new(SessionManager::new(model, cfg.session.clone()));
    serve(mgr, addr, cfg, stop)
}

/// Serve a prebuilt session manager on `addr` ("127.0.0.1:7878"). Blocks;
/// set `stop` from another thread to shut down.
pub fn serve(
    mgr: Arc<SessionManager>,
    addr: &str,
    cfg: &ServeConfig,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    // Crash-safe restart: before accepting any client, reload every
    // surviving spilled session so ids handed out before the crash keep
    // working. Corrupt files are detected (CRC), dropped and counted —
    // never loaded.
    if let Some(dir) = cfg.session.spill_dir.as_ref() {
        let dir = dir.display().to_string();
        let (loaded, corrupt) = mgr.rehydrate_all();
        eprintln!("sam-serve spill dir {dir}: rehydrated {loaded} sessions, dropped {corrupt} corrupt");
    }
    eprintln!(
        "sam-serve listening on {addr} ({} workers, tick {:?}, budget {} bytes)",
        cfg.workers, cfg.tick, cfg.session.byte_budget
    );
    let sched = Arc::new(BatchScheduler::start(mgr.clone(), cfg.tick, cfg.max_batch));
    let queue = Arc::new(ConnQueue::new());
    let ctx = Arc::new(ServerCtx { mgr: mgr.clone(), sched: sched.clone() });

    let workers: Vec<_> = (0..cfg.workers.max(1))
        .map(|_| {
            let queue = queue.clone();
            let ctx = ctx.clone();
            let stop = stop.clone();
            let read_timeout = cfg.read_timeout;
            std::thread::spawn(move || worker_loop(&queue, &ctx, &stop, read_timeout))
        })
        .collect();

    let mut last_expiry = std::time::Instant::now();
    let mut accept_err: Option<std::io::Error> = None;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // Write timeout too: a client that stops reading must not
                // pin a worker in write_all forever — a timed-out write
                // closes the connection like any other I/O error.
                let setup = stream
                    .set_read_timeout(Some(cfg.read_timeout))
                    .and_then(|()| stream.set_write_timeout(Some(Duration::from_secs(2))))
                    .and_then(|()| stream.try_clone());
                match setup {
                    Ok(clone) => queue.push(Conn {
                        reader: BufReader::new(clone),
                        writer: stream,
                        sessions: Vec::new(),
                        line: String::new(),
                    }),
                    Err(e) => eprintln!("accept setup failed: {e}"),
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                accept_err = Some(e);
                break;
            }
        }
        if last_expiry.elapsed() > Duration::from_secs(1) {
            mgr.expire_idle();
            last_expiry = std::time::Instant::now();
        }
    }
    for w in workers {
        let _ = w.join();
    }
    sched.stop();
    match accept_err {
        Some(e) => Err(e.into()),
        None => Ok(()),
    }
}

fn worker_loop(queue: &ConnQueue, ctx: &ServerCtx, stop: &AtomicBool, read_timeout: Duration) {
    while !stop.load(Ordering::Relaxed) {
        let Some(mut conn) = queue.pop(read_timeout) else { continue };
        match serve_one_line(&mut conn, ctx) {
            ConnState::Park => queue.push(conn),
            ConnState::Closed => {
                for id in conn.sessions.drain(..) {
                    ctx.mgr.close(id);
                }
            }
        }
    }
}

enum ConnState {
    /// Connection healthy (request served, or merely idle): back on the
    /// queue for any worker to continue. This is the idle-client fix — the
    /// old single-threaded server returned Ok on a read timeout, silently
    /// dropping keep-alive clients and the state they expected to keep.
    Park,
    /// EOF or I/O error: release the connection's sessions.
    Closed,
}

/// Read and serve at most one request line from `conn`. `conn.line`
/// accumulates across parks: a read timeout can land mid-line (the client
/// wrote slowly), and the partial bytes must survive until the newline
/// arrives — clearing on entry would corrupt the request.
fn serve_one_line(conn: &mut Conn, ctx: &ServerCtx) -> ConnState {
    let eof = match conn.reader.read_line(&mut conn.line) {
        Ok(0) => true, // client hung up (any partial line still served below)
        Ok(_) => false,
        Err(ref e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            // Idle (possibly mid-line): park, keeping what was read.
            return ConnState::Park;
        }
        Err(_) => return ConnState::Closed,
    };
    if !conn.line.ends_with('\n') && !eof {
        // Timed out with a partial line already consumed into the buffer:
        // park and finish the line on a later pass.
        return ConnState::Park;
    }
    if conn.line.len() > MAX_LINE_BYTES {
        // A newline-free stream must not grow the buffer without bound.
        return ConnState::Closed;
    }
    if conn.line.trim().is_empty() {
        conn.line.clear(); // blank keep-alive lines must not accumulate
        return if eof { ConnState::Closed } else { ConnState::Park };
    }
    let response = match handle_request(ctx, conn.line.trim(), &mut conn.sessions) {
        Ok(j) => j,
        // Request-level failures are reported in-band, and they are final:
        // replaying the same malformed/rejected request cannot succeed.
        // (Transient conditions — overload — come back as Ok replies with
        // retryable=true from handle_request.)
        Err(e) => Json::obj(vec![
            ("error", Json::str(format!("{e:#}"))),
            ("retryable", Json::Bool(false)),
        ]),
    };
    conn.line.clear();
    let mut bytes = response.encode().into_bytes();
    bytes.push(b'\n');
    match (write_response(&mut conn.writer, &bytes), eof) {
        (Ok(()), false) => ConnState::Park,
        _ => ConnState::Closed,
    }
}

/// Write one response, retrying transient socket errors (timeout /
/// would-block) with capped exponential backoff before giving up on the
/// connection. Progress is tracked byte-by-byte so a retry never resends
/// bytes the kernel already accepted — a timed-out `write_all` would lose
/// track of the partial write and corrupt the stream on retry.
fn write_response(writer: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    const MAX_RETRIES: u32 = 3;
    const BACKOFF_CAP: Duration = Duration::from_millis(100);
    let mut written = 0usize;
    let mut retries = 0u32;
    let mut backoff = Duration::from_millis(10);
    while written < bytes.len() {
        match writer.write(&bytes[written..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket closed mid-response",
                ));
            }
            Ok(n) => {
                written += n;
                retries = 0; // progress resets the retry budget
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if retries < MAX_RETRIES
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                retries += 1;
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
            Err(e) => return Err(e),
        }
    }
    writer.flush()
}

/// Parse a JSON array into finite f32s. Non-finite values (or f64s that
/// overflow f32 to ±inf) are rejected at the door: NaN in a memory row
/// would poison cosine comparisons deep inside the ANN backends.
fn parse_floats(row: &[Json]) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(row.len());
    for (i, v) in row.iter().enumerate() {
        let f = v.as_f64().unwrap_or(0.0) as f32;
        if !f.is_finite() {
            return Err(anyhow!("input[{i}] is not a finite f32"));
        }
        out.push(f);
    }
    Ok(out)
}

/// Process one request line against the serving runtime. Public for unit
/// testing without sockets; `conn_sessions` tracks session ownership for
/// connection-drop cleanup.
pub fn handle_request(ctx: &ServerCtx, line: &str, conn_sessions: &mut Vec<u64>) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    if req.get("ping").is_some() {
        return Ok(Json::obj(vec![("pong", Json::Bool(true))]));
    }
    if req.get("stats").is_some() {
        let (spilled, rehydrated, corrupt) = ctx.mgr.spill_stats();
        let (evicted, expired) = ctx.mgr.eviction_stats();
        return Ok(Json::obj(vec![
            ("sessions", Json::num(ctx.mgr.session_count() as f64)),
            ("state_bytes", Json::num(ctx.mgr.state_heap_bytes() as f64)),
            ("params_bytes", Json::num(ctx.mgr.params_heap_bytes() as f64)),
            ("params", Json::num(ctx.mgr.model().params_len() as f64)),
            ("spilled", Json::num(spilled as f64)),
            ("rehydrated", Json::num(rehydrated as f64)),
            ("corrupt_dropped", Json::num(corrupt as f64)),
            ("evicted", Json::num(evicted as f64)),
            ("expired", Json::num(expired as f64)),
            ("spill_failures", Json::num(ctx.mgr.spill_failures() as f64)),
            // Process-wide serving metrics (the registry is global, so on a
            // multi-manager process these cover every manager).
            ("steps", Json::num(metrics::SERVE_STEPS.get() as f64)),
            ("step_latency_us", metrics::hist_summary_json(&metrics::SERVE_STEP_LATENCY_US)),
            ("queue_latency_us", metrics::hist_summary_json(&metrics::SERVE_QUEUE_LATENCY_US)),
            ("ticks", Json::num(metrics::SERVE_TICKS.get() as f64)),
            ("tick_requests", Json::num(metrics::SERVE_TICK_REQUESTS.get() as f64)),
            ("tick_fill_permille", Json::num(metrics::SERVE_TICK_FILL_PERMILLE.get() as f64)),
        ]));
    }
    if req.get("metrics").is_some() {
        // Full registry in Prometheus text exposition format, shipped as a
        // single JSON string so the line protocol stays newline-delimited.
        // A sidecar (or the CI smoke step) unwraps the "metrics" field and
        // has a standard scrape body.
        return Ok(Json::obj(vec![("metrics", Json::str(metrics::render_prometheus()))]));
    }
    if let Some(open) = req.get("open") {
        let opened = match open.get("seed").and_then(|s| s.as_f64()) {
            Some(seed) => ctx.mgr.open_checked(Some(seed as u64)),
            None => ctx.mgr.open_auto_checked(),
        };
        let id = match opened {
            Ok(id) => id,
            Err(SessionError::Overloaded { retry_after_ms }) => {
                // Shed rather than destroy: the budget is exhausted and
                // spilling is failing, so admitting this session would evict
                // someone else's state with no copy left anywhere. Tell the
                // client to come back instead. Structured reply (not Err):
                // this is a protocol-level answer, not a malformed request.
                return Ok(Json::obj(vec![
                    ("error", Json::str("overloaded")),
                    ("retryable", Json::Bool(true)),
                    ("retry_after_ms", Json::num(retry_after_ms as f64)),
                ]));
            }
            Err(e) => return Err(anyhow!("{e}")),
        };
        conn_sessions.push(id);
        return Ok(Json::obj(vec![("session", Json::num(id as f64))]));
    }
    if let Some(id) = req.get("close").and_then(|j| j.as_f64()) {
        let id = id as u64;
        // Sessions are connection-scoped: ids are sequential, so without
        // this check any client could close/step another client's session.
        if !conn_sessions.contains(&id) {
            return Err(anyhow!("session {id} not owned by this connection"));
        }
        conn_sessions.retain(|&s| s != id);
        let existed = ctx.mgr.close(id);
        return Ok(Json::obj(vec![("closed", Json::Bool(existed))]));
    }
    if let Some(id) = req.get("reset").and_then(|j| j.as_f64()) {
        let id = id as u64;
        if !conn_sessions.contains(&id) {
            return Err(anyhow!("session {id} not owned by this connection"));
        }
        if let Err(e) = ctx.mgr.reset(id) {
            // Evicted/expired server-side: drop the stale ownership record.
            conn_sessions.retain(|&s| s != id);
            return Err(anyhow!("{e}"));
        }
        return Ok(Json::obj(vec![("reset", Json::Bool(true))]));
    }
    if let Some(id) = req.get("session").and_then(|j| j.as_f64()) {
        let id = id as u64;
        if !conn_sessions.contains(&id) {
            return Err(anyhow!("session {id} not owned by this connection"));
        }
        let input = req
            .get("input")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow!("missing input"))?;
        let x = parse_floats(input)?;
        let y = match ctx.sched.step_blocking(id, x) {
            Ok(y) => y,
            Err(SessionError::SchedulerStopped) => {
                // The session still exists (possibly spilled) — only the
                // scheduler is gone (shutdown or tick panic). Keep the
                // ownership record and answer with a structured retryable
                // reply, NOT a non-retryable Err: a client that retries
                // against a restarted server finds its session again.
                return Ok(Json::obj(vec![
                    ("error", Json::str("unavailable")),
                    ("retryable", Json::Bool(true)),
                ]));
            }
            Err(e) => {
                if matches!(e, SessionError::NoSuchSession(_)) {
                    conn_sessions.retain(|&s| s != id);
                }
                return Err(anyhow!("{e}"));
            }
        };
        return Ok(Json::obj(vec![
            ("session", Json::num(id as f64)),
            ("output", Json::floats(&y)),
        ]));
    }
    if let Some(inputs) = req.get("inputs").and_then(|j| j.as_arr()) {
        // Stateless episode: an ephemeral session stepped through every
        // row (the old protocol, kept for episode-at-a-time clients).
        let x_dim = ctx.mgr.model().x_dim();
        let mut xs = Vec::with_capacity(inputs.len());
        for (t, row) in inputs.iter().enumerate() {
            let row = row.as_arr().ok_or_else(|| anyhow!("inputs[{t}] not an array"))?;
            if row.len() != x_dim {
                return Err(anyhow!("inputs[{t}] has {} dims, want {x_dim}", row.len()));
            }
            xs.push(parse_floats(row)?);
        }
        // Parity seeds (`None`), not a manager-drawn random seed: the
        // stateless episode path must stay deterministic — identical
        // requests return identical outputs, as the pre-session server did.
        let id = ctx.mgr.open_seeded(None);
        let mut outs = Vec::with_capacity(xs.len());
        for x in xs {
            match ctx.sched.step_blocking(id, x) {
                Ok(y) => outs.push(y),
                Err(SessionError::SchedulerStopped) => {
                    ctx.mgr.close(id);
                    return Ok(Json::obj(vec![
                        ("error", Json::str("unavailable")),
                        ("retryable", Json::Bool(true)),
                    ]));
                }
                Err(e) => {
                    ctx.mgr.close(id);
                    return Err(anyhow!("{e}"));
                }
            }
        }
        ctx.mgr.close(id);
        return Ok(Json::obj(vec![(
            "outputs",
            Json::arr(outs.iter().map(|o| Json::floats(o))),
        )]));
    }
    Err(anyhow!("unknown request (want open/session/close/reset/inputs/ping/stats/metrics)"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::{CoreConfig, CoreKind};
    use crate::serving::build_infer_model;
    use crate::util::rng::Rng;

    fn test_ctx() -> (ServerCtx, Arc<SessionManager>) {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let mgr = Arc::new(SessionManager::new(model, SessionConfig::default()));
        let sched = Arc::new(BatchScheduler::start(
            mgr.clone(),
            Duration::from_micros(100),
            16,
        ));
        (ServerCtx { mgr: mgr.clone(), sched }, mgr)
    }

    #[test]
    fn ping_pong() {
        let (ctx, _) = test_ctx();
        let mut owned = Vec::new();
        let r = handle_request(&ctx, r#"{"ping": true}"#, &mut owned).unwrap();
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
        ctx.sched.stop();
    }

    #[test]
    fn session_lifecycle_over_protocol() {
        let (ctx, mgr) = test_ctx();
        let mut owned = Vec::new();
        let r = handle_request(&ctx, r#"{"open": true}"#, &mut owned).unwrap();
        let id = r.get("session").unwrap().as_f64().unwrap() as u64;
        assert_eq!(owned, vec![id]);
        let r = handle_request(
            &ctx,
            &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
            &mut owned,
        )
        .unwrap();
        assert_eq!(r.get("output").unwrap().as_arr().unwrap().len(), 3);
        let r = handle_request(&ctx, &format!(r#"{{"reset": {id}}}"#), &mut owned).unwrap();
        assert_eq!(r.get("reset").unwrap().as_bool(), Some(true));
        let r = handle_request(&ctx, &format!(r#"{{"close": {id}}}"#), &mut owned).unwrap();
        assert_eq!(r.get("closed").unwrap().as_bool(), Some(true));
        assert!(owned.is_empty());
        assert_eq!(mgr.session_count(), 0);
        // Stepping a closed session errors.
        assert!(handle_request(
            &ctx,
            &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
            &mut owned
        )
        .is_err());
        ctx.sched.stop();
    }

    #[test]
    fn foreign_sessions_are_rejected() {
        // Connection B must not be able to step/reset/close a session that
        // connection A opened (ids are guessable sequential integers).
        let (ctx, mgr) = test_ctx();
        let mut conn_a = Vec::new();
        let r = handle_request(&ctx, r#"{"open": true}"#, &mut conn_a).unwrap();
        let id = r.get("session").unwrap().as_f64().unwrap() as u64;
        let mut conn_b = Vec::new();
        assert!(handle_request(
            &ctx,
            &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
            &mut conn_b
        )
        .is_err());
        assert!(handle_request(&ctx, &format!(r#"{{"reset": {id}}}"#), &mut conn_b).is_err());
        assert!(handle_request(&ctx, &format!(r#"{{"close": {id}}}"#), &mut conn_b).is_err());
        assert_eq!(mgr.session_count(), 1, "foreign close must not remove the session");
        // The owner still works.
        assert!(handle_request(
            &ctx,
            &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
            &mut conn_a
        )
        .is_ok());
        ctx.sched.stop();
    }

    #[test]
    fn legacy_episode_request_matches_repeat_and_returns_outputs() {
        // The stateless path must be deterministic: identical requests get
        // identical outputs (parity seeds, not per-request random init).
        let (ctx, mgr) = test_ctx();
        let mut owned = Vec::new();
        let req = r#"{"inputs": [[1,0,0,0],[0,1,0,0]]}"#;
        let a = handle_request(&ctx, req, &mut owned).unwrap();
        let b = handle_request(&ctx, req, &mut owned).unwrap();
        assert_eq!(a.encode(), b.encode(), "stateless episodes must be deterministic");
        assert_eq!(mgr.session_count(), 0);
        ctx.sched.stop();
    }

    #[test]
    fn legacy_episode_request_returns_outputs() {
        let (ctx, mgr) = test_ctx();
        let mut owned = Vec::new();
        let r = handle_request(
            &ctx,
            r#"{"inputs": [[1,0,0,0],[0,1,0,0],[0,0,1,0]]}"#,
            &mut owned,
        )
        .unwrap();
        let outs = r.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].as_arr().unwrap().len(), 3);
        assert_eq!(mgr.session_count(), 0, "ephemeral session must be closed");
        ctx.sched.stop();
    }

    #[test]
    fn malformed_requests_rejected() {
        let (ctx, _) = test_ctx();
        let mut owned = Vec::new();
        assert!(handle_request(&ctx, "not json", &mut owned).is_err());
        assert!(handle_request(&ctx, r#"{"inputs": [[1,0]]}"#, &mut owned).is_err());
        assert!(handle_request(&ctx, r#"{}"#, &mut owned).is_err());
        ctx.sched.stop();
    }

    #[test]
    fn overload_is_shed_with_retryable_reply() {
        // Byte budget exhausted + spill dir that cannot be written (it is a
        // file, not a directory) → the open that would need to demote fails
        // its spill, and the NEXT open is shed with a structured retryable
        // reply instead of destroying a resident session.
        let blocker = std::env::temp_dir()
            .join(format!("sam-server-spill-blocker-{}", std::process::id()));
        std::fs::write(&blocker, b"not a directory").unwrap();

        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let session = SessionConfig {
            byte_budget: 1, // any session exceeds it
            spill_dir: Some(blocker.clone()),
            ..SessionConfig::default()
        };
        let mgr = Arc::new(SessionManager::new(model, session));
        let sched = Arc::new(BatchScheduler::start(
            mgr.clone(),
            Duration::from_micros(100),
            16,
        ));
        let ctx = ServerCtx { mgr: mgr.clone(), sched };

        let mut owned = Vec::new();
        // First open fits trivially (a lone session is never its own
        // victim); the second triggers a demotion attempt that fails.
        handle_request(&ctx, r#"{"open": {"seed": 1}}"#, &mut owned).unwrap();
        handle_request(&ctx, r#"{"open": {"seed": 2}}"#, &mut owned).unwrap();
        assert_eq!(mgr.session_count(), 2, "failed spill must keep the victim resident");
        assert!(mgr.spill_failures() > 0);

        let r = handle_request(&ctx, r#"{"open": {"seed": 3}}"#, &mut owned).unwrap();
        assert_eq!(r.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(r.get("retryable").unwrap().as_bool(), Some(true));
        assert!(r.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(mgr.session_count(), 2, "shed open must not destroy state");
        assert_eq!(owned.len(), 2, "shed open must not record ownership");

        ctx.sched.stop();
        let _ = std::fs::remove_file(&blocker);
    }

    #[test]
    fn stopped_scheduler_steps_answer_unavailable_retryable() {
        // A step against a stopped scheduler must come back as a
        // structured `{"error":"unavailable","retryable":true}` reply —
        // not the non-retryable Err path, and never "no such session":
        // the session still exists, only the scheduler is gone.
        let (ctx, mgr) = test_ctx();
        let mut owned = Vec::new();
        let r = handle_request(&ctx, r#"{"open": {"seed": 4}}"#, &mut owned).unwrap();
        let id = r.get("session").unwrap().as_f64().unwrap() as u64;
        ctx.sched.stop();
        let r = handle_request(
            &ctx,
            &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
            &mut owned,
        )
        .unwrap();
        assert_eq!(r.get("error").unwrap().as_str(), Some("unavailable"));
        assert_eq!(r.get("retryable").unwrap().as_bool(), Some(true));
        assert_eq!(owned, vec![id], "ownership must survive an unavailable reply");
        assert_eq!(mgr.session_count(), 1, "the session must survive too");
        // The stateless episode path degrades the same way.
        let r = handle_request(&ctx, r#"{"inputs": [[1,0,0,0]]}"#, &mut owned).unwrap();
        assert_eq!(r.get("error").unwrap().as_str(), Some("unavailable"));
        assert_eq!(r.get("retryable").unwrap().as_bool(), Some(true));
    }

    /// Minimal Prometheus-text validation shared with the CI smoke step's
    /// shell check: a `# TYPE` header appears, every sample line parses as
    /// `name[{labels}] <integer>`, and the three layer families are present.
    fn assert_valid_prometheus(text: &str) {
        assert!(text.starts_with("# TYPE "), "exposition must open with a TYPE line");
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').unwrap_or(("", ""));
            assert!(!name.is_empty(), "malformed sample line {line:?}");
            assert!(value.parse::<u64>().is_ok(), "non-numeric sample in {line:?}");
        }
        for family in ["sam_train_", "sam_serve_", "sam_sessions_", "sam_mem_", "sam_ann_"] {
            assert!(text.contains(family), "metrics missing the {family}* family");
        }
    }

    #[test]
    fn metrics_render_under_concurrent_load_and_stay_monotonic() {
        let (ctx, _) = test_ctx();
        let ctx = Arc::new(ctx);
        let mut owned = Vec::new();
        let before = handle_request(&ctx, r#"{"metrics": true}"#, &mut owned).unwrap();
        let before_text = before.get("metrics").unwrap().as_str().unwrap().to_string();
        assert_valid_prometheus(&before_text);
        let sample = |text: &str, name: &str| -> u64 {
            text.lines()
                .find(|l| l.split(' ').next() == Some(name))
                .and_then(|l| l.rsplit_once(' '))
                .and_then(|(_, v)| v.parse().ok())
                .unwrap_or_else(|| panic!("{name} missing"))
        };
        // Concurrent sessions stepping while other threads scrape.
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ctx = ctx.clone();
            handles.push(std::thread::spawn(move || {
                let mut owned = Vec::new();
                let r = handle_request(&ctx, &format!(r#"{{"open": {{"seed": {t}}}}}"#), &mut owned)
                    .unwrap();
                let id = r.get("session").unwrap().as_f64().unwrap() as u64;
                for _ in 0..5 {
                    let r = handle_request(
                        &ctx,
                        &format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#),
                        &mut owned,
                    )
                    .unwrap();
                    assert!(r.get("output").is_some());
                    let m = handle_request(&ctx, r#"{"metrics": true}"#, &mut owned).unwrap();
                    assert!(m.get("metrics").unwrap().as_str().unwrap().contains("# TYPE"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let after = handle_request(&ctx, r#"{"metrics": true}"#, &mut owned).unwrap();
        let after_text = after.get("metrics").unwrap().as_str().unwrap().to_string();
        assert_valid_prometheus(&after_text);
        // Counters are monotonic, and the 20 steps above are visible.
        for name in [
            "sam_serve_steps_total",
            "sam_serve_ticks_total",
            "sam_sessions_opened_total",
            "sam_mem_reads_total",
            "sam_mem_writes_total",
            "sam_ann_queries_total",
        ] {
            assert!(
                sample(&after_text, name) >= sample(&before_text, name),
                "{name} went backwards"
            );
        }
        assert!(
            sample(&after_text, "sam_serve_steps_total")
                >= sample(&before_text, "sam_serve_steps_total") + 20,
            "20 steps must be counted"
        );
        ctx.sched.stop();
    }

    #[test]
    fn stats_report_single_param_copy() {
        let (ctx, mgr) = test_ctx();
        let mut owned = Vec::new();
        let before = handle_request(&ctx, r#"{"stats": true}"#, &mut owned).unwrap();
        for _ in 0..4 {
            handle_request(&ctx, r#"{"open": true}"#, &mut owned).unwrap();
        }
        let after = handle_request(&ctx, r#"{"stats": true}"#, &mut owned).unwrap();
        assert_eq!(
            before.get("params_bytes").unwrap().as_f64(),
            after.get("params_bytes").unwrap().as_f64(),
            "params bytes must not scale with session count"
        );
        assert!(after.get("state_bytes").unwrap().as_f64() > before.get("state_bytes").unwrap().as_f64());
        assert_eq!(mgr.session_count(), 4);
        ctx.sched.stop();
    }
}
