//! TCP inference server: newline-delimited JSON requests against a trained
//! core (or a PJRT-compiled cell). Python is never involved — this is the
//! L3 request path.
//!
//! Protocol (one JSON object per line):
//!   → {"inputs": [[f32…], …]}            run an episode, return outputs
//!   → {"ping": true}                      health check
//!   ← {"outputs": [[f32…], …]}  /  {"pong": true}  /  {"error": "…"}

use crate::cores::Core;
use crate::training::eval_episode;
use crate::tasks::{Episode, LossKind};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Serve `core` on `addr` ("127.0.0.1:7878"). Blocks; set `stop` from
/// another thread to shut down after the in-flight request.
pub fn serve(core: Arc<Mutex<Box<dyn Core>>>, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    eprintln!("sam-serve listening on {addr}");
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(e) = handle_client(&core, stream) {
                    eprintln!("client error: {e:#}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn handle_client(core: &Arc<Mutex<Box<dyn Core>>>, stream: TcpStream) -> Result<()> {
    // Bounded reads so a silent client cannot pin the accept loop forever.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(500)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(()) // idle client: free the loop (single-threaded server)
            }
            Err(e) => return Err(e.into()),
        }
        let response = match handle_request(core, line.trim()) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
        };
        writer.write_all(response.encode().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

/// Process one request line. Public for unit testing without sockets.
pub fn handle_request(core: &Arc<Mutex<Box<dyn Core>>>, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    if req.get("ping").is_some() {
        return Ok(Json::obj(vec![("pong", Json::Bool(true))]));
    }
    let inputs = req
        .get("inputs")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| anyhow!("missing inputs"))?;
    let mut core = core.lock().map_err(|_| anyhow!("core poisoned"))?;
    let x_dim = core.x_dim();
    let y_dim = core.y_dim();
    let mut xs = Vec::with_capacity(inputs.len());
    for (t, row) in inputs.iter().enumerate() {
        let row = row.as_arr().ok_or_else(|| anyhow!("inputs[{t}] not an array"))?;
        if row.len() != x_dim {
            return Err(anyhow!("inputs[{t}] has {} dims, want {x_dim}", row.len()));
        }
        xs.push(
            row.iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect::<Vec<f32>>(),
        );
    }
    let t_len = xs.len();
    let ep = Episode {
        inputs: xs,
        targets: vec![vec![0.0; y_dim]; t_len],
        mask: vec![false; t_len],
        loss: LossKind::Bits,
        family: 0,
    };
    let (_, outputs) = eval_episode(core.as_mut(), &ep);
    Ok(Json::obj(vec![(
        "outputs",
        Json::arr(outputs.iter().map(|o| Json::floats(o))),
    )]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::{build_core, CoreConfig, CoreKind};
    use crate::util::rng::Rng;

    fn test_core() -> Arc<Mutex<Box<dyn Core>>> {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        Arc::new(Mutex::new(build_core(CoreKind::Sam, &cfg, &mut rng)))
    }

    #[test]
    fn ping_pong() {
        let core = test_core();
        let r = handle_request(&core, r#"{"ping": true}"#).unwrap();
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn episode_request_returns_outputs() {
        let core = test_core();
        let r = handle_request(
            &core,
            r#"{"inputs": [[1,0,0,0],[0,1,0,0],[0,0,1,0]]}"#,
        )
        .unwrap();
        let outs = r.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].as_arr().unwrap().len(), 3);
    }

    #[test]
    fn malformed_requests_rejected() {
        let core = test_core();
        assert!(handle_request(&core, "not json").is_err());
        assert!(handle_request(&core, r#"{"inputs": [[1,0]]}"#).is_err()); // wrong dim
        assert!(handle_request(&core, r#"{}"#).is_err());
    }

    #[test]
    fn server_round_trip_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let core = test_core();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let addr = "127.0.0.1:47391";
        let core2 = core.clone();
        let handle = std::thread::spawn(move || {
            let _ = serve(core2, addr, stop2);
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        stream
            .write_all(b"{\"inputs\": [[1,0,0,0],[0,0,0,1]]}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("outputs").is_some(), "{line}");
        stop.store(true, Ordering::Relaxed);
        drop(reader); // close BOTH socket handles so the server unblocks
        drop(stream);
        handle.join().unwrap();
    }
}
