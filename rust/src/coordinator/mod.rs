//! Experiment coordination: config parsing, task/core factories, checkpoint
//! I/O, and the TCP inference server. This is the layer the `sam` binary
//! and the examples drive.

pub mod server;

use crate::ann::AnnKind;
use crate::cores::{build_core, Core, CoreConfig, CoreKind};
use crate::curriculum::Curriculum;
use crate::optim::{Adam, Optimizer, RmsProp};
use crate::tasks::{
    babi::BabiTask, copy::CopyTask, omniglot::OmniglotTask, recall::AssociativeRecall,
    sort::PrioritySort, Task,
};
use crate::tensor::rowcodec::RowFormat;
use crate::training::batched::FusedTrainer;
use crate::training::workers::ParallelTrainer;
use crate::training::{TrainConfig, Trainer, TrainLog};
use crate::util::args::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Everything needed to reproduce a run, assembled from CLI flags.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub core: CoreKind,
    pub task: String,
    pub core_cfg: CoreConfig,
    pub train_cfg: TrainConfig,
    /// Curriculum: None = fixed at the task's base level.
    pub curriculum_max: Option<usize>,
    pub curriculum_threshold: f64,
    /// Data-parallel worker threads (1 = serial trainer). Same seed ⇒ same
    /// result at any count; see `training::workers`.
    pub workers: usize,
}

impl ExperimentConfig {
    /// Parse from CLI flags with the paper's defaults (Supp C / E).
    pub fn from_args(args: &Args) -> Result<ExperimentConfig> {
        let core: CoreKind = args
            .str_or("model", "sam")
            .parse()
            .map_err(|e: String| anyhow!(e))?;
        let ann: AnnKind = args
            .str_or("ann", "linear")
            .parse()
            .map_err(|e: String| anyhow!(e))?;
        let row_format: RowFormat = args
            .str_or("row-format", "f32")
            .parse()
            .map_err(|e: String| anyhow!(e))?;
        let task = args.str_or("task", "copy");
        let core_cfg = CoreConfig {
            hidden: args.usize_or("hidden", 100),
            heads: args.usize_or("heads", 4),
            word: args.usize_or("word", 32),
            mem_words: args.usize_or("memory", 128),
            k: args.usize_or("k", 4),
            k_l: args.usize_or("kl", 8),
            ann,
            delta: args.f32_or("delta", 0.005),
            lambda: args.f32_or("lambda", 0.99),
            // Memory shards for the sparse engines (SAM/SDNC): 1 = the
            // unsharded engine; any S is bit-identical to S=1 for
            // ann=linear, so this is a pure throughput knob for training
            // AND serving (sessions inherit it via the core config).
            shards: args.usize_or("shards", 1).max(1),
            // Memory-row codec: f32 (default, the only train-legal format)
            // or bf16/int8 compact rows for serve/eval bandwidth.
            row_format,
            seed: args.u64_or("seed", 1),
            ..CoreConfig::default()
        };
        // Validate here so a bad flag combination is a usage error, not a
        // panic from the engine's own invariant assert at construction.
        if core_cfg.shards > core_cfg.mem_words {
            return Err(anyhow!(
                "--shards {} exceeds --memory {} (at most one shard per memory word)",
                core_cfg.shards,
                core_cfg.mem_words
            ));
        }
        if core_cfg.row_format != RowFormat::F32
            && !matches!(core, CoreKind::Sam | CoreKind::Sdnc)
        {
            return Err(anyhow!(
                "--row-format {} requires a sparse-memory model (sam|sdnc); \
                 --model {core:?} stores rows as plain f32",
                core_cfg.row_format.name()
            ));
        }
        let train_cfg = TrainConfig {
            lr: args.f32_or("lr", 1e-4),
            batch: args.usize_or("batch", 8),
            updates: args.usize_or("updates", 200),
            log_every: args.usize_or("log-every", 10),
            seed: args.u64_or("seed", 1) ^ 0x5555,
            verbose: !args.has("quiet"),
            // Episode lanes fused per worker through the batched training
            // tick (1 = the serial per-episode path). Same seed ⇒ same
            // result at any B for ann=linear; see `training::batched`.
            batch_fuse: args.usize_or("batch-fuse", 1).max(1),
        };
        Ok(ExperimentConfig {
            core,
            task,
            core_cfg,
            train_cfg,
            curriculum_max: args.get("curriculum-max").map(|v| v.parse().unwrap()),
            curriculum_threshold: args.get_or("curriculum-threshold", 0.05f32) as f64,
            workers: args.usize_or("workers", 1).max(1),
        })
    }
}

/// Build a task by name with paper-default dimensions.
pub fn build_task(name: &str) -> Result<Box<dyn Task>> {
    match name {
        "copy" => Ok(Box::new(CopyTask::new(6))),
        "recall" => Ok(Box::new(AssociativeRecall::new(6))),
        "sort" => Ok(Box::new(PrioritySort::new(6))),
        "omniglot" => Ok(Box::new(OmniglotTask::new(32, 32))),
        "babi" => Ok(Box::new(BabiTask::new())),
        other => Err(anyhow!("unknown task {other:?} (copy|recall|sort|omniglot|babi)")),
    }
}

/// Core config with the task's dimensions filled in — the single source of
/// core shape for training, checkpointing AND serving (a served checkpoint
/// must load into an identically-shaped core).
pub fn resolved_core_cfg(cfg: &ExperimentConfig, task: &dyn Task) -> CoreConfig {
    let mut core_cfg = cfg.core_cfg.clone();
    core_cfg.x_dim = task.x_dim();
    core_cfg.y_dim = task.y_dim();
    core_cfg
}

fn make_optimizer(cfg: &ExperimentConfig) -> Box<dyn Optimizer> {
    if std::env::var("SAM_ADAM").is_ok() {
        Box::new(Adam::new(cfg.train_cfg.lr))
    } else {
        Box::new(RmsProp::new(cfg.train_cfg.lr))
    }
}

/// Build core + optimizer + trainer for an experiment (task dims are filled
/// into the core config automatically).
pub fn build_trainer(cfg: &ExperimentConfig, task: &dyn Task) -> Trainer {
    let core_cfg = resolved_core_cfg(cfg, task);
    let mut rng = Rng::new(core_cfg.seed);
    let core = build_core(cfg.core, &core_cfg, &mut rng);
    Trainer::new(core, make_optimizer(cfg), cfg.train_cfg.clone())
}

/// Build the data-parallel trainer with `cfg.workers` identical replicas
/// (each constructed from a fresh seeded Rng so replicas agree bit-for-bit).
pub fn build_parallel_trainer(cfg: &ExperimentConfig, task: &dyn Task) -> ParallelTrainer {
    let core_cfg = resolved_core_cfg(cfg, task);
    let mut factory = |_i: usize| {
        let mut rng = Rng::new(core_cfg.seed);
        build_core(cfg.core, &core_cfg, &mut rng)
    };
    ParallelTrainer::new(&mut factory, cfg.workers, make_optimizer(cfg), cfg.train_cfg.clone())
}

/// Build the threads × batch trainer: `cfg.workers` threads, each fusing
/// up to `train_cfg.batch_fuse` episode lanes per tick (all lanes are
/// identical replicas; see `training::batched` for the determinism
/// contract).
pub fn build_fused_trainer(cfg: &ExperimentConfig, task: &dyn Task) -> FusedTrainer {
    let core_cfg = resolved_core_cfg(cfg, task);
    FusedTrainer::new(cfg.core, &core_cfg, cfg.workers, make_optimizer(cfg), cfg.train_cfg.clone())
}

/// Run a full training experiment; returns (trainer, log). With
/// `--batch-fuse B > 1` training runs on the lane-fused [`FusedTrainer`]
/// (threads × batch); otherwise `cfg.workers > 1` runs on the threaded
/// [`ParallelTrainer`]. Either way the primary replica is handed back
/// wrapped in a serial [`Trainer`] so checkpointing/eval flows are
/// identical, and a fixed seed gives bit-identical results across all
/// three paths for `ann=linear`.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<(Trainer, TrainLog)> {
    let task = build_task(&cfg.task)?;
    let mut curriculum = match cfg.curriculum_max {
        Some(max) => {
            Curriculum::exponential(task.base_level(), max, cfg.curriculum_threshold)
        }
        None => Curriculum::fixed(task.base_level()),
    };
    if cfg.train_cfg.batch_fuse > 1 {
        let mut ft = build_fused_trainer(cfg, task.as_ref());
        let log = ft.run(task.as_ref(), &mut curriculum);
        let (core, opt) = ft.into_primary();
        return Ok((Trainer::new(core, opt, cfg.train_cfg.clone()), log));
    }
    if cfg.workers > 1 {
        let mut pt = build_parallel_trainer(cfg, task.as_ref());
        let log = pt.run(task.as_ref(), &mut curriculum);
        let (core, opt) = pt.into_primary();
        return Ok((Trainer::new(core, opt, cfg.train_cfg.clone()), log));
    }
    let mut trainer = build_trainer(cfg, task.as_ref());
    let log = trainer.run(task.as_ref(), &mut curriculum);
    Ok((trainer, log))
}

// ---------------------------------------------------------------------------
// Checkpoints (flat f32 + JSON header)
// ---------------------------------------------------------------------------

/// Save core parameters to a simple binary checkpoint with a JSON header.
/// The version-2 header records the core kind and the shape knobs that
/// determine the parameter layout, so a load into a differently-shaped (or
/// different-kind) core is rejected instead of silently misassigning
/// weights.
pub fn save_checkpoint(core: &mut dyn Core, cfg: &CoreConfig, path: &Path) -> Result<()> {
    let values = core.save_values();
    let header = Json::obj(vec![
        ("name", Json::str(core.name())),
        ("params", Json::num(values.len() as f64)),
        ("version", Json::num(2.0)),
        ("x_dim", Json::num(cfg.x_dim as f64)),
        ("y_dim", Json::num(cfg.y_dim as f64)),
        ("hidden", Json::num(cfg.hidden as f64)),
        ("heads", Json::num(cfg.heads as f64)),
        ("word", Json::num(cfg.word as f64)),
        ("mem_words", Json::num(cfg.mem_words as f64)),
    ])
    .encode();
    let mut bytes = Vec::with_capacity(8 + header.len() + values.len() * 4);
    bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    for v in &values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("write checkpoint {path:?}"))?;
    Ok(())
}

/// Parse a checkpoint into (header, values), validating the body against
/// the header's param count and rejecting non-finite values — a NaN/inf
/// weight would poison every session sharing the Arc'd params, and serving
/// only guards its *inputs*.
fn parse_checkpoint(path: &Path) -> Result<(Json, Vec<f32>)> {
    let bytes = std::fs::read(path).with_context(|| format!("read checkpoint {path:?}"))?;
    if bytes.len() < 8 {
        return Err(anyhow!("truncated checkpoint"));
    }
    let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
    if bytes.len() < 8 + hlen {
        return Err(anyhow!("truncated checkpoint header"));
    }
    let header = std::str::from_utf8(&bytes[8..8 + hlen]).context("bad header")?;
    let meta = Json::parse(header).map_err(|e| anyhow!("header json: {e}"))?;
    let expect = meta
        .get("params")
        .and_then(|j| j.as_f64())
        .ok_or_else(|| anyhow!("header missing params"))?;
    let body = &bytes[8 + hlen..];
    let n = body.len() / 4;
    if n != expect as usize {
        return Err(anyhow!("checkpoint has {n} params, header says {expect}"));
    }
    let mut values = Vec::with_capacity(n);
    for (i, c) in body.chunks_exact(4).enumerate() {
        let v = f32::from_le_bytes(c.try_into().unwrap());
        if !v.is_finite() {
            return Err(anyhow!(
                "checkpoint param {i} is not finite ({v}); refusing to load a poisoned model"
            ));
        }
        values.push(v);
    }
    Ok((meta, values))
}

/// Validate the checkpoint header against the target core's kind and shape.
/// Legacy version-1 headers carry no shape fields, so only the kind (and
/// the param count, checked by the caller) can be verified for those.
fn validate_checkpoint_header(meta: &Json, name: &str, cfg: &CoreConfig) -> Result<()> {
    let ckpt_name = meta
        .get("name")
        .and_then(|j| j.as_str())
        .ok_or_else(|| anyhow!("header missing name"))?;
    if ckpt_name != name {
        return Err(anyhow!(
            "checkpoint is for core {ckpt_name:?} but the target core is {name:?}"
        ));
    }
    for (key, want) in [
        ("x_dim", cfg.x_dim),
        ("y_dim", cfg.y_dim),
        ("hidden", cfg.hidden),
        ("heads", cfg.heads),
        ("word", cfg.word),
        ("mem_words", cfg.mem_words),
    ] {
        // Absent in legacy v1 headers: skip, the param-count check remains.
        if let Some(got) = meta.get(key).and_then(|j| j.as_f64()) {
            if got as usize != want {
                return Err(anyhow!(
                    "checkpoint {key} is {} but the target core has {key} {want}",
                    got as usize
                ));
            }
        }
    }
    Ok(())
}

/// Read a checkpoint produced by [`save_checkpoint`] back into flat f32
/// values (`HasParams::load_values` layout). The serving runtime uses this
/// to load trained weights into an `InferModel` at build time
/// (`serving::build_infer_model`).
pub fn read_checkpoint(path: &Path) -> Result<Vec<f32>> {
    Ok(parse_checkpoint(path)?.1)
}

/// [`read_checkpoint`] plus header validation against the core kind `name`
/// and shape `cfg` the values are destined for — the serve path's guard.
pub fn read_checkpoint_for(path: &Path, name: &str, cfg: &CoreConfig) -> Result<Vec<f32>> {
    let (meta, values) = parse_checkpoint(path)?;
    validate_checkpoint_header(&meta, name, cfg)?;
    Ok(values)
}

/// Load a checkpoint produced by [`save_checkpoint`] into `core`, rejecting
/// a checkpoint whose recorded kind or shape does not match.
pub fn load_checkpoint(core: &mut dyn Core, cfg: &CoreConfig, path: &Path) -> Result<()> {
    let (meta, values) = parse_checkpoint(path)?;
    validate_checkpoint_header(&meta, core.name(), cfg)?;
    if values.len() != core.param_count() {
        return Err(anyhow!(
            "checkpoint has {} params but the target core has {}",
            values.len(),
            core.param_count()
        ));
    }
    core.load_values(&values);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_from_args_defaults() {
        let args = Args::parse(Vec::<String>::new());
        let cfg = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(cfg.core, CoreKind::Sam);
        assert_eq!(cfg.core_cfg.hidden, 100);
        assert_eq!(cfg.core_cfg.heads, 4);
        assert_eq!(cfg.core_cfg.k, 4);
    }

    #[test]
    fn config_overrides() {
        let args = Args::parse(
            "--model dnc --task babi --memory 64 --ann kdtree --lr 0.001"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(cfg.core, CoreKind::Dnc);
        assert_eq!(cfg.task, "babi");
        assert_eq!(cfg.core_cfg.mem_words, 64);
        assert_eq!(cfg.core_cfg.ann, AnnKind::KdForest);
        // The graph backend parses through the same FromStr path.
        let args = Args::parse("--ann hnsw".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().core_cfg.ann, AnnKind::Hnsw);
    }

    #[test]
    fn shards_flag_parsed_and_defaulted() {
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().core_cfg.shards, 1);
        let args = Args::parse("--shards 4".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().core_cfg.shards, 4);
        let args = Args::parse("--shards 0".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().core_cfg.shards, 1);
        // More shards than memory words is a config error, not a panic.
        let args = Args::parse("--memory 4 --shards 8".split_whitespace().map(String::from));
        assert!(ExperimentConfig::from_args(&args).is_err());
    }

    #[test]
    fn row_format_flag_parsed_and_validated() {
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(
            ExperimentConfig::from_args(&args).unwrap().core_cfg.row_format,
            RowFormat::F32
        );
        for (flag, want) in [("bf16", RowFormat::Bf16), ("int8", RowFormat::Int8)] {
            let args =
                Args::parse(format!("--row-format {flag}").split_whitespace().map(String::from));
            assert_eq!(ExperimentConfig::from_args(&args).unwrap().core_cfg.row_format, want);
        }
        // Unknown codec is a usage error.
        let args = Args::parse("--row-format f16".split_whitespace().map(String::from));
        assert!(ExperimentConfig::from_args(&args).is_err());
        // Compact rows only exist in the sparse engines.
        let args =
            Args::parse("--model dam --row-format bf16".split_whitespace().map(String::from));
        assert!(ExperimentConfig::from_args(&args).is_err());
        let args =
            Args::parse("--model sdnc --row-format int8".split_whitespace().map(String::from));
        assert!(ExperimentConfig::from_args(&args).is_ok());
    }

    #[test]
    fn workers_flag_parsed_and_defaulted() {
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().workers, 1);
        let args = Args::parse("--workers 4".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().workers, 4);
        let args = Args::parse("--workers 0".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().workers, 1);
    }

    #[test]
    fn batch_fuse_flag_parsed_and_defaulted() {
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().train_cfg.batch_fuse, 1);
        let args = Args::parse("--batch-fuse 8".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().train_cfg.batch_fuse, 8);
        let args = Args::parse("--batch-fuse 0".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::from_args(&args).unwrap().train_cfg.batch_fuse, 1);
    }

    #[test]
    fn run_experiment_fused_path() {
        let args = Args::parse(
            "--model sam --task copy --hidden 8 --memory 8 --word 6 --heads 1 --k 2 \
             --batch 3 --updates 3 --workers 2 --batch-fuse 2 --quiet"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ExperimentConfig::from_args(&args).unwrap();
        let (mut trainer, log) = run_experiment(&cfg).unwrap();
        assert_eq!(log.total_episodes, 9);
        let task = build_task("copy").unwrap();
        let errs = trainer.evaluate(task.as_ref(), 2, 2, 7);
        assert!(errs >= 0.0);
    }

    #[test]
    fn run_experiment_parallel_path() {
        let args = Args::parse(
            "--model lstm --task copy --hidden 8 --memory 8 --word 6 --heads 1 \
             --batch 2 --updates 3 --workers 2 --quiet"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ExperimentConfig::from_args(&args).unwrap();
        let (mut trainer, log) = run_experiment(&cfg).unwrap();
        assert_eq!(log.total_episodes, 6);
        // The handed-back primary still evaluates.
        let task = build_task("copy").unwrap();
        let errs = trainer.evaluate(task.as_ref(), 2, 2, 7);
        assert!(errs >= 0.0);
    }

    fn test_core_cfg(seed: u64) -> CoreConfig {
        let task = CopyTask::new(4);
        CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let core_cfg = test_core_cfg(3);
        let mut rng = Rng::new(3);
        let mut core = build_core(CoreKind::Sam, &core_cfg, &mut rng);
        let orig = core.save_values();
        let tmp = std::env::temp_dir().join("sam_ckpt_test.bin");
        save_checkpoint(core.as_mut(), &core_cfg, &tmp).unwrap();
        // perturb then reload
        let zeros = vec![0.0f32; orig.len()];
        core.load_values(&zeros);
        load_checkpoint(core.as_mut(), &core_cfg, &tmp).unwrap();
        assert_eq!(core.save_values(), orig);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn checkpoint_kind_and_shape_mismatches_rejected() {
        // A checkpoint from one core kind/shape must not silently load into
        // another — wrong-kind and wrong-shape loads both fail with a clear
        // error even when param counts happen to be irrelevant.
        let core_cfg = test_core_cfg(5);
        let mut rng = Rng::new(5);
        let mut sam = build_core(CoreKind::Sam, &core_cfg, &mut rng);
        let tmp = std::env::temp_dir().join("sam_ckpt_mismatch_test.bin");
        save_checkpoint(sam.as_mut(), &core_cfg, &tmp).unwrap();

        // Wrong core kind.
        let mut rng = Rng::new(5);
        let mut dnc = build_core(CoreKind::Dnc, &core_cfg, &mut rng);
        let err = load_checkpoint(dnc.as_mut(), &core_cfg, &tmp).unwrap_err();
        assert!(err.to_string().contains("core"), "unhelpful error: {err}");

        // Wrong memory shape, same kind.
        let mut wide = core_cfg.clone();
        wide.mem_words = 16;
        let mut rng = Rng::new(5);
        let mut sam_wide = build_core(CoreKind::Sam, &wide, &mut rng);
        let err = load_checkpoint(sam_wide.as_mut(), &wide, &tmp).unwrap_err();
        assert!(err.to_string().contains("mem_words"), "unhelpful error: {err}");

        // The serve-path reader applies the same validation.
        assert!(read_checkpoint_for(&tmp, "sam", &core_cfg).is_ok());
        assert!(read_checkpoint_for(&tmp, "dnc", &core_cfg).is_err());
        assert!(read_checkpoint_for(&tmp, "sam", &wide).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn checkpoint_with_non_finite_params_rejected() {
        // A NaN weight would poison every session sharing the params; the
        // reader must refuse it with the offending index.
        let core_cfg = test_core_cfg(6);
        let mut rng = Rng::new(6);
        let mut core = build_core(CoreKind::Sam, &core_cfg, &mut rng);
        let tmp = std::env::temp_dir().join("sam_ckpt_nan_test.bin");
        save_checkpoint(core.as_mut(), &core_cfg, &tmp).unwrap();

        // Corrupt one param in the body to NaN (header length prefix +
        // header text precede the flat f32 body).
        let mut bytes = std::fs::read(&tmp).unwrap();
        let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        let body = 8 + hlen;
        bytes[body..body + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        std::fs::write(&tmp, &bytes).unwrap();

        let err = read_checkpoint(&tmp).unwrap_err();
        assert!(err.to_string().contains("not finite"), "unhelpful error: {err}");
        assert!(load_checkpoint(core.as_mut(), &core_cfg, &tmp).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn unknown_task_rejected() {
        assert!(build_task("nope").is_err());
    }
}
