//! Benchmark support: a tiny criterion replacement (criterion is not
//! available in the offline build image) shared by the `benches/` binaries
//! that regenerate the paper's tables and figures.
//!
//! Conventions: every bench prints a markdown table mirroring the paper's
//! rows/series and writes the raw numbers to `results/<bench>.json` for
//! EXPERIMENTS.md. `cargo bench` runs them all at a reduced default scale;
//! pass `--paper-scale` for the full sweeps.

use crate::util::json::Json;
use crate::util::timer::{time_reps, Stats};

/// Markdown-ish table printer with aligned columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Write bench results JSON under `results/` (created on demand).
pub fn save_results(bench: &str, value: Json) {
    let dir = std::path::Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{bench}.json"));
    if let Err(e) = std::fs::write(&path, value.encode()) {
        eprintln!("warn: could not write {path:?}: {e}");
    } else {
        println!("\nresults written to {path:?}");
    }
}

/// Write a perf-regression JSON at the repo root: `BENCH_<name>.json`
/// (override the directory with `BENCH_OUT_DIR`). These files are the
/// measured perf trajectory: `benches/kernels.rs` populates them, CI
/// uploads them as artifacts, and future kernel/hot-path changes are
/// judged against the numbers they record.
///
/// Every object payload is stamped with the dispatched kernel path
/// (`"kernel_path"`: avx2 | scalar) and the memory-row codec
/// (`"row_format"`, default `"f32"`) unless the bench already set them —
/// perf numbers are meaningless without knowing which code path and row
/// width produced them.
pub fn save_bench_root(name: &str, value: Json) {
    let value = stamp_bench_context(value);
    let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    if let Err(e) = std::fs::write(&path, value.encode()) {
        eprintln!("warn: could not write {path:?}: {e}");
    } else {
        println!("\nbench results written to {path:?}");
    }
}

/// Inject `kernel_path` / `row_format` into an object payload when absent
/// (non-object payloads pass through untouched).
fn stamp_bench_context(value: Json) -> Json {
    match value {
        Json::Obj(mut map) => {
            map.entry("kernel_path".to_string())
                .or_insert_with(|| Json::Str(crate::tensor::simd::kernel_path_name().to_string()));
            map.entry("row_format".to_string())
                .or_insert_with(|| Json::Str("f32".to_string()));
            Json::Obj(map)
        }
        other => other,
    }
}

/// GFLOP/s for `flops` floating-point operations done in `secs` seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs > 0.0 {
        flops / secs / 1e9
    } else {
        0.0
    }
}

/// Measure a closure: warmup once, then `reps` timed runs.
pub fn measure<F: FnMut()>(reps: usize, f: F) -> Stats {
    time_reps(1, reps.max(1), f)
}

/// Format seconds like the paper's axes (ms / s).
pub fn fmt_time(s: f64) -> String {
    crate::util::timer::fmt_duration(s)
}

/// Human bytes.
pub fn fmt_bytes(b: usize) -> String {
    crate::util::alloc::fmt_bytes(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["model", "N", "ms"]);
        t.row(vec!["sam".into(), "65536".into(), "0.7".into()]);
        t.row(vec!["ntm".into(), "64".into(), "12.0".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn bench_payloads_are_stamped_with_dispatch_context() {
        let stamped = stamp_bench_context(Json::obj(vec![("x", Json::Num(1.0))]));
        let Json::Obj(map) = &stamped else { panic!("object in, object out") };
        assert_eq!(
            map.get("kernel_path"),
            Some(&Json::Str(crate::tensor::simd::kernel_path_name().to_string()))
        );
        assert_eq!(map.get("row_format"), Some(&Json::Str("f32".to_string())));
        // Bench-provided values win over the injected defaults.
        let explicit = stamp_bench_context(Json::obj(vec![(
            "row_format",
            Json::Str("bf16".to_string()),
        )]));
        let Json::Obj(map) = &explicit else { panic!() };
        assert_eq!(map.get("row_format"), Some(&Json::Str("bf16".to_string())));
        // Non-object payloads pass through untouched.
        assert_eq!(stamp_bench_context(Json::Num(3.0)), Json::Num(3.0));
    }

    #[test]
    fn gflops_math() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-9);
        assert_eq!(gflops(1e9, 0.0), 0.0);
    }

    #[test]
    fn measure_runs() {
        let s = measure(3, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 3);
    }
}
