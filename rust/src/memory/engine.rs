//! The shared sparse-memory engine (paper §3.1–3.5).
//!
//! Every sparse core used to carry its own copy of the same mechanism:
//! a [`MemoryStore`], an ANN index kept in sync by a `touched`-set +
//! end-of-episode resync loop, an LRA ring, per-step write journals and a
//! row-sparse carried memory gradient. [`SparseMemoryEngine`] owns all of
//! that state behind one small differentiable API, so SAM, SDNC and (via
//! the dense sub-API) DAM share a single implementation:
//!
//! * **Forward**: [`sparse_write`](SparseMemoryEngine::sparse_write) applies
//!   eq. 5's gated write, journals the touched rows, updates the LRA ring
//!   and keeps the ANN in sync *incrementally* via
//!   [`AnnIndex::update_row`]; [`read_topk_into`](SparseMemoryEngine::read_topk_into)
//!   answers all heads' content reads with one batched
//!   [`AnnIndex::query_many_into`] traversal (eq. 2/4).
//! * **Backward**: [`backward_write_into`](SparseMemoryEngine::backward_write_into)
//!   consumes the journal tape in reverse, rolling the memory back in place
//!   (§3.4, O(1) space per step) and re-syncing the ANN rows it restores;
//!   the read-side helpers accumulate into the carried [`RowSparse`]
//!   memory gradient.
//!
//! Because the ANN is updated on *both* write and revert, it is in sync
//! with the memory at every step boundary: there is no per-episode resync
//! loop and no full rebuild on the default path — index restructuring is
//! amortized inside the index implementations themselves.
//!
//! NOTE: `memory/sharded.rs` mirrors this engine's write/backward float-op
//! sequences for its S>1 paths (see the mirror-maintenance contract there)
//! — numerics changes here must be reflected there, with
//! rust/tests/shard_parity.rs as the bitwise drift alarm.
//!
//! **Zero-allocation hot path**: every per-step buffer (journal rows, gate
//! weights, content-read caches, read words, gradient vectors) is drawn
//! from the caller's [`Workspace`] and recycled back when its step is
//! backpropagated, so a steady-state step performs no heap allocations
//! (rust/tests/zero_alloc.rs). Buffers the caller keeps on its tape
//! (ContentRead, gate weights, TopKRead parts) must be returned via
//! [`recycle_content_read`](SparseMemoryEngine::recycle_content_read) /
//! `Workspace::recycle_*` during backward — the same workspace must serve
//! all of a core's engine calls.

use crate::ann::{build_index_fmt, AnnIndex, AnnKind};
use crate::cores::addressing::{
    content_weights_backward_ws, content_weights_into, write_gate_backward_ws, write_gate_ws,
    ContentRead, CosSim, WriteGate,
};
use crate::memory::store::{MemoryStore, StepJournal};
use crate::memory::usage::LraRing;
use crate::tensor::csr::{RowSparse, SparseVec};
use crate::tensor::matrix::dot;
use crate::tensor::rowcodec::RowFormat;
use crate::tensor::workspace::{Pool, Workspace};
use crate::util::metrics;
use crate::util::rng::Rng;

/// Episode-start contents of memory row `i`: small deterministic noise
/// (std [`MEM_INIT_STD`]) regenerable per row in O(W). A strictly zero
/// memory makes every content similarity tie at episode start, which makes
/// the ANN's top-K selection arbitrary; tiny distinct words break the ties
/// without carrying information.
pub const MEM_INIT_STD: f32 = 0.02;

pub fn init_row(seed: u64, i: usize, out: &mut [f32]) {
    let mut r = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    for v in out {
        *v = r.normal() * MEM_INIT_STD;
    }
}

/// One head's batched content read: the ANN/content caches the backward
/// pass needs, the sparse read weights w̃^R, and the read word r̃ (eq. 4).
/// All buffers are workspace-pooled; the consuming core recycles them at
/// backward time.
pub struct TopKRead {
    pub read: ContentRead,
    pub weights: SparseVec,
    pub r: Vec<f32>,
}

/// Shared tail of `read_topk_into`: turn each drained [`ContentRead`] into
/// a [`TopKRead`] (pooled weight vector + mixture read through
/// `read_mixture`). One implementation serves both the single engine and
/// the sharded wrapper so their assembly can never drift.
pub(crate) fn assemble_topk_reads(
    crs: &mut Vec<ContentRead>,
    word: usize,
    out: &mut Vec<TopKRead>,
    ws: &mut Workspace,
    mut read_mixture: impl FnMut(&SparseVec, &mut Vec<f32>),
) {
    for read in crs.drain(..) {
        let mut pairs = ws.take_pairs();
        pairs.extend(read.rows.iter().copied().zip(read.weights.iter().copied()));
        let mut weights = ws.take_sparse();
        weights.assign_from_pairs(&mut pairs);
        ws.recycle_pairs(pairs);
        let mut r = ws.take_f32(word);
        read_mixture(&weights, &mut r);
        out.push(TopKRead { read, weights, r });
    }
}

/// Owns the external memory and every auxiliary structure that must stay
/// consistent with it. Cores own only their controller, head parameters and
/// model-specific state (e.g. the SDNC's temporal links).
pub struct SparseMemoryEngine {
    mem: MemoryStore,
    /// `None` for the dense control models (DAM), which never content-query.
    ann: Option<Box<dyn AnnIndex>>,
    /// `None` in dense mode — DAM selects write targets by discounted-usage
    /// argmin, so allocating 2N usizes of LRA state would be dead weight.
    ring: Option<LraRing>,
    /// The episode's write tape, one journal per `sparse_write`, in write
    /// order. `backward_write_into`/`rollback` consume it in reverse.
    journals: Vec<StepJournal>,
    /// Carried row-sparse memory gradient ∂L/∂M (Supp A).
    dmem: RowSparse,
    /// Sparse reads per head (paper: K = 4).
    k: usize,
    /// Usage threshold δ for LRA touches (paper: 0.005).
    delta: f32,
    /// Seed the memory rows were initialized from ([`init_row`]); kept so a
    /// serving session can [`reinit`](SparseMemoryEngine::reinit) back to
    /// the episode-start state without journals, allocation-free.
    mem_seed: u64,
    /// Global-id mapping for row init: local row `l` seeds as global row
    /// `l * init_stride + init_offset`. (1, 0) for a standalone engine;
    /// (S, s) when this engine is shard `s` of a
    /// [`crate::memory::sharded::ShardedMemoryEngine`], which is what makes
    /// a sharded memory's episode-start contents bit-identical to the
    /// unsharded layout.
    init_stride: usize,
    init_offset: usize,
    // -- reusable scratch (engine-internal; never per-episode state) --------
    /// Drained journal shells awaiting refill (their `saved` capacity).
    spare_journals: Vec<StepJournal>,
    /// Batched ANN result buffers, one per head.
    neigh: Vec<Vec<(usize, f32)>>,
    /// CosSim cache buffers for ContentRead (CosSim lives in `cores`, so
    /// the pool lives here rather than in the type-agnostic Workspace).
    sim_pool: Pool<CosSim>,
    /// ContentRead staging for `read_topk_into`.
    cr_tmp: Vec<ContentRead>,
    /// dL/dweights staging for `backward_read_topk`.
    dw_scratch: Vec<f32>,
    /// Decoded-row staging for ANN sync on compact-format stores (empty
    /// for f32, where the row is borrowed directly).
    row_scratch: Vec<f32>,
}

impl SparseMemoryEngine {
    /// Sparse engine (SAM/SDNC): deterministically-initialized memory rows,
    /// an ANN index over them, and an LRA ring. Draws `mem_seed` then the
    /// ANN seed from `rng`, in that order.
    pub fn new_sparse(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        rng: &mut Rng,
    ) -> SparseMemoryEngine {
        let mem_seed = rng.next_u64();
        let ann_seed = rng.next_u64();
        SparseMemoryEngine::new_sparse_from_seeds(n, word, k, delta, kind, mem_seed, ann_seed)
    }

    /// [`new_sparse`](SparseMemoryEngine::new_sparse) with the memory-init
    /// and ANN seeds given explicitly. Cores record the two seeds they drew
    /// so serving sessions can construct engines whose episode-start state
    /// is bit-identical to the trained core's — the infer-parity guarantee.
    pub fn new_sparse_from_seeds(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
    ) -> SparseMemoryEngine {
        SparseMemoryEngine::new_sparse_from_seeds_fmt(
            n,
            word,
            k,
            delta,
            kind,
            mem_seed,
            ann_seed,
            RowFormat::F32,
        )
    }

    /// [`new_sparse_from_seeds`](SparseMemoryEngine::new_sparse_from_seeds)
    /// with an explicit row format. Compact stores are initialized by
    /// encoding the same deterministic [`init_row`] noise, and the ANN is
    /// fed the *decoded* rows (what the store actually holds), keeping the
    /// index consistent with every later decode-on-read scan.
    #[allow(clippy::too_many_arguments)]
    pub fn new_sparse_from_seeds_fmt(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        fmt: RowFormat,
    ) -> SparseMemoryEngine {
        let (mem, ann, row_scratch) = Self::build_store_and_index(
            n, word, kind, mem_seed, ann_seed, 1, 0, fmt,
        );
        SparseMemoryEngine {
            mem,
            ann: Some(ann),
            ring: Some(LraRing::new(n)),
            journals: Vec::new(),
            dmem: RowSparse::new(word),
            k,
            delta,
            mem_seed,
            init_stride: 1,
            init_offset: 0,
            spare_journals: Vec::new(),
            neigh: Vec::new(),
            sim_pool: Pool::new(),
            cr_tmp: Vec::new(),
            dw_scratch: Vec::new(),
            row_scratch,
        }
    }

    /// Shared store+index construction: deterministic row init through the
    /// global-id mapping, f32 rows borrowed straight into the ANN, compact
    /// rows encoded then re-decoded for the insert.
    #[allow(clippy::too_many_arguments)]
    fn build_store_and_index(
        n_local: usize,
        word: usize,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        stride: usize,
        offset: usize,
        fmt: RowFormat,
    ) -> (MemoryStore, Box<dyn AnnIndex>, Vec<f32>) {
        let mut mem = MemoryStore::zeros_fmt(n_local, word, fmt);
        let mut ann = build_index_fmt(kind, n_local, word, ann_seed, fmt);
        if fmt == RowFormat::F32 {
            for l in 0..n_local {
                init_row(mem_seed, l * stride + offset, mem.row_mut(l));
            }
            for l in 0..n_local {
                ann.insert(l, mem.row(l));
            }
            (mem, ann, Vec::new())
        } else {
            let mut scratch = vec![0.0; word];
            for l in 0..n_local {
                init_row(mem_seed, l * stride + offset, &mut scratch);
                mem.set_row(l, &scratch);
            }
            for l in 0..n_local {
                mem.decode_row_into(l, &mut scratch);
                ann.insert(l, &scratch);
            }
            (mem, ann, scratch)
        }
    }

    /// One shard of a [`crate::memory::sharded::ShardedMemoryEngine`]:
    /// `n_local` rows that are the global rows `l * stride + offset`,
    /// seeded from the *global* `mem_seed` so the union of S shards holds
    /// bit-identical contents to one unsharded engine. A shard owns its
    /// store, ANN index and journal tape; the LRA ring, carried gradient
    /// and read/write orchestration stay global in the sharded wrapper, so
    /// no ring is allocated and the ring-dependent entry points
    /// (`sparse_write`, `read_topk_into`, …) must not be called on it —
    /// shards are driven through the `shard_*` methods below.
    pub fn new_shard(
        n_local: usize,
        word: usize,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        stride: usize,
        offset: usize,
    ) -> SparseMemoryEngine {
        SparseMemoryEngine::new_shard_fmt(
            n_local,
            word,
            kind,
            mem_seed,
            ann_seed,
            stride,
            offset,
            RowFormat::F32,
        )
    }

    /// [`new_shard`](SparseMemoryEngine::new_shard) with an explicit row
    /// format; see
    /// [`new_sparse_from_seeds_fmt`](SparseMemoryEngine::new_sparse_from_seeds_fmt)
    /// for the compact-initialization contract.
    #[allow(clippy::too_many_arguments)]
    pub fn new_shard_fmt(
        n_local: usize,
        word: usize,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        stride: usize,
        offset: usize,
        fmt: RowFormat,
    ) -> SparseMemoryEngine {
        let (mem, ann, row_scratch) = Self::build_store_and_index(
            n_local, word, kind, mem_seed, ann_seed, stride, offset, fmt,
        );
        SparseMemoryEngine {
            mem,
            ann: Some(ann),
            ring: None,
            journals: Vec::new(),
            dmem: RowSparse::new(word),
            k: 0,
            delta: 0.0,
            mem_seed,
            init_stride: stride,
            init_offset: offset,
            spare_journals: Vec::new(),
            neigh: Vec::new(),
            sim_pool: Pool::new(),
            cr_tmp: Vec::new(),
            dw_scratch: Vec::new(),
            row_scratch,
        }
    }

    /// Dense engine (DAM): zero-initialized memory, no ANN. The dense
    /// control models snapshot/restore instead of journaling, so the
    /// journal tape stays empty.
    pub fn new_dense(n: usize, word: usize) -> SparseMemoryEngine {
        SparseMemoryEngine {
            mem: MemoryStore::zeros(n, word),
            ann: None,
            ring: None,
            journals: Vec::new(),
            dmem: RowSparse::new(word),
            k: 0,
            delta: 0.0,
            mem_seed: 0,
            init_stride: 1,
            init_offset: 0,
            spare_journals: Vec::new(),
            neigh: Vec::new(),
            sim_pool: Pool::new(),
            cr_tmp: Vec::new(),
            dw_scratch: Vec::new(),
            row_scratch: Vec::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.mem.n()
    }

    pub fn word_size(&self) -> usize {
        self.mem.word_size()
    }

    /// Read-only view of the memory, for addressing math that takes
    /// `&MemoryStore` (e.g. the dense models' `content_weights`).
    pub fn store(&self) -> &MemoryStore {
        &self.mem
    }

    /// Storage format of the memory rows (f32 or a compact codec).
    pub fn row_format(&self) -> RowFormat {
        self.mem.fmt()
    }

    // -- forward ------------------------------------------------------------

    /// Gated sparse write (eq. 5/8) for one head: pops the LRA target,
    /// interpolates the write weights, erases the LRA row, applies the
    /// sparse add, journals the prior row contents, touches the ring and
    /// incrementally syncs the ANN. Returns the gate cache for backward;
    /// the caller owns it (tape) and recycles `gate.weights` into `ws`
    /// after `backward_write_into`.
    pub fn sparse_write(
        &mut self,
        alpha_raw: f32,
        gamma_raw: f32,
        w_read_prev: &SparseVec,
        word: &[f32],
        ws: &mut Workspace,
    ) -> WriteGate {
        metrics::MEM_WRITES.inc();
        let ring = self.ring.as_mut().expect("sparse_write needs a sparse engine (LRA ring)");
        let lra_row = ring.pop_lra();
        let gate = write_gate_ws(alpha_raw, gamma_raw, w_read_prev, lra_row, ws);
        let mut journal = self.spare_journals.pop().unwrap_or_default();
        self.mem.journal_sparse_write(lra_row, &gate.weights, word, &mut journal, ws);
        let ring = self.ring.as_mut().unwrap();
        for (i, wv) in gate.weights.iter() {
            if wv.abs() > self.delta {
                ring.touch(i);
            }
        }
        self.sync_rows(&journal);
        self.journals.push(journal);
        gate
    }

    /// Forward-only gated sparse write (serving mode): identical write
    /// semantics, LRA touches and incremental ANN sync as
    /// [`sparse_write`](SparseMemoryEngine::sparse_write), but **nothing is
    /// journaled** — the memory advances irreversibly and
    /// [`tape_bytes`](SparseMemoryEngine::tape_bytes) stays 0. Returns the
    /// ws-pooled write weights (the SDNC aggregates them for its link
    /// update); the caller recycles them into `ws`. Zero steady-state heap
    /// allocations.
    pub fn infer_write(
        &mut self,
        alpha_raw: f32,
        gamma_raw: f32,
        w_read_prev: &SparseVec,
        word: &[f32],
        ws: &mut Workspace,
    ) -> SparseVec {
        metrics::MEM_WRITES.inc();
        let ring = self.ring.as_mut().expect("infer_write needs a sparse engine (LRA ring)");
        let lra_row = ring.pop_lra();
        let gate = write_gate_ws(alpha_raw, gamma_raw, w_read_prev, lra_row, ws);
        self.mem.apply_sparse_write(lra_row, &gate.weights, word);
        let ring = self.ring.as_mut().unwrap();
        for (i, wv) in gate.weights.iter() {
            if wv.abs() > self.delta {
                ring.touch(i);
            }
        }
        // ANN sync over the same row set the journaled path touches: the
        // erased row first, then the add support (minus the erase row).
        if self.ann.is_some() {
            self.ann_sync_row(lra_row);
            for (i, _) in gate.weights.iter() {
                if i != lra_row {
                    self.ann_sync_row(i);
                }
            }
        }
        gate.weights
    }

    /// Push one store row into the ANN index. F32 stores lend the row
    /// slice directly; compact stores decode into the persistent
    /// `row_scratch` first so the index always mirrors the *decoded*
    /// (post-quantization) contents, allocation-free in steady state.
    fn ann_sync_row(&mut self, row: usize) {
        let Some(ann) = self.ann.as_mut() else { return };
        if self.mem.fmt() == RowFormat::F32 {
            ann.update_row(row, self.mem.row(row));
        } else {
            self.mem.decode_row_into(row, &mut self.row_scratch);
            ann.update_row(row, &self.row_scratch);
        }
    }

    /// Re-initialize to the episode-start state without journals: memory
    /// rows regenerate from the recorded seed, the ANN re-syncs row by row
    /// and the ring resets. This is the serving session's episode boundary
    /// — O(N·W) like construction, but allocation-free (rows and index
    /// slots are overwritten in place). Dense engines zero-fill instead.
    pub fn reinit(&mut self) {
        debug_assert!(self.journals.is_empty(), "reinit with live journals (infer mode only)");
        let n = self.mem.n();
        if self.ann.is_some() {
            // Sparse mode (standalone or shard): regenerate the seeded init
            // through the global-id mapping and re-sync the index in place.
            let (seed, stride, offset) = (self.mem_seed, self.init_stride, self.init_offset);
            if self.mem.fmt() == RowFormat::F32 {
                for i in 0..n {
                    init_row(seed, i * stride + offset, self.mem.row_mut(i));
                }
            } else {
                for i in 0..n {
                    init_row(seed, i * stride + offset, &mut self.row_scratch);
                    self.mem.set_row(i, &self.row_scratch);
                }
            }
            for i in 0..n {
                self.ann_sync_row(i);
            }
            if let Some(ring) = self.ring.as_mut() {
                ring.reset();
            }
        } else {
            self.mem.fill(0.0);
        }
        self.dmem.clear();
    }

    // -- spill/rehydrate state hooks ----------------------------------------

    /// Overwrite local row `local` with decoded values and re-sync the ANN
    /// slot, mirroring [`reinit`](SparseMemoryEngine::reinit)'s set-then-sync
    /// order. For Int8 stores the journaled per-row `scale` reproduces the
    /// original storage codes bit-exactly; other formats quantize-on-write
    /// (f32 copies, bf16 re-encode is exact because the values being set
    /// were themselves bf16-decoded).
    pub(crate) fn import_row(&mut self, local: usize, vals: &[f32], scale: f32) {
        if self.mem.fmt() == RowFormat::Int8 {
            self.mem.set_row_with_scale(local, vals, scale);
        } else {
            self.mem.set_row(local, vals);
        }
        self.ann_sync_row(local);
    }

    /// Dequant scale of local row `local` (1.0 outside Int8).
    pub(crate) fn row_scale(&self, local: usize) -> f32 {
        self.mem.row_scale(local)
    }

    /// LRA ring order, least- to most-recently used (sparse engines only).
    pub(crate) fn ring_order(&self) -> Vec<usize> {
        self.ring.as_ref().expect("ring_order needs a sparse engine").order()
    }

    /// Restore a captured LRA ring order (sparse engines only).
    pub(crate) fn set_ring_order(&mut self, order: &[usize]) {
        self.ring.as_mut().expect("set_ring_order needs a sparse engine").set_order(order);
        self.dmem.clear();
    }

    /// Batched content reads for all heads (SAM's read path): one
    /// `query_many_into` index traversal, then per-head softmax weights,
    /// sparse read and ring touches, in head order. Results append to
    /// `out`; every buffer inside them is pooled from `ws` (plus the
    /// engine's sim pool) and must come back via
    /// [`recycle_content_read`](SparseMemoryEngine::recycle_content_read) /
    /// `ws.recycle_*` at backward time.
    pub fn read_topk_into(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<TopKRead>,
        ws: &mut Workspace,
    ) {
        self.ann_fill_neigh(queries);
        self.read_topk_from_neigh(queries, betas, out, ws);
    }

    /// The post-ANN half of [`read_topk_into`](Self::read_topk_into):
    /// per-head softmax weights, sparse read and ring touches from the
    /// neighbour lists already filled by
    /// [`ann_fill_neigh`](Self::ann_fill_neigh). The batched training tick
    /// calls the halves separately so B lanes' ANN lookups can merge into
    /// one pool dispatch.
    pub fn read_topk_from_neigh(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<TopKRead>,
        ws: &mut Workspace,
    ) {
        metrics::MEM_READS.add(queries.len() as u64);
        let mut crs = std::mem::take(&mut self.cr_tmp);
        self.content_read_many_from_neigh(queries, betas, &mut crs, ws);
        let word = self.mem.word_size();
        assemble_topk_reads(&mut crs, word, out, ws, |w, r| self.read_mixture_into(w, r));
        self.cr_tmp = crs;
    }

    /// Run the ANN lookup for a batch of queries into `self.neigh` (the
    /// first half of the content-read path; a single index, so always
    /// serial at this level).
    pub fn ann_fill_neigh(&mut self, queries: &[Vec<f32>]) {
        let ann = self.ann.as_mut().expect("content reads need a sparse engine (ANN)");
        ann.query_many_into(queries, self.k, &mut self.neigh);
    }

    /// Batched content-weight computation without the memory read or ring
    /// touches — for cores (SDNC) that mix content weights with other
    /// addressing modes before reading. Appends one ContentRead per query.
    pub fn content_read_many_into(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<ContentRead>,
        ws: &mut Workspace,
    ) {
        self.ann_fill_neigh(queries);
        self.content_read_many_from_neigh(queries, betas, out, ws);
    }

    /// The post-ANN half of
    /// [`content_read_many_into`](Self::content_read_many_into): per-head
    /// softmax weights over the neighbour lists already in `self.neigh`.
    pub fn content_read_many_from_neigh(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<ContentRead>,
        ws: &mut Workspace,
    ) {
        assert_eq!(queries.len(), betas.len());
        for (hi, (q, &beta_raw)) in queries.iter().zip(betas).enumerate() {
            let mut rows = ws.take_usize(self.k);
            rows.extend(self.neigh[hi].iter().map(|&(i, _)| i));
            let cr = content_weights_into(
                q,
                beta_raw,
                &self.mem,
                rows,
                self.sim_pool.take(),
                ws.take_f32_empty(self.k),
            );
            out.push(cr);
        }
    }

    /// Sparse read r = Σᵢ w(sᵢ)·M(sᵢ) (eq. 4) with LRA touches for every
    /// non-negligible weight, into a reused buffer (resized to word size).
    pub fn read_mixture_into(&mut self, w_read: &SparseVec, r: &mut Vec<f32>) {
        r.clear();
        r.resize(self.mem.word_size(), 0.0);
        self.mem.read_sparse(w_read, r);
        let ring = self.ring.as_mut().expect("read_mixture needs a sparse engine (LRA ring)");
        for (i, wv) in w_read.iter() {
            if wv > self.delta {
                ring.touch(i);
            }
        }
    }

    /// Return a ContentRead's pooled buffers (tape recycling at backward).
    pub fn recycle_content_read(&mut self, cr: ContentRead, ws: &mut Workspace) {
        ws.recycle_usize(cr.rows);
        ws.recycle_f32(cr.weights);
        self.sim_pool.recycle(cr.sims);
    }

    // -- backward -----------------------------------------------------------

    /// Backward of one head's `read_topk_into` result: accumulates ∂L/∂M
    /// over the read support, folds in the carried gradient on w̃^R from
    /// step t+1 (`carried_dw`), and backprops the content softmax into
    /// dq/dβ̂.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_read_topk(
        &mut self,
        read: &ContentRead,
        query: &[f32],
        dr: &[f32],
        carried_dw: &SparseVec,
        dq: &mut [f32],
        dbeta_raw: &mut f32,
        ws: &mut Workspace,
    ) {
        let mut dws = std::mem::take(&mut self.dw_scratch);
        dws.clear();
        for (j, &row) in read.rows.iter().enumerate() {
            dws.push(dot(self.mem.row(row), dr) + carried_dw.get(row));
            self.dmem.axpy_row(row, read.weights[j], dr);
        }
        self.backward_content(read, query, &dws, dq, dbeta_raw, ws);
        self.dw_scratch = dws;
    }

    /// Backward of a sparse mixture read: returns dL/dw over the read
    /// support (including the carried gradient) as a pooled vector and
    /// accumulates ∂L/∂M.
    pub fn backward_sparse_read(
        &mut self,
        w_read: &SparseVec,
        dr: &[f32],
        carried_dw: &SparseVec,
        ws: &mut Workspace,
    ) -> SparseVec {
        let mut out = ws.take_sparse();
        for (i, wv) in w_read.iter() {
            let g = dot(self.mem.row(i), dr) + carried_dw.get(i);
            self.dmem.axpy_row(i, wv, dr);
            out.push(i, g);
        }
        out
    }

    /// Content-softmax backward (eq. 2) with ∂L/∂M rows accumulated into
    /// the engine's carried gradient.
    pub fn backward_content(
        &mut self,
        read: &ContentRead,
        query: &[f32],
        dweights: &[f32],
        dq: &mut [f32],
        dbeta_raw: &mut f32,
        ws: &mut Workspace,
    ) {
        let mem = &self.mem;
        let dmem = &mut self.dmem;
        content_weights_backward_ws(read, query, mem, dweights, dq, dbeta_raw, ws, |row, d| {
            dmem.axpy_row(row, 1.0, d)
        });
    }

    /// Backward of one head's `sparse_write` (reverse head order): computes
    /// the write-word and gate gradients from ∂L/∂M, kills the erased row's
    /// gradient, reverts this write's journal (rolling the memory back one
    /// head, Supp Fig 5) and re-syncs the restored ANN rows. `da` must
    /// arrive zeroed at word length; dL/d(w̃^R_{t-1}) is returned pooled.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_write_into(
        &mut self,
        gate: &WriteGate,
        word: &[f32],
        w_read_used: &SparseVec,
        dalpha_raw: &mut f32,
        dgamma_raw: &mut f32,
        da: &mut [f32],
        ws: &mut Workspace,
    ) -> SparseVec {
        debug_assert_eq!(da.len(), self.mem.word_size());
        let mut dw = ws.take_sparse();
        for (i, wv) in gate.weights.iter() {
            if let Some(drow) = self.dmem.row(i) {
                for (daj, dj) in da.iter_mut().zip(drow) {
                    *daj += wv * dj;
                }
                dw.push(i, dot(word, drow));
            }
        }
        // The erased row's pre-write contents don't affect the loss.
        self.dmem.clear_row(gate.lra_row);
        let dw_prev = write_gate_backward_ws(gate, w_read_used, &dw, dalpha_raw, dgamma_raw, ws);
        ws.recycle_sparse(dw);
        let mut journal = self
            .journals
            .pop()
            .expect("backward_write without a matching sparse_write");
        self.mem.revert(&journal);
        self.sync_rows(&journal);
        journal.recycle_rows(ws);
        self.spare_journals.push(journal);
        dw_prev
    }

    // -- episode lifecycle ---------------------------------------------------

    /// Discard the remaining write tape without computing gradients:
    /// reverts every outstanding journal in reverse order, restoring the
    /// memory (bit-exactly) and the ANN to the episode-start state. Journal
    /// rows recycle into `ws`.
    pub fn rollback_ws(&mut self, ws: &mut Workspace) {
        if !self.journals.is_empty() {
            metrics::MEM_ROLLBACKS.inc();
        }
        while let Some(mut journal) = self.journals.pop() {
            self.mem.revert(&journal);
            self.sync_rows(&journal);
            journal.recycle_rows(ws);
            self.spare_journals.push(journal);
        }
    }

    /// [`rollback_ws`](SparseMemoryEngine::rollback_ws) without buffer
    /// reuse (tests / cold paths).
    pub fn rollback(&mut self) {
        let mut ws = Workspace::new();
        self.rollback_ws(&mut ws);
    }

    /// Start a new episode. Outstanding journals mean the previous episode
    /// was abandoned mid-tape; reverting them restores memory + ANN in
    /// O(tape) — there is no touched-set bookkeeping to replay.
    pub fn reset(&mut self, ws: &mut Workspace) {
        self.rollback_ws(ws);
        if let Some(ring) = self.ring.as_mut() {
            ring.reset();
        }
        // Clear-retain: the carried gradient's row buffers and map capacity
        // persist across episodes, part of the zero-allocation steady state.
        self.dmem.clear();
    }

    /// Called after the last backward of an episode. Incremental
    /// maintenance keeps the ANN in sync through every write and revert, so
    /// there is nothing to resync and no full rebuild on the default path.
    pub fn end_episode(&mut self) {
        debug_assert!(self.journals.is_empty(), "end_episode with outstanding journals");
    }

    /// Keep the ANN rows listed in `journal` consistent with the memory —
    /// the §3.5 per-write sync, also applied on revert so the index never
    /// goes stale. Trade-off vs the old end-of-episode resync: roughly one
    /// extra `update_row` per journaled row during backward, in exchange
    /// for an always-in-sync index, no touched-set bookkeeping, and O(tape)
    /// recovery from abandoned episodes. For `LinearIndex` (the default)
    /// the resulting index *content* is bit-identical to the old resync;
    /// for KdForest/LSH the extra updates shift internal rebuild cadence
    /// and tree shape, so those backends keep per-run determinism but not
    /// bit-parity with the pre-engine code (same caveat class as
    /// DESIGN.md's worker-count note).
    fn sync_rows(&mut self, journal: &StepJournal) {
        if self.ann.is_some() {
            for row in journal.touched_rows() {
                self.ann_sync_row(row);
            }
        }
    }

    // -- shard-level API (driven by `memory::sharded::ShardedMemoryEngine`) --
    //
    // A shard is this engine minus the global orchestration: the wrapper
    // pops the (global) LRA target, evaluates the write gate once, splits
    // its support by `i % S`, and hands each shard its local slice here.
    // Every global write maps to exactly one `shard_write` per shard (the
    // slice may be empty), so per-shard journal tapes stay aligned with the
    // global step count and `shard_revert_last` rolls all shards back in
    // lockstep.

    /// Apply one global write's local slice: journal the touched local
    /// rows, erase `erase_local` if this shard owns the LRA row, apply the
    /// sparse add and incrementally sync the ANN. Always pushes a journal
    /// (possibly empty) to keep the shard tape aligned.
    pub fn shard_write(
        &mut self,
        erase_local: Option<usize>,
        weights_local: &SparseVec,
        word: &[f32],
        ws: &mut Workspace,
    ) {
        debug_assert!(self.ring.is_none(), "shard_write is for ring-less shard engines");
        let mut journal = self.spare_journals.pop().unwrap_or_default();
        self.mem
            .journal_sparse_write_opt(erase_local, weights_local, word, &mut journal, ws);
        self.sync_rows(&journal);
        self.journals.push(journal);
    }

    /// Journal-free twin of [`SparseMemoryEngine::shard_write`] (serving
    /// mode): same write semantics and ANN sync over the same row set, no
    /// tape.
    pub fn shard_infer_write(
        &mut self,
        erase_local: Option<usize>,
        weights_local: &SparseVec,
        word: &[f32],
    ) {
        self.mem.apply_sparse_write_opt(erase_local, weights_local, word);
        if self.ann.is_some() {
            if let Some(er) = erase_local {
                self.ann_sync_row(er);
            }
            for (i, _) in weights_local.iter() {
                if erase_local != Some(i) {
                    self.ann_sync_row(i);
                }
            }
        }
    }

    /// Pop and revert this shard's most recent journal (one global write),
    /// re-syncing the restored ANN rows. Panics if the tape is empty — the
    /// wrapper's global step count and the shard tapes must never diverge.
    pub fn shard_revert_last(&mut self, ws: &mut Workspace) {
        let mut journal = self
            .journals
            .pop()
            .expect("shard_revert_last on an empty shard tape (wrapper sequencing bug)");
        self.mem.revert(&journal);
        self.sync_rows(&journal);
        journal.recycle_rows(ws);
        self.spare_journals.push(journal);
    }

    /// Live journals on this shard's tape (wrapper sequencing asserts).
    pub fn journals_len(&self) -> usize {
        self.journals.len()
    }

    /// Batched rank-keyed ANN query over this shard's local rows — the
    /// per-shard leg of the sharded engine's fan-out (see
    /// [`AnnIndex::query_many_rank_into`] for the key contract).
    pub fn ann_query_rank_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        self.ann
            .as_mut()
            .expect("ann_query_rank_into needs a sparse engine")
            .query_many_rank_into(queries, k, out);
    }

    /// Full rebuilds performed by this engine's ANN (0 for dense engines) —
    /// lets the sharding tests pin that rollback fuzzing stays on the
    /// incremental maintenance path.
    pub fn ann_full_rebuilds(&self) -> usize {
        self.ann.as_ref().map(|a| a.full_rebuilds()).unwrap_or(0)
    }

    // -- compatibility wrappers (tests / cold paths) -------------------------

    /// Allocating wrapper over [`read_topk_into`](SparseMemoryEngine::read_topk_into).
    pub fn read_topk(&mut self, queries: Vec<(Vec<f32>, f32)>) -> Vec<TopKRead> {
        let mut ws = Workspace::new();
        let (qs, betas): (Vec<Vec<f32>>, Vec<f32>) = queries.into_iter().unzip();
        let mut out = Vec::new();
        self.read_topk_into(&qs, &betas, &mut out, &mut ws);
        out
    }

    /// Allocating wrapper over
    /// [`content_read_many_into`](SparseMemoryEngine::content_read_many_into).
    pub fn content_read_many(&mut self, queries: &[(Vec<f32>, f32)]) -> Vec<ContentRead> {
        let mut ws = Workspace::new();
        let qs: Vec<Vec<f32>> = queries.iter().map(|(q, _)| q.clone()).collect();
        let betas: Vec<f32> = queries.iter().map(|&(_, b)| b).collect();
        let mut out = Vec::new();
        self.content_read_many_into(&qs, &betas, &mut out, &mut ws);
        out
    }

    /// Allocating wrapper over [`read_mixture_into`](SparseMemoryEngine::read_mixture_into).
    pub fn read_mixture(&mut self, w_read: &SparseVec) -> Vec<f32> {
        let mut r = Vec::new();
        self.read_mixture_into(w_read, &mut r);
        r
    }

    // -- dense sub-API (DAM, the paper's dense control model) ----------------

    /// Full memory snapshot — the O(N·W)/step BPTT cost the sparse path
    /// eliminates; dense baselines cache one per step.
    pub fn snapshot(&self) -> Vec<f32> {
        self.mem.snapshot()
    }

    /// Snapshot into a reused buffer (the dense per-step copy without the
    /// per-step allocation).
    pub fn snapshot_into(&self, out: &mut Vec<f32>) {
        self.mem.snapshot_into(out);
    }

    pub fn restore(&mut self, snap: &[f32]) {
        self.mem.restore(snap);
    }

    pub fn fill(&mut self, v: f32) {
        self.mem.fill(v);
    }

    /// Dense read r = Σᵢ w(i)·M(i) (eq. 1) in O(N·W).
    pub fn read_dense(&self, weights: &[f32], out: &mut [f32]) {
        self.mem.read_dense(weights, out);
    }

    /// Dense write: erase `erase_row` fully (R_t = 𝕀^U 1ᵀ), then add
    /// w^W aᵀ over all non-zero weights (eq. 3 with a full-row erase).
    pub fn dense_write(&mut self, w_write: &[f32], word: &[f32], erase_row: usize) {
        self.mem.row_mut(erase_row).iter_mut().for_each(|v| *v = 0.0);
        let n = self.mem.n();
        for i in 0..n {
            let wv = w_write[i];
            if wv != 0.0 {
                let row = self.mem.row_mut(i);
                for (m, &av) in row.iter_mut().zip(word) {
                    *m += wv * av;
                }
            }
        }
    }

    // -- accounting ----------------------------------------------------------

    /// Bytes of per-episode BPTT state the engine holds (the Fig 1b
    /// quantity: grows with T, constant in N).
    pub fn tape_bytes(&self) -> usize {
        self.journal_heap_bytes()
    }

    pub fn store_heap_bytes(&self) -> usize {
        self.mem.heap_bytes()
    }

    pub fn ann_heap_bytes(&self) -> usize {
        self.ann.as_ref().map(|a| a.heap_bytes()).unwrap_or(0)
    }

    pub fn ring_heap_bytes(&self) -> usize {
        self.ring.as_ref().map(|r| r.heap_bytes()).unwrap_or(0)
    }

    pub fn journal_heap_bytes(&self) -> usize {
        // Live journals only: the drained tape reports zero (the retained
        // vec capacity is a warm buffer, not per-episode state).
        self.journals.iter().map(|j| j.heap_bytes()).sum::<usize>()
            + self.journals.len() * std::mem::size_of::<StepJournal>()
    }

    pub fn grad_heap_bytes(&self) -> usize {
        self.dmem.heap_bytes()
    }

    /// Total engine heap: by construction exactly the sum of its parts
    /// (asserted in `benches/fig1_memory.rs` so Fig 1b can't silently
    /// drift).
    pub fn heap_bytes(&self) -> usize {
        self.store_heap_bytes()
            + self.ann_heap_bytes()
            + self.ring_heap_bytes()
            + self.journal_heap_bytes()
            + self.grad_heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_engine(seed: u64) -> SparseMemoryEngine {
        let mut rng = Rng::new(seed);
        SparseMemoryEngine::new_sparse(16, 6, 3, 0.005, AnnKind::Linear, &mut rng)
    }

    fn write_some(engine: &mut SparseMemoryEngine, steps: usize, seed: u64) {
        let mut ws = Workspace::new();
        let mut rng = Rng::new(seed);
        let mut w_prev = SparseVec::new();
        for _ in 0..steps {
            let word: Vec<f32> = (0..engine.word_size()).map(|_| rng.normal()).collect();
            let gate = engine.sparse_write(rng.normal(), rng.normal(), &w_prev, &word, &mut ws);
            w_prev = gate.weights;
        }
    }

    #[test]
    fn rollback_restores_memory_and_ann() {
        let mut engine = sparse_engine(1);
        let start = engine.snapshot();
        let q: Vec<f32> = (0..6).map(|i| (i as f32 + 1.0) * 0.1).collect();
        let before = engine.content_read_many(&[(q.clone(), 0.5)]);
        write_some(&mut engine, 8, 2);
        assert_ne!(engine.snapshot(), start, "writes should modify memory");
        engine.rollback();
        assert_eq!(engine.snapshot(), start, "rollback must be bit-exact");
        // The incremental revert-sync must leave the ANN answering exactly
        // as before the writes — no end-of-episode resync exists anymore.
        let after = engine.content_read_many(&[(q, 0.5)]);
        assert_eq!(before[0].rows, after[0].rows);
        for (a, b) in before[0].weights.iter().zip(&after[0].weights) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn reset_recovers_abandoned_episode() {
        let mut engine = sparse_engine(3);
        let mut ws = Workspace::new();
        let start = engine.snapshot();
        write_some(&mut engine, 5, 4);
        // No rollback/backward: reset alone must restore the start state.
        engine.reset(&mut ws);
        assert_eq!(engine.snapshot(), start);
        engine.end_episode();
    }

    #[test]
    fn read_topk_returns_normalized_weights() {
        let mut engine = sparse_engine(5);
        write_some(&mut engine, 4, 6);
        let queries: Vec<(Vec<f32>, f32)> = (0..3)
            .map(|h| ((0..6).map(|i| (h + i) as f32 * 0.2 - 0.5).collect(), 0.3))
            .collect();
        let reads = engine.read_topk(queries);
        assert_eq!(reads.len(), 3);
        for tk in &reads {
            assert_eq!(tk.read.rows.len(), 3, "K=3 candidates");
            let sum: f32 = tk.read.weights.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax weights sum to 1");
            assert_eq!(tk.weights.nnz(), tk.read.rows.len());
            assert_eq!(tk.r.len(), 6);
        }
        engine.rollback();
    }

    #[test]
    fn pooled_read_paths_match_allocating_wrappers() {
        // Two identical engines; one read through the hot path, one through
        // the wrappers — results must match bitwise.
        let mut a = sparse_engine(9);
        let mut b = sparse_engine(9);
        write_some(&mut a, 5, 10);
        write_some(&mut b, 5, 10);
        let queries: Vec<Vec<f32>> = (0..3)
            .map(|h| (0..6).map(|i| (h + i) as f32 * 0.15 - 0.4).collect())
            .collect();
        let betas = vec![0.3f32; 3];
        let mut ws = Workspace::new();
        let mut hot = Vec::new();
        a.read_topk_into(&queries, &betas, &mut hot, &mut ws);
        let cold =
            b.read_topk(queries.iter().map(|q| (q.clone(), 0.3)).collect());
        assert_eq!(hot.len(), cold.len());
        for (x, y) in hot.iter().zip(&cold) {
            assert_eq!(x.read.rows, y.read.rows);
            assert_eq!(x.read.weights, y.read.weights);
            assert_eq!(x.weights, y.weights);
            assert_eq!(x.r, y.r);
        }
        a.rollback();
        b.rollback();
    }

    #[test]
    fn infer_write_matches_sparse_write_with_zero_tape() {
        // Same seeds, one engine written through the journaled train path,
        // one through the journal-free infer path: memory, ANN answers and
        // ring order must agree bitwise, and the infer engine must hold
        // zero tape bytes throughout.
        let mut a = sparse_engine(11);
        let mut b = sparse_engine(11);
        let mut ws_a = Workspace::new();
        let mut ws_b = Workspace::new();
        let mut rng = Rng::new(12);
        let mut w_prev_a = SparseVec::new();
        let mut w_prev_b = SparseVec::new();
        for _ in 0..6 {
            let word: Vec<f32> = (0..a.word_size()).map(|_| rng.normal()).collect();
            let (ar, gr) = (rng.normal(), rng.normal());
            let gate = a.sparse_write(ar, gr, &w_prev_a, &word, &mut ws_a);
            b.infer_write(ar, gr, &w_prev_b, &word, &mut ws_b);
            // The infer path has no gate cache; mirror the recurrent read
            // weights through read_topk on both engines.
            let q: Vec<f32> = (0..a.word_size()).map(|_| rng.normal()).collect();
            let ra = a.read_topk(vec![(q.clone(), 0.4)]);
            let rb = b.read_topk(vec![(q, 0.4)]);
            assert_eq!(ra[0].weights, rb[0].weights);
            assert_eq!(ra[0].r, rb[0].r);
            w_prev_a = ra.into_iter().next().unwrap().weights;
            w_prev_b = rb.into_iter().next().unwrap().weights;
            drop(gate);
            assert_eq!(b.tape_bytes(), 0, "infer path must journal nothing");
        }
        assert_eq!(a.snapshot(), b.snapshot());
        a.rollback();
    }

    #[test]
    fn infer_reinit_restores_episode_start() {
        let mut engine = sparse_engine(13);
        let start = engine.snapshot();
        let q: Vec<f32> = (0..6).map(|i| 0.1 * (i as f32 + 1.0)).collect();
        let before = engine.content_read_many(&[(q.clone(), 0.5)]);
        let mut ws = Workspace::new();
        let mut rng = Rng::new(14);
        for _ in 0..5 {
            let word: Vec<f32> = (0..engine.word_size()).map(|_| rng.normal()).collect();
            engine.infer_write(rng.normal(), rng.normal(), &SparseVec::new(), &word, &mut ws);
        }
        assert_ne!(engine.snapshot(), start);
        engine.reinit();
        assert_eq!(engine.snapshot(), start, "reinit must regenerate the seeded init");
        let after = engine.content_read_many(&[(q, 0.5)]);
        assert_eq!(before[0].rows, after[0].rows, "ANN must be back in sync");
    }

    #[test]
    fn heap_bytes_is_sum_of_parts() {
        let mut engine = sparse_engine(7);
        write_some(&mut engine, 6, 8);
        assert_eq!(
            engine.heap_bytes(),
            engine.store_heap_bytes()
                + engine.ann_heap_bytes()
                + engine.ring_heap_bytes()
                + engine.journal_heap_bytes()
                + engine.grad_heap_bytes()
        );
        assert!(engine.tape_bytes() > 0);
        engine.rollback();
    }

    #[test]
    fn dense_write_matches_manual_loop() {
        let mut engine = SparseMemoryEngine::new_dense(4, 2);
        engine.fill(1.0);
        engine.dense_write(&[0.5, 0.0, 0.0, 0.25], &[2.0, 4.0], 0);
        // row0 erased then 0.5*word; row3 gets 1 + 0.25*word.
        assert_eq!(engine.store().row(0), &[1.0, 2.0]);
        assert_eq!(engine.store().row(1), &[1.0, 1.0]);
        assert_eq!(engine.store().row(3), &[1.5, 2.0]);
        let mut out = vec![0.0; 2];
        engine.read_dense(&[1.0, 0.0, 0.0, 0.0], &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }
}
