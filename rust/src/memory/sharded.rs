//! Sharded sparse memory: N slots striped across S independent
//! [`SparseMemoryEngine`] shards, with the ANN query fanned out across a
//! persistent worker pool — the "scale it further (sharding)" step that
//! takes `query_many` from one O(N) scan on one core to S scans of N/S
//! rows on S cores, which is what makes million-slot memories answer at
//! interactive latency (see `benches/fig1_speed.rs`'s BENCH_shard.json
//! section).
//!
//! ## Index mapping
//!
//! Global row `i` lives in shard `i % S` at local row `i / S`
//! (`i = local * S + shard`). The striping is stable and bijective, shard
//! stores are seeded through the *global* row id
//! ([`crate::memory::engine::init_row`]), so the
//! union of shard contents is bit-identical to one unsharded store at
//! every step — [`ShardedMemoryEngine::snapshot`] reassembles the global
//! layout and existing snapshot-equality tests hold unchanged.
//!
//! ## Deterministic merge
//!
//! Each shard answers a batched top-K query over its own rows in **raw
//! rank-key space** ([`crate::ann::AnnIndex::query_many_rank_into`]);
//! the wrapper merges the ≤ S·K candidates by `(key, global id)` and keeps
//! the best K. Results are therefore bitwise independent of thread
//! scheduling (per-shard results land in per-shard slots; the merge is a
//! total order), and for [`crate::ann::LinearIndex`] — whose rank key is
//! the exact squared unit distance its scan compares, with ties resolved
//! by ascending id exactly as the unsharded scan resolves them — the
//! merged candidate list is **bit-identical to the S=1 scan**, which makes
//! the whole training stack bit-identical (rust/tests/shard_parity.rs).
//! Approximate backends (kd/LSH) keep per-run determinism but not S-parity
//! (their per-shard trees see different row subsets).
//!
//! ## Journal sequencing
//!
//! A global gated write pops the **global** LRA target (the ring stays
//! unsharded — LRA order is a global property), evaluates eq. 5's gate
//! once, splits the support by `i % S` and hands every shard its local
//! slice. Every global write pushes exactly one journal on *every* shard
//! (possibly empty), so the S shard tapes stay aligned with the global
//! step count: `backward_write_into`/`rollback` revert one journal per
//! shard per step, restoring disjoint row sets — bit-exact in any order.
//! The carried memory gradient ∂L/∂M also stays global (row-sparse over
//! global ids), so the backward float-op order matches S=1 exactly.
//!
//! ## S = 1
//!
//! With one shard (the default everywhere) every method delegates straight
//! to the inner [`SparseMemoryEngine`] — today's exact behavior by
//! construction, not by re-derivation. The generic S>1 path is the one
//! `shard_parity.rs` proves equal to it.

use crate::ann::AnnKind;
use crate::cores::addressing::{
    content_weights_backward_ws, content_weights_into, write_gate_backward_ws, write_gate_ws,
    ContentRead, CosSim, WriteGate,
};
use crate::memory::engine::{assemble_topk_reads, SparseMemoryEngine, TopKRead};
use crate::memory::store::RowSource;
use crate::memory::usage::LraRing;
use crate::tensor::csr::{RowSparse, SparseVec};
use crate::tensor::matrix::dot;
use crate::tensor::rowcodec::RowFormat;
use crate::tensor::workspace::{Pool, Workspace};
use crate::util::metrics;
use crate::util::pool::ShardPool;
use crate::util::rng::Rng;

/// Below this many total rows the fan-out runs serially on the calling
/// thread: queue/wake costs exceed an L2-resident scan, and the merge rule
/// makes serial and pooled execution bitwise identical anyway, so the
/// threshold is pure scheduling, never semantics.
pub const SHARD_PARALLEL_MIN_ROWS: usize = 1 << 14;

/// Read-only striped view over the shard stores — the [`RowSource`] the
/// shared addressing math reads global rows through.
struct ShardRows<'a> {
    shards: &'a [SparseMemoryEngine],
    s: usize,
}

impl RowSource for ShardRows<'_> {
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        self.shards[i % self.s].store().row(i / self.s)
    }

    // Forward the codec-aware kernels to the owning shard's store, so
    // compact-format shards keep decode fused into the scan instead of
    // falling back to the borrow-a-row defaults (which panic on compact).
    #[inline]
    fn row_dot_normsq(&self, i: usize, q: &[f32]) -> (f32, f32) {
        self.shards[i % self.s].store().row_dot_normsq(i / self.s, q)
    }

    #[inline]
    fn row_axpy(&self, i: usize, coeff: f32, out: &mut [f32]) {
        self.shards[i % self.s].store().row_axpy(i / self.s, coeff, out);
    }
}

/// S-way sharded drop-in for [`SparseMemoryEngine`]: same differentiable
/// API, global semantics, per-shard storage and parallel query. See the
/// module docs for the invariants.
pub struct ShardedMemoryEngine {
    shards: Vec<SparseMemoryEngine>,
    s: usize,
    n: usize,
    word: usize,
    k: usize,
    delta: f32,
    mem_seed: u64,
    /// Global LRA ring (S>1; the S=1 inner engine owns its own).
    ring: Option<LraRing>,
    /// Global carried ∂L/∂M over global row ids (S>1).
    dmem: RowSparse,
    /// Number of global writes currently journaled across all shards.
    live_writes: usize,
    // -- persistent S>1 scratch (the "merge buffers"; all capacity-warm
    //    after one episode, see rust/tests/zero_alloc.rs) ------------------
    /// Per-shard local write-weight staging for the current global write.
    split_w: Vec<SparseVec>,
    /// Per-shard, per-head rank-keyed ANN results from the last fan-out.
    neigh: Vec<Vec<Vec<(usize, f32)>>>,
    /// (key, global id) merge staging, sorted per head.
    cand: Vec<(f32, usize)>,
    /// CosSim cache pool for ContentRead (mirrors the engine's).
    sim_pool: Pool<CosSim>,
    /// ContentRead staging for `read_topk_into`.
    cr_tmp: Vec<ContentRead>,
    /// dL/dweights staging for `backward_read_topk`.
    dw_scratch: Vec<f32>,
}

impl ShardedMemoryEngine {
    /// Sharded sparse engine; draws `mem_seed` then the ANN seed from
    /// `rng`, in the same order as [`SparseMemoryEngine::new_sparse`].
    pub fn new_sparse(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        rng: &mut Rng,
        shards: usize,
    ) -> ShardedMemoryEngine {
        let mem_seed = rng.next_u64();
        let ann_seed = rng.next_u64();
        ShardedMemoryEngine::new_sparse_from_seeds(
            n, word, k, delta, kind, mem_seed, ann_seed, shards,
        )
    }

    /// [`ShardedMemoryEngine::new_sparse`] with explicit seeds (the serving
    /// sessions' parity contract). `shards == 1` constructs exactly the
    /// engine [`SparseMemoryEngine::new_sparse_from_seeds`] constructs;
    /// `shards > 1` stripes the rows, seeding shard ANNs from `ann_seed`
    /// xor-mixed with the shard id (deterministic per run).
    #[allow(clippy::too_many_arguments)]
    pub fn new_sparse_from_seeds(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        shards: usize,
    ) -> ShardedMemoryEngine {
        ShardedMemoryEngine::new_sparse_from_seeds_fmt(
            n,
            word,
            k,
            delta,
            kind,
            mem_seed,
            ann_seed,
            shards,
            RowFormat::F32,
        )
    }

    /// [`ShardedMemoryEngine::new_sparse_from_seeds`] with an explicit row
    /// format for every shard store (and the per-shard linear ANN).
    #[allow(clippy::too_many_arguments)]
    pub fn new_sparse_from_seeds_fmt(
        n: usize,
        word: usize,
        k: usize,
        delta: f32,
        kind: AnnKind,
        mem_seed: u64,
        ann_seed: u64,
        shards: usize,
        fmt: RowFormat,
    ) -> ShardedMemoryEngine {
        assert!(shards >= 1, "need at least one shard");
        assert!(shards <= n, "more shards ({shards}) than memory rows ({n})");
        let (engines, ring, dmem) = if shards == 1 {
            let inner = SparseMemoryEngine::new_sparse_from_seeds_fmt(
                n, word, k, delta, kind, mem_seed, ann_seed, fmt,
            );
            (vec![inner], None, RowSparse::new(word))
        } else {
            let engines = (0..shards)
                .map(|sh| {
                    let n_local = (n - sh).div_ceil(shards);
                    let shard_ann_seed =
                        ann_seed ^ (sh as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                    SparseMemoryEngine::new_shard_fmt(
                        n_local,
                        word,
                        kind,
                        mem_seed,
                        shard_ann_seed,
                        shards,
                        sh,
                        fmt,
                    )
                })
                .collect();
            (engines, Some(LraRing::new(n)), RowSparse::new(word))
        };
        ShardedMemoryEngine {
            shards: engines,
            s: shards,
            n,
            word,
            k,
            delta,
            mem_seed,
            ring,
            dmem,
            live_writes: 0,
            split_w: (0..shards).map(|_| SparseVec::new()).collect(),
            neigh: (0..shards).map(|_| Vec::new()).collect(),
            cand: Vec::new(),
            sim_pool: Pool::new(),
            cr_tmp: Vec::new(),
            dw_scratch: Vec::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn word_size(&self) -> usize {
        self.word
    }

    /// Shard count S.
    pub fn num_shards(&self) -> usize {
        self.s
    }

    /// Read access to one shard engine (tests, benches, accounting).
    pub fn shard(&self, sh: usize) -> &SparseMemoryEngine {
        &self.shards[sh]
    }

    /// Global memory row `i` (striped lookup).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        self.shards[i % self.s].store().row(i / self.s)
    }

    /// The memory seed rows were initialized from (recorded for serving
    /// sessions, like the engine's).
    pub fn mem_seed(&self) -> u64 {
        self.mem_seed
    }

    // -- forward ------------------------------------------------------------

    /// Gated sparse write (eq. 5/8): global LRA pop, one gate evaluation,
    /// per-shard journaled application, global ring touches — the same
    /// observable sequence as [`SparseMemoryEngine::sparse_write`].
    pub fn sparse_write(
        &mut self,
        alpha_raw: f32,
        gamma_raw: f32,
        w_read_prev: &SparseVec,
        word: &[f32],
        ws: &mut Workspace,
    ) -> WriteGate {
        if self.s == 1 {
            return self.shards[0].sparse_write(alpha_raw, gamma_raw, w_read_prev, word, ws);
        }
        metrics::MEM_WRITES.inc();
        let ring = self.ring.as_mut().expect("sharded sparse engine has a global ring");
        let lra_row = ring.pop_lra();
        let gate = write_gate_ws(alpha_raw, gamma_raw, w_read_prev, lra_row, ws);
        Self::scatter(&gate.weights, self.s, &mut self.split_w);
        for sh in 0..self.s {
            let erase = if lra_row % self.s == sh { Some(lra_row / self.s) } else { None };
            self.shards[sh].shard_write(erase, &self.split_w[sh], word, ws);
        }
        let ring = self.ring.as_mut().unwrap();
        for (i, wv) in gate.weights.iter() {
            if wv.abs() > self.delta {
                ring.touch(i);
            }
        }
        self.live_writes += 1;
        gate
    }

    /// Forward-only gated write (serving): identical semantics and ANN
    /// sync, no journals anywhere, tape stays 0. Returns the pooled write
    /// weights like [`SparseMemoryEngine::infer_write`].
    pub fn infer_write(
        &mut self,
        alpha_raw: f32,
        gamma_raw: f32,
        w_read_prev: &SparseVec,
        word: &[f32],
        ws: &mut Workspace,
    ) -> SparseVec {
        if self.s == 1 {
            return self.shards[0].infer_write(alpha_raw, gamma_raw, w_read_prev, word, ws);
        }
        metrics::MEM_WRITES.inc();
        let ring = self.ring.as_mut().expect("sharded sparse engine has a global ring");
        let lra_row = ring.pop_lra();
        let gate = write_gate_ws(alpha_raw, gamma_raw, w_read_prev, lra_row, ws);
        Self::scatter(&gate.weights, self.s, &mut self.split_w);
        for sh in 0..self.s {
            let erase = if lra_row % self.s == sh { Some(lra_row / self.s) } else { None };
            self.shards[sh].shard_infer_write(erase, &self.split_w[sh], word);
        }
        let ring = self.ring.as_mut().unwrap();
        for (i, wv) in gate.weights.iter() {
            if wv.abs() > self.delta {
                ring.touch(i);
            }
        }
        gate.weights
    }

    /// Split global sparse weights into per-shard local vectors. Global
    /// indices ascend, so each shard's locals ascend too — `push` keeps the
    /// CSR invariant without sorting.
    fn scatter(weights: &SparseVec, s: usize, split: &mut [SparseVec]) {
        for sv in split.iter_mut() {
            sv.clear();
        }
        for (i, v) in weights.iter() {
            split[i % s].push(i / s, v);
        }
    }

    /// Batched content reads for all heads: one parallel sharded fan-out,
    /// one merge per head, then the same per-head softmax/read/touch
    /// sequence as [`SparseMemoryEngine::read_topk_into`].
    pub fn read_topk_into(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<TopKRead>,
        ws: &mut Workspace,
    ) {
        self.ann_fill_neigh(queries, false);
        self.read_topk_from_neigh(queries, betas, out, ws);
    }

    /// The post-ANN half of [`read_topk_into`](Self::read_topk_into) — see
    /// [`SparseMemoryEngine::read_topk_from_neigh`]. Requires the neighbour
    /// lists filled by [`ann_fill_neigh`](Self::ann_fill_neigh).
    pub fn read_topk_from_neigh(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<TopKRead>,
        ws: &mut Workspace,
    ) {
        if self.s == 1 {
            return self.shards[0].read_topk_from_neigh(queries, betas, out, ws);
        }
        metrics::MEM_READS.add(queries.len() as u64);
        let mut crs = std::mem::take(&mut self.cr_tmp);
        self.content_read_many_from_neigh(queries, betas, &mut crs, ws);
        let word = self.word;
        assemble_topk_reads(&mut crs, word, out, ws, |w, r| self.read_mixture_into(w, r));
        self.cr_tmp = crs;
    }

    /// Fan the ANN lookup for a batch of queries out across the shards into
    /// the per-shard neighbour lists. `serial` forces the strictly serial
    /// fan-out even above [`SHARD_PARALLEL_MIN_ROWS`] — the batched
    /// training tick sets it when the call is already running on a
    /// [`ShardPool`] worker, where the lanes themselves are the parallel
    /// unit and a nested dispatch would only queue behind the outer one.
    /// Bitwise identical either way: per-shard result slots +
    /// deterministic merge.
    pub fn ann_fill_neigh(&mut self, queries: &[Vec<f32>], serial: bool) {
        if self.s == 1 {
            return self.shards[0].ann_fill_neigh(queries);
        }
        if serial {
            let k = self.k;
            for (shard, out) in self.shards.iter_mut().zip(self.neigh.iter_mut()) {
                shard.ann_query_rank_into(queries, k, out);
            }
        } else {
            self.query_shards(queries);
        }
    }

    /// Batched content-weight computation (no memory read, no touches) —
    /// the sharded twin of [`SparseMemoryEngine::content_read_many_into`].
    pub fn content_read_many_into(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<ContentRead>,
        ws: &mut Workspace,
    ) {
        self.ann_fill_neigh(queries, false);
        self.content_read_many_from_neigh(queries, betas, out, ws);
    }

    /// The post-ANN half of
    /// [`content_read_many_into`](Self::content_read_many_into): per-head
    /// total-order candidate merge + softmax weights over the per-shard
    /// neighbour lists already filled by
    /// [`ann_fill_neigh`](Self::ann_fill_neigh).
    pub fn content_read_many_from_neigh(
        &mut self,
        queries: &[Vec<f32>],
        betas: &[f32],
        out: &mut Vec<ContentRead>,
        ws: &mut Workspace,
    ) {
        if self.s == 1 {
            return self.shards[0].content_read_many_from_neigh(queries, betas, out, ws);
        }
        assert_eq!(queries.len(), betas.len());
        for (hi, (q, &beta_raw)) in queries.iter().zip(betas).enumerate() {
            let mut rows = ws.take_usize(self.k);
            self.cand.clear();
            for sh in 0..self.s {
                for &(l, key) in &self.neigh[sh][hi] {
                    self.cand.push((key, l * self.s + sh));
                }
            }
            // Total order (key asc, global id asc): equals the unsharded
            // LinearIndex scan order — see module docs. total_cmp is safe
            // here (keys are finite; d² of finite unit vectors) and makes
            // the merge order well-defined for any backend.
            self.cand
                .sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            rows.extend(self.cand.iter().take(self.k).map(|&(_, gid)| gid));
            let sims = self.sim_pool.take();
            let wbuf = ws.take_f32_empty(self.k);
            let view = ShardRows { shards: &self.shards, s: self.s };
            let cr = content_weights_into(q, beta_raw, &view, rows, sims, wbuf);
            out.push(cr);
        }
    }

    /// Fan the rank-keyed batched query out across the shards. Parallel via
    /// the global [`ShardPool`] above [`SHARD_PARALLEL_MIN_ROWS`] total
    /// rows, serial below — bitwise identical either way (per-shard result
    /// slots + deterministic merge).
    fn query_shards(&mut self, queries: &[Vec<f32>]) {
        let k = self.k;
        let shards = &mut self.shards[..];
        let neigh = &mut self.neigh[..];
        debug_assert_eq!(shards.len(), neigh.len());
        if self.n >= SHARD_PARALLEL_MIN_ROWS {
            ShardPool::global().run2(shards, neigh, &(queries, k), |_i, shard, out, ctx| {
                shard.ann_query_rank_into(ctx.0, ctx.1, out);
            });
        } else {
            for (shard, out) in shards.iter_mut().zip(neigh.iter_mut()) {
                shard.ann_query_rank_into(queries, k, out);
            }
        }
    }

    /// Sparse read r = Σᵢ w(sᵢ)·M(sᵢ) over global ids with global ring
    /// touches — same value and op order as the unsharded engine (weights
    /// iterate in ascending global order either way).
    pub fn read_mixture_into(&mut self, w_read: &SparseVec, r: &mut Vec<f32>) {
        if self.s == 1 {
            return self.shards[0].read_mixture_into(w_read, r);
        }
        r.clear();
        r.resize(self.word, 0.0);
        for (i, wv) in w_read.iter() {
            // Codec-aware accumulate (decode fused for compact shards);
            // bit-identical to the old manual loop for f32 rows.
            self.shards[i % self.s].store().row_axpy(i / self.s, wv, r);
        }
        let ring = self.ring.as_mut().expect("sharded sparse engine has a global ring");
        for (i, wv) in w_read.iter() {
            if wv > self.delta {
                ring.touch(i);
            }
        }
    }

    /// Return a ContentRead's pooled buffers (tape recycling at backward).
    pub fn recycle_content_read(&mut self, cr: ContentRead, ws: &mut Workspace) {
        if self.s == 1 {
            return self.shards[0].recycle_content_read(cr, ws);
        }
        ws.recycle_usize(cr.rows);
        ws.recycle_f32(cr.weights);
        self.sim_pool.recycle(cr.sims);
    }

    // -- backward -----------------------------------------------------------
    //
    // MIRROR-MAINTENANCE CONTRACT: the S>1 bodies below intentionally
    // restate the engine's float-op sequences over the global gradient and
    // striped rows (sharing them outright would mean threading ring/dmem
    // injection through the engine's hot paths, trading the S=1
    // exact-behavior guarantee for DRY). Any numerics change in
    // `SparseMemoryEngine`'s write/backward paths MUST be mirrored here;
    // rust/tests/shard_parity.rs is the drift alarm (bitwise, for Linear).

    /// Backward of one head's `read_topk_into` result; global carried
    /// gradient, striped row reads — float-op order matches S=1.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_read_topk(
        &mut self,
        read: &ContentRead,
        query: &[f32],
        dr: &[f32],
        carried_dw: &SparseVec,
        dq: &mut [f32],
        dbeta_raw: &mut f32,
        ws: &mut Workspace,
    ) {
        if self.s == 1 {
            return self.shards[0]
                .backward_read_topk(read, query, dr, carried_dw, dq, dbeta_raw, ws);
        }
        let mut dws = std::mem::take(&mut self.dw_scratch);
        dws.clear();
        for (j, &row) in read.rows.iter().enumerate() {
            let g = dot(self.row(row), dr) + carried_dw.get(row);
            dws.push(g);
            self.dmem.axpy_row(row, read.weights[j], dr);
        }
        self.backward_content(read, query, &dws, dq, dbeta_raw, ws);
        self.dw_scratch = dws;
    }

    /// Backward of a sparse mixture read (SDNC): dL/dw over the support
    /// plus carried gradient; ∂L/∂M accumulates into the global gradient.
    pub fn backward_sparse_read(
        &mut self,
        w_read: &SparseVec,
        dr: &[f32],
        carried_dw: &SparseVec,
        ws: &mut Workspace,
    ) -> SparseVec {
        if self.s == 1 {
            return self.shards[0].backward_sparse_read(w_read, dr, carried_dw, ws);
        }
        let mut out = ws.take_sparse();
        for (i, wv) in w_read.iter() {
            let g = dot(self.row(i), dr) + carried_dw.get(i);
            self.dmem.axpy_row(i, wv, dr);
            out.push(i, g);
        }
        out
    }

    /// Content-softmax backward with ∂L/∂M rows accumulated into the global
    /// carried gradient, rows read through the striped view.
    pub fn backward_content(
        &mut self,
        read: &ContentRead,
        query: &[f32],
        dweights: &[f32],
        dq: &mut [f32],
        dbeta_raw: &mut f32,
        ws: &mut Workspace,
    ) {
        if self.s == 1 {
            return self.shards[0].backward_content(read, query, dweights, dq, dbeta_raw, ws);
        }
        let view = ShardRows { shards: &self.shards, s: self.s };
        let dmem = &mut self.dmem;
        content_weights_backward_ws(read, query, &view, dweights, dq, dbeta_raw, ws, |row, d| {
            dmem.axpy_row(row, 1.0, d)
        });
    }

    /// Backward of one head's `sparse_write`: same gate/gradient math as
    /// the engine on the global carried gradient, then one journal pop per
    /// shard (this global write's slices) rolling all stores back in
    /// lockstep.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_write_into(
        &mut self,
        gate: &WriteGate,
        word: &[f32],
        w_read_used: &SparseVec,
        dalpha_raw: &mut f32,
        dgamma_raw: &mut f32,
        da: &mut [f32],
        ws: &mut Workspace,
    ) -> SparseVec {
        if self.s == 1 {
            return self.shards[0].backward_write_into(
                gate, word, w_read_used, dalpha_raw, dgamma_raw, da, ws,
            );
        }
        debug_assert_eq!(da.len(), self.word);
        let mut dw = ws.take_sparse();
        for (i, wv) in gate.weights.iter() {
            if let Some(drow) = self.dmem.row(i) {
                for (daj, dj) in da.iter_mut().zip(drow) {
                    *daj += wv * dj;
                }
                dw.push(i, dot(word, drow));
            }
        }
        self.dmem.clear_row(gate.lra_row);
        let dw_prev = write_gate_backward_ws(gate, w_read_used, &dw, dalpha_raw, dgamma_raw, ws);
        ws.recycle_sparse(dw);
        assert!(self.live_writes > 0, "backward_write without a matching sparse_write");
        for shard in &mut self.shards {
            shard.shard_revert_last(ws);
        }
        self.live_writes -= 1;
        dw_prev
    }

    // -- episode lifecycle ---------------------------------------------------

    /// Discard the remaining write tape: revert every outstanding global
    /// write (one journal per shard each), newest first.
    pub fn rollback_ws(&mut self, ws: &mut Workspace) {
        if self.s == 1 {
            return self.shards[0].rollback_ws(ws);
        }
        if self.live_writes > 0 {
            metrics::MEM_ROLLBACKS.inc();
        }
        while self.live_writes > 0 {
            for shard in &mut self.shards {
                shard.shard_revert_last(ws);
            }
            self.live_writes -= 1;
        }
    }

    /// [`ShardedMemoryEngine::rollback_ws`] without buffer reuse (tests /
    /// cold paths).
    pub fn rollback(&mut self) {
        let mut ws = Workspace::new();
        self.rollback_ws(&mut ws);
    }

    /// Start a new episode (rolls back abandoned tape, resets the global
    /// ring, clears the carried gradient).
    pub fn reset(&mut self, ws: &mut Workspace) {
        if self.s == 1 {
            return self.shards[0].reset(ws);
        }
        self.rollback_ws(ws);
        if let Some(ring) = self.ring.as_mut() {
            ring.reset();
        }
        self.dmem.clear();
    }

    /// Called after the last backward of an episode; asserts every shard
    /// tape drained in lockstep with the global count.
    pub fn end_episode(&mut self) {
        if self.s == 1 {
            return self.shards[0].end_episode();
        }
        debug_assert_eq!(self.live_writes, 0, "end_episode with outstanding writes");
        for shard in &self.shards {
            debug_assert_eq!(shard.journals_len(), 0, "shard tape out of lockstep");
        }
    }

    /// Serving episode boundary: every shard regenerates its seeded init
    /// (through the global-id mapping) and re-syncs its ANN in place; the
    /// global ring resets. Allocation-free, like the engine's.
    pub fn reinit(&mut self) {
        if self.s == 1 {
            return self.shards[0].reinit();
        }
        for shard in &mut self.shards {
            shard.reinit();
        }
        if let Some(ring) = self.ring.as_mut() {
            ring.reset();
        }
        self.dmem.clear();
    }

    /// Total full ANN rebuilds across all shards (0 on the incremental
    /// default path — pinned by the sharded rollback fuzz).
    pub fn ann_full_rebuilds(&self) -> usize {
        self.shards.iter().map(|sh| sh.ann_full_rebuilds()).sum()
    }

    // -- compatibility wrappers (tests / cold paths) -------------------------

    /// Allocating wrapper over [`ShardedMemoryEngine::read_topk_into`].
    pub fn read_topk(&mut self, queries: Vec<(Vec<f32>, f32)>) -> Vec<TopKRead> {
        let mut ws = Workspace::new();
        let (qs, betas): (Vec<Vec<f32>>, Vec<f32>) = queries.into_iter().unzip();
        let mut out = Vec::new();
        self.read_topk_into(&qs, &betas, &mut out, &mut ws);
        out
    }

    /// Allocating wrapper over
    /// [`ShardedMemoryEngine::content_read_many_into`].
    pub fn content_read_many(&mut self, queries: &[(Vec<f32>, f32)]) -> Vec<ContentRead> {
        let mut ws = Workspace::new();
        let qs: Vec<Vec<f32>> = queries.iter().map(|(q, _)| q.clone()).collect();
        let betas: Vec<f32> = queries.iter().map(|&(_, b)| b).collect();
        let mut out = Vec::new();
        self.content_read_many_into(&qs, &betas, &mut out, &mut ws);
        out
    }

    /// Allocating wrapper over [`ShardedMemoryEngine::read_mixture_into`].
    pub fn read_mixture(&mut self, w_read: &SparseVec) -> Vec<f32> {
        let mut r = Vec::new();
        self.read_mixture_into(w_read, &mut r);
        r
    }

    /// Full snapshot **in global row order** (decoded to f32 whatever the
    /// row format) — shard layout is invisible, so S=1 and S=8 snapshots
    /// of the same logical memory are equal.
    pub fn snapshot(&self) -> Vec<f32> {
        if self.s == 1 {
            return self.shards[0].snapshot();
        }
        let mut out = vec![0.0; self.n * self.word];
        for i in 0..self.n {
            let sh = self.shards[i % self.s].store();
            sh.decode_row_into(i / self.s, &mut out[i * self.word..(i + 1) * self.word]);
        }
        out
    }

    /// Storage format of the shard stores (uniform across shards).
    pub fn row_format(&self) -> RowFormat {
        self.shards[0].row_format()
    }

    // -- spill/rehydrate state export + import -------------------------------

    /// Per-row dequant scales **in global row order** (all 1.0 outside
    /// Int8). Spilled next to [`snapshot`](ShardedMemoryEngine::snapshot)
    /// so Int8 rehydration re-encodes the exact storage codes.
    pub fn row_scales(&self) -> Vec<f32> {
        (0..self.n).map(|i| self.shards[i % self.s].row_scale(i / self.s)).collect()
    }

    /// LRA ring order (global row ids, least- to most-recently used).
    /// S=1 reads the shard's own ring; S>1 the single global ring.
    pub fn ring_order(&self) -> Vec<usize> {
        if self.s == 1 {
            return self.shards[0].ring_order();
        }
        self.ring.as_ref().expect("sparse sharded engine has a global ring").order()
    }

    /// Restore spilled session state: overwrite every row from the decoded
    /// global-order snapshot (re-syncing each shard's ANN slot, mirroring
    /// [`reinit`](ShardedMemoryEngine::reinit)'s set-then-sync order),
    /// re-encode Int8 rows against their journaled `scales`, and restore
    /// the LRA ring order. Leaves no tape; serving path only.
    pub fn import_state(&mut self, rows: &[f32], scales: &[f32], ring_order: &[usize]) {
        assert_eq!(rows.len(), self.n * self.word, "imported rows shape mismatch");
        assert_eq!(scales.len(), self.n, "imported scales length mismatch");
        for i in 0..self.n {
            let vals = &rows[i * self.word..(i + 1) * self.word];
            self.shards[i % self.s].import_row(i / self.s, vals, scales[i]);
        }
        if self.s == 1 {
            self.shards[0].set_ring_order(ring_order);
        } else {
            self.ring
                .as_mut()
                .expect("sparse sharded engine has a global ring")
                .set_order(ring_order);
        }
        self.dmem.clear();
    }

    // -- accounting ----------------------------------------------------------

    /// Bytes of per-episode BPTT state (the Fig 1b quantity).
    pub fn tape_bytes(&self) -> usize {
        self.journal_heap_bytes()
    }

    pub fn store_heap_bytes(&self) -> usize {
        self.shards.iter().map(|sh| sh.store_heap_bytes()).sum()
    }

    pub fn ann_heap_bytes(&self) -> usize {
        self.shards.iter().map(|sh| sh.ann_heap_bytes()).sum()
    }

    pub fn ring_heap_bytes(&self) -> usize {
        self.shards.iter().map(|sh| sh.ring_heap_bytes()).sum::<usize>()
            + self.ring.as_ref().map(|r| r.heap_bytes()).unwrap_or(0)
    }

    pub fn journal_heap_bytes(&self) -> usize {
        self.shards.iter().map(|sh| sh.journal_heap_bytes()).sum()
    }

    pub fn grad_heap_bytes(&self) -> usize {
        self.shards.iter().map(|sh| sh.grad_heap_bytes()).sum::<usize>()
            + self.dmem.heap_bytes()
    }

    /// Total engine heap — exactly the sum of its parts (asserted in
    /// `benches/fig1_memory.rs` across shard counts).
    pub fn heap_bytes(&self) -> usize {
        self.store_heap_bytes()
            + self.ann_heap_bytes()
            + self.ring_heap_bytes()
            + self.journal_heap_bytes()
            + self.grad_heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engines(seed: u64, n: usize, word: usize, s: usize) -> (ShardedMemoryEngine, ShardedMemoryEngine) {
        // Same seeds → same logical memory; one unsharded, one S-sharded.
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        let a = ShardedMemoryEngine::new_sparse(n, word, 3, 0.005, AnnKind::Linear, &mut r1, 1);
        let b = ShardedMemoryEngine::new_sparse(n, word, 3, 0.005, AnnKind::Linear, &mut r2, s);
        (a, b)
    }

    #[test]
    fn striping_reassembles_the_unsharded_init() {
        for s in [2usize, 3, 5] {
            let (a, b) = engines(7, 23, 6, s);
            assert_eq!(a.snapshot(), b.snapshot(), "S={s} init layout");
            for i in 0..23 {
                assert_eq!(a.row(i), b.row(i), "row {i} S={s}");
                assert_eq!(b.shard(i % s).store().row(i / s), b.row(i));
            }
        }
    }

    #[test]
    fn write_read_backward_rollback_match_unsharded_bitwise() {
        for s in [2usize, 3, 8] {
            let (mut a, mut b) = engines(11, 32, 6, s);
            let mut ws_a = Workspace::new();
            let mut ws_b = Workspace::new();
            let mut rng = Rng::new(99);
            let start = a.snapshot();
            let mut wp_a = SparseVec::new();
            let mut wp_b = SparseVec::new();
            let mut tape = Vec::new();
            for _ in 0..10 {
                let word: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
                let (ar, gr) = (rng.normal(), rng.normal());
                let ga = a.sparse_write(ar, gr, &wp_a, &word, &mut ws_a);
                let gb = b.sparse_write(ar, gr, &wp_b, &word, &mut ws_b);
                assert_eq!(ga.lra_row, gb.lra_row, "LRA choice must match (S={s})");
                assert_eq!(ga.weights, gb.weights);
                let q: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
                let ra = a.read_topk(vec![(q.clone(), 0.4)]);
                let rb = b.read_topk(vec![(q, 0.4)]);
                assert_eq!(ra[0].read.rows, rb[0].read.rows, "candidate order (S={s})");
                assert_eq!(ra[0].read.weights, rb[0].read.weights);
                assert_eq!(ra[0].r, rb[0].r);
                wp_a = ra.into_iter().next().unwrap().weights;
                wp_b = rb.into_iter().next().unwrap().weights;
                tape.push((ga, gb, word));
            }
            assert_eq!(a.snapshot(), b.snapshot(), "post-write memory (S={s})");
            // Backward through the writes (no read backward here; the full
            // stack parity lives in rust/tests/shard_parity.rs).
            let dr: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            let da_seed = a.backward_sparse_read(&wp_a, &dr, &SparseVec::new(), &mut ws_a);
            let db_seed = b.backward_sparse_read(&wp_b, &dr, &SparseVec::new(), &mut ws_b);
            assert_eq!(da_seed, db_seed);
            for (ga, gb, word) in tape.iter().rev() {
                let (mut ar_a, mut gr_a, mut ar_b, mut gr_b) = (0.0, 0.0, 0.0, 0.0);
                let mut da_a = vec![0.0; 6];
                let mut da_b = vec![0.0; 6];
                let empty = SparseVec::new();
                let dwa = a.backward_write_into(
                    ga, word, &empty, &mut ar_a, &mut gr_a, &mut da_a, &mut ws_a,
                );
                let dwb = b.backward_write_into(
                    gb, word, &empty, &mut ar_b, &mut gr_b, &mut da_b, &mut ws_b,
                );
                assert_eq!(ar_a.to_bits(), ar_b.to_bits());
                assert_eq!(gr_a.to_bits(), gr_b.to_bits());
                assert_eq!(da_a, da_b);
                assert_eq!(dwa, dwb);
            }
            a.end_episode();
            b.end_episode();
            assert_eq!(a.snapshot(), start, "unsharded rollback");
            assert_eq!(b.snapshot(), start, "sharded rollback (S={s})");
        }
    }

    #[test]
    fn rollback_restores_memory_and_ann_answers() {
        let mut rng = Rng::new(3);
        let mut e = ShardedMemoryEngine::new_sparse(24, 5, 3, 0.005, AnnKind::Linear, &mut rng, 3);
        let mut ws = Workspace::new();
        let start = e.snapshot();
        let q: Vec<f32> = (0..5).map(|i| 0.2 * (i as f32 + 1.0)).collect();
        let before = e.content_read_many(&[(q.clone(), 0.5)]);
        let mut wp = SparseVec::new();
        for _ in 0..7 {
            let word: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
            let gate = e.sparse_write(rng.normal(), rng.normal(), &wp, &word, &mut ws);
            wp = gate.weights;
        }
        assert_ne!(e.snapshot(), start);
        assert!(e.tape_bytes() > 0);
        e.rollback();
        assert_eq!(e.snapshot(), start, "sharded rollback must be bit-exact");
        assert_eq!(e.tape_bytes(), 0);
        let after = e.content_read_many(&[(q, 0.5)]);
        assert_eq!(before[0].rows, after[0].rows, "shard ANNs must be back in sync");
        for (x, y) in before[0].weights.iter().zip(&after[0].weights) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn infer_write_matches_sparse_write_with_zero_tape() {
        // a journals (train), b infers — same S=4 sharded semantics
        // required (cross-S parity is covered above).
        let mut r1 = Rng::new(17);
        let mut r2 = Rng::new(17);
        let mut a = ShardedMemoryEngine::new_sparse(24, 6, 3, 0.005, AnnKind::Linear, &mut r1, 4);
        let mut b = ShardedMemoryEngine::new_sparse(24, 6, 3, 0.005, AnnKind::Linear, &mut r2, 4);
        let mut ws_a = Workspace::new();
        let mut ws_b = Workspace::new();
        let mut rng = Rng::new(18);
        let mut wp_a = SparseVec::new();
        let mut wp_b = SparseVec::new();
        for _ in 0..6 {
            let word: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            let (ar, gr) = (rng.normal(), rng.normal());
            let gate = a.sparse_write(ar, gr, &wp_a, &word, &mut ws_a);
            let wts = b.infer_write(ar, gr, &wp_b, &word, &mut ws_b);
            assert_eq!(gate.weights, wts);
            let q: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            let ra = a.read_topk(vec![(q.clone(), 0.4)]);
            let rb = b.read_topk(vec![(q, 0.4)]);
            assert_eq!(ra[0].weights, rb[0].weights);
            assert_eq!(ra[0].r, rb[0].r);
            wp_a = ra.into_iter().next().unwrap().weights;
            wp_b = rb.into_iter().next().unwrap().weights;
            ws_b.recycle_sparse(wts);
            assert_eq!(b.tape_bytes(), 0, "infer path must journal nothing");
        }
        assert_eq!(a.snapshot(), b.snapshot());
        a.rollback();
    }

    #[test]
    fn reinit_restores_episode_start_across_shards() {
        let mut rng = Rng::new(21);
        let mut e = ShardedMemoryEngine::new_sparse(20, 4, 3, 0.005, AnnKind::Linear, &mut rng, 4);
        let start = e.snapshot();
        let q: Vec<f32> = (0..4).map(|i| 0.3 * (i as f32 + 1.0)).collect();
        let before = e.content_read_many(&[(q.clone(), 0.5)]);
        let mut ws = Workspace::new();
        for _ in 0..5 {
            let word: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
            let wts = e.infer_write(rng.normal(), rng.normal(), &SparseVec::new(), &word, &mut ws);
            ws.recycle_sparse(wts);
        }
        assert_ne!(e.snapshot(), start);
        e.reinit();
        assert_eq!(e.snapshot(), start, "reinit must regenerate the striped seeded init");
        let after = e.content_read_many(&[(q, 0.5)]);
        assert_eq!(before[0].rows, after[0].rows, "shard ANNs must re-sync on reinit");
    }

    #[test]
    fn heap_bytes_is_sum_of_parts_and_accounts_all_shards() {
        let mut rng = Rng::new(31);
        let mut e = ShardedMemoryEngine::new_sparse(32, 8, 3, 0.005, AnnKind::Linear, &mut rng, 4);
        let mut ws = Workspace::new();
        let mut wp = SparseVec::new();
        for _ in 0..5 {
            let word: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let gate = e.sparse_write(rng.normal(), rng.normal(), &wp, &word, &mut ws);
            wp = gate.weights;
        }
        assert_eq!(
            e.heap_bytes(),
            e.store_heap_bytes()
                + e.ann_heap_bytes()
                + e.ring_heap_bytes()
                + e.journal_heap_bytes()
                + e.grad_heap_bytes()
        );
        // Stores across shards sum to exactly the unsharded store.
        assert_eq!(e.store_heap_bytes(), 32 * 8 * 4);
        // The global ring is the only ring.
        assert_eq!(e.ring_heap_bytes(), 2 * 32 * std::mem::size_of::<usize>());
        assert!(e.tape_bytes() > 0);
        e.rollback();
        assert_eq!(e.tape_bytes(), 0);
    }

    #[test]
    fn kd_and_lsh_shards_are_run_deterministic() {
        for kind in [AnnKind::KdForest, AnnKind::Lsh, AnnKind::Hnsw] {
            let run = |seed: u64| -> Vec<u32> {
                let mut rng = Rng::new(seed);
                let mut e =
                    ShardedMemoryEngine::new_sparse(48, 8, 3, 0.005, kind, &mut rng, 3);
                let mut ws = Workspace::new();
                let mut wp = SparseVec::new();
                let mut bits = Vec::new();
                for _ in 0..6 {
                    let word: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                    let gate = e.sparse_write(rng.normal(), rng.normal(), &wp, &word, &mut ws);
                    let q: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                    let r = e.read_topk(vec![(q, 0.4)]);
                    bits.extend(r[0].r.iter().map(|v| v.to_bits()));
                    bits.extend(r[0].read.rows.iter().map(|&i| i as u32));
                    wp = r.into_iter().next().unwrap().weights;
                    drop(gate);
                }
                e.rollback();
                bits
            };
            assert_eq!(run(5), run(5), "{kind:?} sharded run must be deterministic");
        }
    }
}
