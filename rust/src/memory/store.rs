//! The external memory M ∈ R^{N×W} with sparse writes and O(1) rollback.
//!
//! This implements the paper's memory-efficient BPTT (§3.4, Supp Fig 5):
//! instead of caching the full memory at every time step (O(N·T) space),
//! each write records a [`StepJournal`] with the *old contents of the few
//! rows it touches* (O(K·W) = O(1) space per step). During the backward
//! pass the journals are reverted in reverse order, rolling the memory back
//! to its state at each step — bit-exactly, because we restore saved bytes
//! rather than subtracting float updates.
//!
//! A pleasant corollary used by the trainer: after a full backward pass the
//! memory has rolled all the way back to its episode-start state, so no
//! O(N) reset is needed between episodes.

use crate::tensor::csr::SparseVec;
use crate::tensor::matrix::{axpy, dot};
use crate::tensor::rowcodec::{RowFormat, RowStore};
use crate::tensor::workspace::Workspace;

/// Row-addressed read access to memory contents. The addressing math
/// (`cores::addressing`) is written against this instead of a concrete
/// [`MemoryStore`] so the sharded engine can present N rows that physically
/// live in S different stores (global row `i` → shard `i % S`, local row
/// `i / S`) without copying. For a plain store, `row(i)` is the slice it
/// always was.
///
/// The two fused kernels have row-borrowing defaults (exactly the float-op
/// sequences the addressing/read paths always ran), and codec-aware
/// implementors override them so compact rows are decoded inside the scan
/// instead of borrowed — `row()` itself stays the f32/training accessor and
/// panics on compact formats.
pub trait RowSource {
    fn row(&self, i: usize) -> &[f32];

    /// Fused `(q·row(i), row(i)·row(i))` — the content-addressing read.
    #[inline]
    fn row_dot_normsq(&self, i: usize, q: &[f32]) -> (f32, f32) {
        let r = self.row(i);
        (dot(q, r), dot(r, r))
    }

    /// `out += coeff · row(i)` — the sparse-read mixture kernel.
    #[inline]
    fn row_axpy(&self, i: usize, coeff: f32, out: &mut [f32]) {
        axpy(out, coeff, self.row(i));
    }
}

/// Dense external memory of `n` words (rows) of width `w`, stored in one of
/// the [`RowFormat`] codecs (f32 by default; bf16/int8 for serve/eval).
#[derive(Debug, Clone)]
pub struct MemoryStore {
    n: usize,
    w: usize,
    rows: RowStore,
    /// Decode staging for compact-format writes (empty for f32; persistent
    /// so the journal-free serving write stays zero-allocation).
    scratch: Vec<f32>,
}

impl RowSource for MemoryStore {
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        MemoryStore::row(self, i)
    }

    #[inline]
    fn row_dot_normsq(&self, i: usize, q: &[f32]) -> (f32, f32) {
        self.rows.dot_normsq(i, q)
    }

    #[inline]
    fn row_axpy(&self, i: usize, coeff: f32, out: &mut [f32]) {
        self.rows.axpy_into(i, coeff, out);
    }
}

/// One write step's sparse modification record: the prior contents of every
/// row the step touched. Reverting = copying these rows back.
#[derive(Debug, Clone, Default)]
pub struct StepJournal {
    saved: Vec<(usize, Vec<f32>)>,
}

impl StepJournal {
    pub fn touched_rows(&self) -> impl Iterator<Item = usize> + '_ {
        self.saved.iter().map(|(i, _)| *i)
    }

    pub fn is_empty(&self) -> bool {
        self.saved.is_empty()
    }

    /// Hand the saved row buffers back to a workspace, leaving an empty
    /// journal shell (its `saved` Vec keeps capacity) ready for reuse.
    pub fn recycle_rows(&mut self, ws: &mut Workspace) {
        for (_, row) in self.saved.drain(..) {
            ws.recycle_f32(row);
        }
    }

    /// Heap bytes held (for the Fig 1b accounting): K+1 rows of W floats.
    pub fn heap_bytes(&self) -> usize {
        self.saved
            .iter()
            .map(|(_, row)| row.capacity() * 4 + 24)
            .sum::<usize>()
            + self.saved.capacity() * 32
    }
}

/// A sparse write (paper eq. 3/8): zero the erased rows (R_t = I^U 1ᵀ),
/// then add the outer product w^W aᵀ restricted to w^W's support.
#[derive(Debug, Clone)]
pub struct WriteOp {
    /// Rows fully erased before writing (the least-recently-accessed word).
    pub erase_rows: Vec<usize>,
    /// Sparse write weights w^W (K+1 non-zeros for SAM).
    pub weights: SparseVec,
    /// The write word a_t (length W).
    pub word: Vec<f32>,
}

impl MemoryStore {
    /// Allocate an n×w f32 memory initialized to zero (O(N) — the one-off
    /// init cost of Supp A.1).
    pub fn zeros(n: usize, w: usize) -> MemoryStore {
        MemoryStore::zeros_fmt(n, w, RowFormat::F32)
    }

    /// [`MemoryStore::zeros`] in an explicit row format (`--row-format`).
    pub fn zeros_fmt(n: usize, w: usize, fmt: RowFormat) -> MemoryStore {
        let scratch = if fmt == RowFormat::F32 { Vec::new() } else { vec![0.0; w] };
        MemoryStore { n, w, rows: RowStore::zeros(n, w, fmt), scratch }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn word_size(&self) -> usize {
        self.w
    }

    /// The storage codec rows are held in.
    #[inline]
    pub fn fmt(&self) -> RowFormat {
        self.rows.fmt()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        self.rows.row(i)
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        self.rows.row_mut(i)
    }

    /// Decode row `i` into a caller buffer (any format; the ANN re-insert
    /// and journaling path for compact rows).
    #[inline]
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        self.rows.decode_into(i, out);
    }

    /// Encode `vals` into row `i` (quantize-on-write for compact formats).
    #[inline]
    pub fn set_row(&mut self, i: usize, vals: &[f32]) {
        self.rows.set_row(i, vals);
    }

    /// Dequant scale of row `i` (Int8; other formats return 1.0). Spilled
    /// alongside decoded rows so rehydration can re-encode Int8 storage
    /// bits exactly (see [`MemoryStore::set_row_with_scale`]).
    #[inline]
    pub fn row_scale(&self, i: usize) -> f32 {
        self.rows.row_scale(i)
    }

    /// Int8-only: encode `vals` against a caller-supplied scale, so decoded
    /// values round back to the original storage codes bit-exactly (the
    /// journal-revert and spill-rehydration path).
    #[inline]
    pub fn set_row_with_scale(&mut self, i: usize, vals: &[f32], scale: f32) {
        self.rows.set_row_with_scale(i, vals, scale);
    }

    /// Squared distance from `q` to row `i`, decode fused in.
    #[inline]
    pub fn row_dist_sq(&self, i: usize, q: &[f32]) -> f32 {
        self.rows.dist_sq_to(i, q)
    }

    pub fn fill(&mut self, v: f32) {
        self.rows.fill(v);
    }

    /// Sparse read r = Σᵢ w̃(sᵢ) M(sᵢ) (paper eq. 4) in O(K·W). For f32
    /// rows this is the exact historical float-op sequence (axpy per
    /// support row); compact rows decode inside the same fused loop.
    pub fn read_sparse(&self, weights: &SparseVec, out: &mut [f32]) {
        assert_eq!(out.len(), self.w);
        out.iter_mut().for_each(|x| *x = 0.0);
        for (i, wv) in weights.iter() {
            self.rows.axpy_into(i, wv, out);
        }
    }

    /// Dense read r = Σᵢ w(i) M(i) (paper eq. 1) in O(N·W).
    pub fn read_dense(&self, weights: &[f32], out: &mut [f32]) {
        assert_eq!(weights.len(), self.n);
        assert_eq!(out.len(), self.w);
        out.iter_mut().for_each(|x| *x = 0.0);
        for (i, &wv) in weights.iter().enumerate() {
            if wv != 0.0 {
                self.rows.axpy_into(i, wv, out);
            }
        }
    }

    /// Apply a sparse write, journaling prior contents of touched rows.
    /// O(K·W) time and space, independent of N. f32-only (the generic
    /// dense/test path; the engine's hot writes go through
    /// [`MemoryStore::journal_sparse_write_opt`], which handles every
    /// format).
    pub fn apply_write(&mut self, op: &WriteOp) -> StepJournal {
        assert_eq!(op.word.len(), self.w);
        assert!(self.fmt() == RowFormat::F32, "apply_write is f32-only");
        // Save each distinct touched row once (erase ∪ add supports).
        let mut journal = StepJournal::default();
        let save = |store: &RowStore, j: &mut StepJournal, i: usize| {
            if !j.saved.iter().any(|(r, _)| *r == i) {
                j.saved.push((i, store.row(i).to_vec()));
            }
        };
        for &i in &op.erase_rows {
            save(&self.rows, &mut journal, i);
        }
        for (i, _) in op.weights.iter() {
            save(&self.rows, &mut journal, i);
        }
        // Erase then add (paper: the LRA word is set to zero before writing).
        for &i in &op.erase_rows {
            self.row_mut(i).iter_mut().for_each(|x| *x = 0.0);
        }
        for (i, wv) in op.weights.iter() {
            let row = self.row_mut(i);
            for (m, a) in row.iter_mut().zip(&op.word) {
                *m += wv * a;
            }
        }
        journal
    }

    /// Hot-path twin of [`MemoryStore::apply_write`] for the engine's
    /// single-erase-row writes: journals into the caller's (reused) journal
    /// shell with row buffers drawn from the workspace instead of fresh
    /// `to_vec`s. Identical write semantics and journal row order (erase
    /// row first, then the weight support in index order, deduplicated).
    pub fn journal_sparse_write(
        &mut self,
        erase_row: usize,
        weights: &SparseVec,
        word: &[f32],
        journal: &mut StepJournal,
        ws: &mut Workspace,
    ) {
        self.journal_sparse_write_opt(Some(erase_row), weights, word, journal, ws);
    }

    /// [`MemoryStore::journal_sparse_write`] with the erase row optional —
    /// the shard-local form of a global gated write: only the shard that
    /// owns the LRA row erases; the others journal and apply just their
    /// slice of the add support (possibly empty, which still records an
    /// empty journal so per-shard tapes stay aligned step-for-step).
    pub fn journal_sparse_write_opt(
        &mut self,
        erase_row: Option<usize>,
        weights: &SparseVec,
        word: &[f32],
        journal: &mut StepJournal,
        ws: &mut Workspace,
    ) {
        assert_eq!(word.len(), self.w);
        debug_assert!(journal.is_empty(), "journal shell must arrive drained");
        if self.fmt() == RowFormat::F32 {
            if let Some(erase_row) = erase_row {
                journal
                    .saved
                    .push((erase_row, ws.take_f32_copy(self.row(erase_row))));
            }
            for (i, _) in weights.iter() {
                if erase_row != Some(i) {
                    let row_copy = ws.take_f32_copy(self.row(i));
                    journal.saved.push((i, row_copy));
                }
            }
            if let Some(erase_row) = erase_row {
                self.row_mut(erase_row).iter_mut().for_each(|x| *x = 0.0);
            }
            for (i, wv) in weights.iter() {
                let row = self.row_mut(i);
                for (m, a) in row.iter_mut().zip(word) {
                    *m += wv * a;
                }
            }
            return;
        }
        // Compact rows: journal the *decoded* contents (plus, for int8, the
        // row's scale as a trailing element) so revert can re-encode the
        // exact prior storage bits; then decode-modify-encode each touched
        // row (quantize-on-write).
        if let Some(erase_row) = erase_row {
            journal.saved.push((erase_row, self.journal_row_copy(erase_row, ws)));
        }
        for (i, _) in weights.iter() {
            if erase_row != Some(i) {
                let row_copy = self.journal_row_copy(i, ws);
                journal.saved.push((i, row_copy));
            }
        }
        self.apply_sparse_write_opt(erase_row, weights, word);
    }

    /// Journal payload for one compact row: the decoded values, with the
    /// int8 dequant scale appended so revert restores identical bits.
    fn journal_row_copy(&self, i: usize, ws: &mut Workspace) -> Vec<f32> {
        let extra = (self.fmt() == RowFormat::Int8) as usize;
        let mut buf = ws.take_f32(self.w + extra);
        self.rows.decode_into(i, &mut buf[..self.w]);
        if extra == 1 {
            buf[self.w] = self.rows.row_scale(i);
        }
        buf
    }

    /// Journal-free twin of [`MemoryStore::journal_sparse_write`] for
    /// forward-only inference: identical write semantics (erase the LRA row,
    /// then the sparse add), but nothing is saved — the memory advances
    /// irreversibly and the step costs zero tape bytes. Serving sessions
    /// never backpropagate, so the journal would be pure overhead.
    pub fn apply_sparse_write(&mut self, erase_row: usize, weights: &SparseVec, word: &[f32]) {
        self.apply_sparse_write_opt(Some(erase_row), weights, word);
    }

    /// [`MemoryStore::apply_sparse_write`] with the erase row optional —
    /// the journal-free shard-local write (serving mode on a sharded
    /// engine).
    pub fn apply_sparse_write_opt(
        &mut self,
        erase_row: Option<usize>,
        weights: &SparseVec,
        word: &[f32],
    ) {
        assert_eq!(word.len(), self.w);
        if self.fmt() == RowFormat::F32 {
            if let Some(erase_row) = erase_row {
                self.row_mut(erase_row).iter_mut().for_each(|x| *x = 0.0);
            }
            for (i, wv) in weights.iter() {
                let row = self.row_mut(i);
                for (m, a) in row.iter_mut().zip(word) {
                    *m += wv * a;
                }
            }
            return;
        }
        // Compact rows: decode-modify-encode per touched row, in f32, via
        // the persistent scratch (zero allocations in steady state). The
        // erase row starts from zero; if it is not also in the add support
        // it is written back as an encoded zero row.
        if let Some(er) = erase_row {
            if !weights.iter().any(|(i, _)| i == er) {
                self.scratch.iter_mut().for_each(|x| *x = 0.0);
                self.rows.set_row(er, &self.scratch);
            }
        }
        for (i, wv) in weights.iter() {
            if erase_row == Some(i) {
                self.scratch.iter_mut().for_each(|x| *x = 0.0);
            } else {
                self.rows.decode_into(i, &mut self.scratch);
            }
            for (m, a) in self.scratch.iter_mut().zip(word) {
                *m += wv * a;
            }
            self.rows.set_row(i, &self.scratch);
        }
    }

    /// Dense write M ← (1-R)⊙M + A with R = w^W eᵀ, A = w^W aᵀ (paper
    /// eq. 3, NTM-style). O(N·W): for the dense baselines the caller caches
    /// the full memory per step instead of journaling.
    pub fn apply_write_dense(&mut self, weights: &[f32], erase: &[f32], add: &[f32]) {
        assert_eq!(weights.len(), self.n);
        assert_eq!(erase.len(), self.w);
        assert_eq!(add.len(), self.w);
        for i in 0..self.n {
            let wv = weights[i];
            if wv == 0.0 {
                continue;
            }
            let row = self.row_mut(i);
            for j in 0..row.len() {
                row[j] = row[j] * (1.0 - wv * erase[j]) + wv * add[j];
            }
        }
    }

    /// Revert a journaled write: restore the saved rows (bit-exact in every
    /// format — f32 copies bytes back; bf16 re-encodes losslessly
    /// (`encode∘decode` is the identity); int8 re-encodes against the
    /// journaled scale, which reproduces the original codes exactly).
    pub fn revert(&mut self, journal: &StepJournal) {
        match self.fmt() {
            RowFormat::F32 => {
                for (i, old) in journal.saved.iter().rev() {
                    self.row_mut(*i).copy_from_slice(old);
                }
            }
            RowFormat::Bf16 => {
                for (i, old) in journal.saved.iter().rev() {
                    self.rows.set_row(*i, old);
                }
            }
            RowFormat::Int8 => {
                for (i, old) in journal.saved.iter().rev() {
                    let (vals, scale) = old.split_at(self.w);
                    self.rows.set_row_with_scale(*i, vals, scale[0]);
                }
            }
        }
    }

    /// Full snapshot as decoded f32 (used by the dense baselines' BPTT
    /// tape — this O(N·W) copy per step is exactly the overhead SAM
    /// eliminates).
    pub fn snapshot(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.snapshot_into(&mut out);
        out
    }

    /// Snapshot into a reused buffer (the dense baselines' per-step copy
    /// without the per-step allocation). Compact rows are decoded; pairing
    /// with [`MemoryStore::restore`] is value-faithful, not bit-identical
    /// to the pre-snapshot *storage* for int8 (scales are recomputed).
    pub fn snapshot_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.n * self.w);
        for i in 0..self.n {
            let len = out.len();
            out.resize(len + self.w, 0.0);
            self.rows.decode_into(i, &mut out[len..]);
        }
    }

    pub fn restore(&mut self, snap: &[f32]) {
        assert_eq!(snap.len(), self.n * self.w);
        for i in 0..self.n {
            self.rows.set_row(i, &snap[i * self.w..(i + 1) * self.w]);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.rows.heap_bytes() + self.scratch.capacity() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_store(n: usize, w: usize, rng: &mut Rng) -> MemoryStore {
        let mut m = MemoryStore::zeros(n, w);
        for i in 0..n {
            for j in 0..w {
                m.row_mut(i)[j] = rng.normal();
            }
        }
        m
    }

    #[test]
    fn sparse_read_matches_dense() {
        let mut rng = Rng::new(1);
        let m = random_store(32, 8, &mut rng);
        let sw = SparseVec::from_pairs(vec![(3, 0.5), (17, 0.25), (31, 0.25)]);
        let dw = sw.to_dense(32);
        let mut rs = vec![0.0; 8];
        let mut rd = vec![0.0; 8];
        m.read_sparse(&sw, &mut rs);
        m.read_dense(&dw, &mut rd);
        for (a, b) in rs.iter().zip(&rd) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn write_then_revert_is_bit_exact() {
        let mut rng = Rng::new(2);
        let mut m = random_store(16, 4, &mut rng);
        let before = m.snapshot();
        let op = WriteOp {
            erase_rows: vec![5],
            weights: SparseVec::from_pairs(vec![(5, 1.0), (2, 0.3), (9, -0.7)]),
            word: vec![1.5, -2.0, 0.25, 3.0],
        };
        let j = m.apply_write(&op);
        assert_ne!(m.snapshot(), before);
        m.revert(&j);
        assert_eq!(m.snapshot(), before, "rollback must be bit-exact");
    }

    /// Property test: T random sparse writes then T reverts restores the
    /// start state exactly, for many seeds.
    #[test]
    fn multi_step_rollback_property() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let n = 64;
            let w = 8;
            let mut m = random_store(n, w, &mut rng);
            let start = m.snapshot();
            let t_steps = 50;
            let mut journals = Vec::new();
            for _ in 0..t_steps {
                let k = rng.int_in(1, 4);
                let idx = rng.sample_indices(n, k);
                let weights = SparseVec::from_pairs(
                    idx.iter().map(|&i| (i, rng.normal())).collect(),
                );
                let erase_rows = if rng.bernoulli(0.8) {
                    vec![rng.below(n)]
                } else {
                    vec![]
                };
                let word: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
                journals.push(m.apply_write(&WriteOp { erase_rows, weights, word }));
            }
            for j in journals.iter().rev() {
                m.revert(j);
            }
            assert_eq!(m.snapshot(), start, "seed {seed}");
        }
    }

    #[test]
    fn journal_sparse_write_matches_apply_write() {
        let mut rng = Rng::new(9);
        let mut a = random_store(16, 4, &mut rng);
        let mut b = a.clone();
        let weights = SparseVec::from_pairs(vec![(5, 1.0), (2, 0.3), (9, -0.7)]);
        let word = vec![1.5, -2.0, 0.25, 3.0];
        let op = WriteOp { erase_rows: vec![5], weights: weights.clone(), word: word.clone() };
        let j1 = a.apply_write(&op);
        let mut ws = Workspace::new();
        let mut j2 = StepJournal::default();
        b.journal_sparse_write(5, &weights, &word, &mut j2, &mut ws);
        assert_eq!(a.snapshot(), b.snapshot(), "write effects must match");
        let rows1: Vec<usize> = j1.touched_rows().collect();
        let rows2: Vec<usize> = j2.touched_rows().collect();
        assert_eq!(rows1, rows2, "journal row order must match");
        a.revert(&j1);
        b.revert(&j2);
        assert_eq!(a.snapshot(), b.snapshot(), "reverts must match");
        j2.recycle_rows(&mut ws);
        assert!(j2.is_empty());
    }

    #[test]
    fn apply_sparse_write_matches_journaled_write() {
        let mut rng = Rng::new(11);
        let mut a = random_store(16, 4, &mut rng);
        let mut b = a.clone();
        let weights = SparseVec::from_pairs(vec![(5, 1.0), (2, 0.3), (9, -0.7)]);
        let word = vec![1.5, -2.0, 0.25, 3.0];
        let mut ws = Workspace::new();
        let mut j = StepJournal::default();
        a.journal_sparse_write(5, &weights, &word, &mut j, &mut ws);
        b.apply_sparse_write(5, &weights, &word);
        assert_eq!(a.snapshot(), b.snapshot(), "infer write must match the journaled write");
    }

    #[test]
    fn opt_erase_write_journals_and_reverts() {
        // The shard-local form: no erase row, support-only journal; and the
        // fully-empty write still leaves a (revertible) empty journal.
        let mut rng = Rng::new(13);
        let mut m = random_store(8, 3, &mut rng);
        let before = m.snapshot();
        let mut ws = Workspace::new();
        let weights = SparseVec::from_pairs(vec![(2, 0.5), (6, -1.0)]);
        let word = vec![1.0, 2.0, 3.0];
        let mut j = StepJournal::default();
        m.journal_sparse_write_opt(None, &weights, &word, &mut j, &mut ws);
        assert_eq!(j.touched_rows().collect::<Vec<_>>(), vec![2, 6]);
        assert_ne!(m.snapshot(), before);
        m.revert(&j);
        assert_eq!(m.snapshot(), before);
        let mut j2 = StepJournal::default();
        m.journal_sparse_write_opt(None, &SparseVec::new(), &word, &mut j2, &mut ws);
        assert!(j2.is_empty(), "empty shard write must journal nothing");
        assert_eq!(m.snapshot(), before, "empty shard write must not touch memory");
        m.revert(&j2);
        assert_eq!(m.snapshot(), before);
    }

    #[test]
    fn erase_zeroes_before_add() {
        let mut m = MemoryStore::zeros(4, 2);
        m.row_mut(1).copy_from_slice(&[9.0, 9.0]);
        let op = WriteOp {
            erase_rows: vec![1],
            weights: SparseVec::from_pairs(vec![(1, 0.5)]),
            word: vec![2.0, 4.0],
        };
        m.apply_write(&op);
        assert_eq!(m.row(1), &[1.0, 2.0]); // 0 + 0.5*word, old 9s gone
    }

    #[test]
    fn dense_write_matches_formula() {
        let mut m = MemoryStore::zeros(2, 2);
        m.row_mut(0).copy_from_slice(&[1.0, 2.0]);
        m.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let weights = [0.5, 0.0];
        let erase = [1.0, 0.5];
        let add = [10.0, 10.0];
        m.apply_write_dense(&weights, &erase, &add);
        // row0: [1*(1-0.5*1)+0.5*10, 2*(1-0.5*0.5)+0.5*10] = [5.5, 6.5]
        assert_eq!(m.row(0), &[5.5, 6.5]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    // -- compact-row (bf16/int8) write/rollback contract --------------------

    fn random_compact_store(n: usize, w: usize, fmt: RowFormat, rng: &mut Rng) -> MemoryStore {
        let mut m = MemoryStore::zeros_fmt(n, w, fmt);
        let mut buf = vec![0.0; w];
        for i in 0..n {
            for v in buf.iter_mut() {
                *v = rng.normal() * 0.02;
            }
            m.set_row(i, &buf);
        }
        m
    }

    #[test]
    fn compact_write_then_revert_is_bit_exact() {
        for fmt in [RowFormat::Bf16, RowFormat::Int8] {
            for seed in 0..10u64 {
                let mut rng = Rng::new(seed);
                let (n, w) = (32, 12);
                let mut m = random_compact_store(n, w, fmt, &mut rng);
                // Compare decoded contents before/after; storage bits are
                // a function of decoded values + (journaled) scales.
                let before = m.snapshot();
                let scales: Vec<f32> = (0..n).map(|i| m.rows.row_scale(i)).collect();
                let mut ws = Workspace::new();
                let mut journals = Vec::new();
                for _ in 0..25 {
                    let k = rng.int_in(1, 4);
                    let idx = rng.sample_indices(n, k);
                    let weights = SparseVec::from_pairs(
                        idx.iter().map(|&i| (i, rng.normal())).collect(),
                    );
                    let erase = if rng.bernoulli(0.8) { Some(rng.below(n)) } else { None };
                    let word: Vec<f32> = (0..w).map(|_| rng.normal() * 0.02).collect();
                    let mut j = StepJournal::default();
                    m.journal_sparse_write_opt(erase, &weights, &word, &mut j, &mut ws);
                    journals.push(j);
                }
                for j in journals.iter().rev() {
                    m.revert(j);
                }
                assert_eq!(m.snapshot(), before, "{fmt:?} seed {seed}: decoded rollback");
                let scales_after: Vec<f32> = (0..n).map(|i| m.rows.row_scale(i)).collect();
                assert_eq!(scales, scales_after, "{fmt:?} seed {seed}: scale rollback");
            }
        }
    }

    #[test]
    fn compact_infer_write_matches_journaled_write() {
        for fmt in [RowFormat::Bf16, RowFormat::Int8] {
            let mut rng = Rng::new(17);
            let mut a = random_compact_store(16, 6, fmt, &mut rng);
            let mut b = a.clone();
            let weights = SparseVec::from_pairs(vec![(2, 0.3), (5, 1.0), (9, -0.7)]);
            let word: Vec<f32> = (0..6).map(|_| rng.normal() * 0.02).collect();
            let mut ws = Workspace::new();
            let mut j = StepJournal::default();
            a.journal_sparse_write(5, &weights, &word, &mut j, &mut ws);
            b.apply_sparse_write(5, &weights, &word);
            assert_eq!(a.snapshot(), b.snapshot(), "{fmt:?}: infer write must match");
        }
    }

    #[test]
    fn compact_erase_zeroes_before_add() {
        for fmt in [RowFormat::Bf16, RowFormat::Int8] {
            let mut m = MemoryStore::zeros_fmt(4, 2, fmt);
            m.set_row(1, &[9.0, 9.0]);
            m.apply_sparse_write(1, &SparseVec::from_pairs(vec![(1, 0.5)]), &[2.0, 4.0]);
            let mut dec = vec![0.0; 2];
            m.decode_row_into(1, &mut dec);
            // 0 + 0.5·word, old 9s gone; both values are exactly encodable.
            assert_eq!(dec, vec![1.0, 2.0], "{fmt:?}");
            // Erase-only (row not in support) leaves an encoded zero row.
            m.apply_sparse_write(1, &SparseVec::new(), &[2.0, 4.0]);
            m.decode_row_into(1, &mut dec);
            assert_eq!(dec, vec![0.0, 0.0], "{fmt:?} erase-only");
        }
    }

    #[test]
    fn compact_heap_bytes_shrink() {
        let (n, w) = (64, 16);
        let f32b = MemoryStore::zeros(n, w).heap_bytes();
        let bf = MemoryStore::zeros_fmt(n, w, RowFormat::Bf16).heap_bytes();
        let i8b = MemoryStore::zeros_fmt(n, w, RowFormat::Int8).heap_bytes();
        assert_eq!(f32b, n * w * 4);
        // Compact stores carry a w-float decode scratch on top of storage.
        assert_eq!(bf, n * w * 2 + w * 4);
        assert_eq!(i8b, n * w + n * 4 + w * 4);
    }

    #[test]
    fn journal_size_is_constant_in_n() {
        let mut rng = Rng::new(3);
        let op = WriteOp {
            erase_rows: vec![0],
            weights: SparseVec::from_pairs(vec![(0, 1.0), (1, 0.5)]),
            word: vec![1.0; 32],
        };
        let mut sizes = Vec::new();
        for &n in &[128usize, 1024, 8192] {
            let mut m = random_store(n, 32, &mut rng);
            let j = m.apply_write(&op);
            sizes.push(j.heap_bytes());
        }
        assert_eq!(sizes[0], sizes[1]);
        assert_eq!(sizes[1], sizes[2]);
    }
}
