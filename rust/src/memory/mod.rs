//! External-memory substrates: the dense store with sparse-write rollback
//! journal (§3.4), usage tracking (§3.2, Supp A.3), and the shared
//! [`engine::SparseMemoryEngine`] that owns store + ANN + ring + journals
//! on behalf of the sparse cores.
pub mod engine;
pub mod store;
pub mod usage;
