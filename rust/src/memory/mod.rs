//! External-memory substrates: the dense store with sparse-write rollback
//! journal (§3.4) and usage tracking (§3.2, Supp A.3).
pub mod store;
pub mod usage;
