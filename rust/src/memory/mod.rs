//! External-memory substrates: the dense store with sparse-write rollback
//! journal (§3.4), usage tracking (§3.2, Supp A.3), the shared
//! [`engine::SparseMemoryEngine`] that owns store + ANN + ring + journals
//! on behalf of the sparse cores, and the S-way
//! [`sharded::ShardedMemoryEngine`] that stripes those slots across
//! independent shards with a parallel, deterministically-merged ANN query.
pub mod engine;
pub mod sharded;
pub mod store;
pub mod usage;
