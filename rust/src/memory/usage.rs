//! Memory-usage tracking: which word is least recently / least heavily used?
//!
//! Two schemes from the paper (§3.2):
//!
//! * **U⁽²⁾, used by SAM** — "time steps since a non-negligible access",
//!   maintained in O(1) by [`LraRing`], the circular linked list of Supp
//!   A.3: the head is the least-recently-accessed word; touching a word
//!   splices it to the back; popping advances the head.
//!
//! * **U⁽¹⁾, used by DAM** — the time-discounted access sum
//!   U_T(i) = Σ_t λ^{T-t}(w^W_t(i) + w^R_t(i)), maintained densely in O(N)
//!   per step by [`DiscountedUsage`] (DAM is the dense control model, so
//!   O(N) is by design).

use crate::tensor::csr::SparseVec;

/// Circular doubly-linked list over word indices preserving strict temporal
/// access order. All operations O(1). (Supp A.3.)
#[derive(Debug, Clone)]
pub struct LraRing {
    next: Vec<usize>,
    prev: Vec<usize>,
    /// Least recently accessed element (front of the ring).
    head: usize,
    n: usize,
}

impl LraRing {
    /// Initialize with order 0,1,…,n-1 (0 = least recently accessed).
    pub fn new(n: usize) -> LraRing {
        assert!(n >= 2);
        let next: Vec<usize> = (0..n).map(|i| (i + 1) % n).collect();
        let prev: Vec<usize> = (0..n).map(|i| (i + n - 1) % n).collect();
        LraRing { next, prev, head: 0, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The least-recently-accessed index (the write target 𝕀ᵁ).
    pub fn lra(&self) -> usize {
        self.head
    }

    /// Mark `i` as most-recently-accessed: splice it out and insert it just
    /// before the head (= at the back of the ring). O(1).
    pub fn touch(&mut self, i: usize) {
        debug_assert!(i < self.n);
        if i == self.head {
            // Touching the front: the head simply advances.
            self.head = self.next[self.head];
            return;
        }
        let tail = self.prev[self.head];
        if i == tail {
            return; // already most recent
        }
        // Unlink i.
        let (p, nx) = (self.prev[i], self.next[i]);
        self.next[p] = nx;
        self.prev[nx] = p;
        // Insert between tail and head.
        self.next[tail] = i;
        self.prev[i] = tail;
        self.next[i] = self.head;
        self.prev[self.head] = i;
    }

    /// Pop the LRA element for writing: returns it and marks it most
    /// recently accessed (head advances). O(1).
    pub fn pop_lra(&mut self) -> usize {
        let h = self.head;
        self.head = self.next[h];
        h
    }

    /// Reset to the initial 0..n order. O(N) — episode-boundary only.
    pub fn reset(&mut self) {
        let n = self.n;
        for i in 0..n {
            self.next[i] = (i + 1) % n;
            self.prev[i] = (i + n - 1) % n;
        }
        self.head = 0;
    }

    /// Restore a previously captured [`order`](Self::order): `order[0]`
    /// becomes the LRA head, `order[n-1]` the most recent. `order` must be
    /// a permutation of 0..n. O(N) — spill-rehydration boundary only.
    pub fn set_order(&mut self, order: &[usize]) {
        assert_eq!(order.len(), self.n, "ring order length mismatch");
        let mut seen = vec![false; self.n];
        for &i in order {
            assert!(i < self.n && !seen[i], "ring order is not a permutation of 0..n");
            seen[i] = true;
        }
        for j in 0..self.n {
            let cur = order[j];
            let nxt = order[(j + 1) % self.n];
            self.next[cur] = nxt;
            self.prev[nxt] = cur;
        }
        self.head = order[0];
    }

    /// Access order from least- to most-recently used (O(N); test/debug).
    pub fn order(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.n);
        let mut cur = self.head;
        for _ in 0..self.n {
            out.push(cur);
            cur = self.next[cur];
        }
        out
    }

    pub fn heap_bytes(&self) -> usize {
        (self.next.capacity() + self.prev.capacity()) * std::mem::size_of::<usize>()
    }
}

/// Dense time-discounted usage U⁽¹⁾ for DAM. O(N) per step.
#[derive(Debug, Clone)]
pub struct DiscountedUsage {
    pub u: Vec<f32>,
    pub lambda: f32,
}

impl DiscountedUsage {
    pub fn new(n: usize, lambda: f32) -> DiscountedUsage {
        DiscountedUsage { u: vec![0.0; n], lambda }
    }

    /// U ← λU + w^R + w^W (dense weights).
    pub fn update_dense(&mut self, read_w: &[f32], write_w: &[f32]) {
        for i in 0..self.u.len() {
            self.u[i] = self.lambda * self.u[i] + read_w[i] + write_w[i];
        }
    }

    /// Same with sparse weights (still decays all N entries).
    pub fn update_sparse(&mut self, read_w: &SparseVec, write_w: &SparseVec) {
        for v in self.u.iter_mut() {
            *v *= self.lambda;
        }
        for (i, w) in read_w.iter() {
            self.u[i] += w;
        }
        for (i, w) in write_w.iter() {
            self.u[i] += w;
        }
    }

    /// argmin U — the least-used word (𝕀ᵁ for DAM).
    pub fn argmin(&self) -> usize {
        let mut best = 0;
        let mut bv = f32::INFINITY;
        for (i, &v) in self.u.iter().enumerate() {
            if v < bv {
                bv = v;
                best = i;
            }
        }
        best
    }

    pub fn reset(&mut self) {
        self.u.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive reference: a Vec kept in LRA order, O(N) per op.
    struct NaiveLra {
        order: Vec<usize>,
    }

    impl NaiveLra {
        fn new(n: usize) -> Self {
            NaiveLra { order: (0..n).collect() }
        }
        fn lra(&self) -> usize {
            self.order[0]
        }
        fn touch(&mut self, i: usize) {
            self.order.retain(|&x| x != i);
            self.order.push(i);
        }
        fn pop_lra(&mut self) -> usize {
            let h = self.order.remove(0);
            self.order.push(h);
            h
        }
    }

    #[test]
    fn ring_matches_naive_reference_property() {
        for seed in 0..10u64 {
            let mut rng = Rng::new(seed);
            let n = 16;
            let mut ring = LraRing::new(n);
            let mut naive = NaiveLra::new(n);
            for _ in 0..500 {
                match rng.below(3) {
                    0 => {
                        let i = rng.below(n);
                        ring.touch(i);
                        naive.touch(i);
                    }
                    1 => {
                        assert_eq!(ring.pop_lra(), naive.pop_lra());
                    }
                    _ => {
                        assert_eq!(ring.lra(), naive.lra());
                    }
                }
                assert_eq!(ring.order(), naive.order, "seed {seed}");
            }
        }
    }

    #[test]
    fn ring_basics() {
        let mut ring = LraRing::new(4);
        assert_eq!(ring.lra(), 0);
        ring.touch(0); // 0 becomes most recent
        assert_eq!(ring.lra(), 1);
        assert_eq!(ring.pop_lra(), 1);
        assert_eq!(ring.lra(), 2);
        ring.touch(2);
        assert_eq!(ring.lra(), 3);
        ring.reset();
        assert_eq!(ring.order(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn set_order_round_trips_arbitrary_states() {
        for seed in 0..5u64 {
            let mut rng = Rng::new(seed);
            let n = 12;
            let mut ring = LraRing::new(n);
            for _ in 0..200 {
                match rng.below(2) {
                    0 => ring.touch(rng.below(n)),
                    _ => {
                        ring.pop_lra();
                    }
                }
            }
            let order = ring.order();
            let mut fresh = LraRing::new(n);
            fresh.set_order(&order);
            assert_eq!(fresh.order(), order, "seed {seed}");
            assert_eq!(fresh.lra(), ring.lra());
            // The restored ring must behave identically going forward.
            for _ in 0..50 {
                let i = rng.below(n);
                ring.touch(i);
                fresh.touch(i);
                assert_eq!(ring.pop_lra(), fresh.pop_lra());
            }
        }
    }

    #[test]
    fn touching_tail_is_noop() {
        let mut ring = LraRing::new(3);
        ring.touch(1);
        let before = ring.order();
        ring.touch(1); // 1 is already most recent
        assert_eq!(ring.order(), before);
    }

    #[test]
    fn discounted_usage_decays() {
        let mut u = DiscountedUsage::new(3, 0.5);
        u.update_dense(&[1.0, 0.0, 0.0], &[0.0, 0.0, 0.0]);
        u.update_dense(&[0.0, 1.0, 0.0], &[0.0, 0.0, 0.0]);
        // u = [0.5, 1.0, 0.0] -> argmin = 2
        assert_eq!(u.argmin(), 2);
        assert!((u.u[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn discounted_sparse_matches_dense() {
        let mut a = DiscountedUsage::new(8, 0.9);
        let mut b = DiscountedUsage::new(8, 0.9);
        let r = SparseVec::from_pairs(vec![(1, 0.5), (4, 0.5)]);
        let w = SparseVec::from_pairs(vec![(4, 1.0)]);
        for _ in 0..5 {
            a.update_dense(&r.to_dense(8), &w.to_dense(8));
            b.update_sparse(&r, &w);
        }
        for (x, y) in a.u.iter().zip(&b.u) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
