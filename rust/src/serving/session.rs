//! Multi-session lifecycle management for the serving runtime.
//!
//! A [`SessionManager`] owns the session table for one shared
//! [`InferModel`]: open / step / close, per-session RNG-derived memory
//! seeds, LRU eviction under a byte budget, and idle-session expiry. All
//! state sits behind one internal mutex, so any worker thread can serve
//! any session; the batched [`SessionManager::step_many`] is the
//! scheduler's tick entry and coalesces the controller math of every
//! distinct session in the tick into one GEMM per projection.
//!
//! **Durability** (`spill_dir` set): going over the byte budget *demotes*
//! the LRU session to a checksummed spill file instead of destroying it,
//! and a later step/reset of a spilled id transparently rehydrates it —
//! from the caller's perspective the session never went away. Idle expiry
//! demotes too. A cold restart calls
//! [`SessionManager::rehydrate_all`] to reload every surviving spill
//! file. When the disk is failing, sessions are **never** destroyed:
//! the victim stays resident, the failure is counted, and new opens are
//! shed with [`SessionError::Overloaded`] until a spill succeeds again.

use super::spill::{self, SpillMeta};
use super::{InferModel, Session};
use crate::cores::CtrlBatch;
use crate::util::metrics;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Suggested client backoff when opens are shed under overload.
pub const OVERLOAD_RETRY_MS: u64 = 1000;

/// Session-table policy knobs.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Total per-session state bytes to keep resident; the least-recently
    /// used sessions are evicted once the table exceeds this.
    pub byte_budget: usize,
    /// Sessions untouched for this long are dropped (or, with `spill_dir`
    /// set, demoted to disk) by [`SessionManager::expire_idle`].
    pub idle_expiry: Duration,
    /// Seed stream for per-session memory init.
    pub seed: u64,
    /// Demote-to-disk directory. `None` (the default) keeps the historical
    /// destroy-evict behavior; `Some(dir)` turns eviction and idle expiry
    /// into spills and makes spilled sessions step-transparent.
    pub spill_dir: Option<PathBuf>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            byte_budget: 1 << 30, // 1 GiB of episodic state
            idle_expiry: Duration::from_secs(300),
            seed: 0x5E55_1045,
            spill_dir: None,
        }
    }
}

struct Entry {
    state: Box<dyn Session>,
    /// Monotonic touch tick (LRU order) — cheaper and more testable than
    /// wall-clock ordering.
    last_touch: u64,
    /// Wall clock of the last touch (idle expiry).
    last_used: Instant,
    /// Cached `state.heap_bytes()`, refreshed whenever the session is
    /// touched, so the byte-budget check never walks every session.
    bytes: usize,
    /// The seed this session was opened with, recorded in its spill meta
    /// so rehydration re-opens a session with identical engine seeds.
    open_seed: Option<u64>,
}

struct Inner {
    sessions: HashMap<u64, Entry>,
    clock: u64,
    next_id: u64,
    rng: Rng,
    batch: CtrlBatch,
    /// Running Σ of the entries' cached `bytes` — kept exact at every
    /// insert/remove/touch so steps stay O(1) in the session count.
    state_bytes: usize,
    /// Sessions evicted by the byte budget since construction (stats).
    evicted: u64,
    /// Sessions dropped by idle expiry since construction (stats).
    expired: u64,
    /// Sessions demoted to disk (stats).
    spilled: u64,
    /// Sessions transparently reloaded from disk (stats).
    rehydrated: u64,
    /// Spill files dropped because CRC/shape validation failed (stats).
    corrupt_dropped: u64,
    /// Spill write attempts that failed (disk full, I/O error, ...).
    spill_failures: u64,
    /// The most recent spill attempt failed: shed new opens instead of
    /// destroying sessions until a spill succeeds again.
    spill_failing: bool,
}

impl Inner {
    fn insert(&mut self, id: u64, mut entry: Entry) {
        entry.bytes = entry.state.heap_bytes();
        self.state_bytes += entry.bytes;
        self.sessions.insert(id, entry);
        metrics::SESSIONS_OPEN.set(self.sessions.len() as u64);
    }

    fn remove(&mut self, id: u64) -> Option<Entry> {
        let e = self.sessions.remove(&id)?;
        self.state_bytes -= e.bytes;
        metrics::SESSIONS_OPEN.set(self.sessions.len() as u64);
        Some(e)
    }

    /// Evict least-recently-touched sessions until the cached total fits
    /// the budget. Sessions touched at the CURRENT clock tick are exempt —
    /// a step (or batched tick) must never evict a session it just served.
    ///
    /// With `spill` set, eviction is demotion: the victim is written to a
    /// checksummed spill file and only removed from the table once the
    /// atomic rename succeeded. A failed spill keeps the victim resident
    /// (over budget beats destroyed state), flags `spill_failing` so new
    /// opens shed, and stops — retried on the next budget check. Session
    /// types without spill support fall back to destroy-eviction.
    fn enforce_budget(&mut self, budget: usize, spill: Option<(&Path, &str)>) {
        while self.state_bytes > budget && self.sessions.len() > 1 {
            let clock = self.clock;
            let victim = self
                .sessions
                .iter()
                .filter(|(_, e)| e.last_touch < clock)
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(id, _)| *id);
            let Some(id) = victim else { return }; // all touched this tick
            if let Some((dir, model)) = spill {
                if !self.demote(id, dir, model) {
                    return;
                }
            } else {
                self.remove(id);
                self.evicted += 1;
                metrics::SESSIONS_EVICTED.inc();
            }
        }
    }

    /// Demote one session to disk. Returns false (leaving the session
    /// resident) iff the spill write failed.
    fn demote(&mut self, id: u64, dir: &Path, model: &str) -> bool {
        let entry = self.sessions.get_mut(&id).expect("demote of unknown session");
        let Some(snap) = spill::snapshot_session(entry.state.as_mut()) else {
            // This session type cannot spill: historical destroy-evict.
            self.remove(id);
            self.evicted += 1;
            metrics::SESSIONS_EVICTED.inc();
            return true;
        };
        let meta = SpillMeta { model: model.to_string(), open_seed: entry.open_seed };
        match spill::write_spill(&spill::spill_path(dir, id), &meta, &snap) {
            Ok(()) => {
                self.remove(id);
                self.spilled += 1;
                metrics::SESSIONS_SPILLED.inc();
                self.spill_failing = false;
                true
            }
            Err(_) => {
                self.spill_failures += 1;
                metrics::SESSIONS_SPILL_FAILURES.inc();
                self.spill_failing = true;
                false
            }
        }
    }
}

/// Errors a step can hit (string payloads keep the wire protocol simple).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// Unknown, closed, evicted or expired session id.
    NoSuchSession(u64),
    /// Input width did not match the model.
    BadInput { want: usize, got: usize },
    /// Shed under overload: the byte budget is exhausted and spilling is
    /// failing, so opening would destroy an existing session. Retryable.
    Overloaded { retry_after_ms: u64 },
    /// The batch scheduler is stopped or dead (shutdown or a tick panic).
    /// The session itself still exists — possibly spilled — so this is a
    /// retryable "server unavailable", distinct from `NoSuchSession`.
    SchedulerStopped,
}

impl SessionError {
    /// Whether the client should retry the identical request later.
    pub fn retryable(&self) -> bool {
        matches!(self, SessionError::Overloaded { .. } | SessionError::SchedulerStopped)
    }
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::NoSuchSession(id) => write!(f, "no such session {id}"),
            SessionError::BadInput { want, got } => {
                write!(f, "input has {got} dims, model wants {want}")
            }
            SessionError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded, retry in {retry_after_ms} ms")
            }
            SessionError::SchedulerStopped => {
                write!(f, "scheduler stopped, retry against a live server")
            }
        }
    }
}

/// The session table for one shared-weight model. Cloneable by `Arc`;
/// every method takes `&self`.
pub struct SessionManager {
    model: Arc<dyn InferModel>,
    cfg: SessionConfig,
    inner: Mutex<Inner>,
}

impl SessionManager {
    pub fn new(model: Arc<dyn InferModel>, cfg: SessionConfig) -> SessionManager {
        let rng = Rng::new(cfg.seed);
        SessionManager {
            model,
            cfg,
            inner: Mutex::new(Inner {
                sessions: HashMap::new(),
                clock: 0,
                next_id: 1,
                rng,
                batch: CtrlBatch::new(),
                state_bytes: 0,
                evicted: 0,
                expired: 0,
                spilled: 0,
                rehydrated: 0,
                corrupt_dropped: 0,
                spill_failures: 0,
                spill_failing: false,
            }),
        }
    }

    /// The shared model (one copy of the parameters, however many
    /// sessions exist).
    pub fn model(&self) -> &Arc<dyn InferModel> {
        &self.model
    }

    /// The demote-to-disk directory, when durability is on.
    pub fn spill_dir(&self) -> Option<&Path> {
        self.cfg.spill_dir.as_deref()
    }

    /// Spill target for the budget enforcer (`None` = destroy-evict mode).
    fn spill_opts(&self) -> Option<(&Path, &str)> {
        self.cfg.spill_dir.as_deref().map(|d| (d, self.model.name()))
    }

    /// Open a session with a manager-drawn per-session memory seed.
    pub fn open(&self) -> u64 {
        let seed = {
            let mut inner = self.inner.lock().unwrap();
            inner.rng.next_u64()
        };
        self.open_seeded(Some(seed))
    }

    /// Open a session with an explicit seed policy (`None` = the trained
    /// core's own seeds, the bit-parity default used by the tests).
    pub fn open_seeded(&self, seed: Option<u64>) -> u64 {
        metrics::SESSIONS_OPENED.inc();
        let state = self.model.open_session(seed);
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.clock += 1;
        let entry = Entry {
            state,
            last_touch: inner.clock,
            last_used: Instant::now(),
            bytes: 0,
            open_seed: seed,
        };
        inner.insert(id, entry);
        inner.enforce_budget(self.cfg.byte_budget, self.spill_opts());
        id
    }

    /// Overload-checked open for the serving front door: sheds with
    /// [`SessionError::Overloaded`] when the byte budget is exhausted AND
    /// spilling is failing — the one situation where admitting a session
    /// could only be paid for by destroying another one.
    pub fn open_checked(&self, seed: Option<u64>) -> Result<u64, SessionError> {
        self.check_overload()?;
        Ok(self.open_seeded(seed))
    }

    /// [`SessionManager::open`] (manager-drawn seed) with the same
    /// overload shedding as [`SessionManager::open_checked`].
    pub fn open_auto_checked(&self) -> Result<u64, SessionError> {
        self.check_overload()?;
        Ok(self.open())
    }

    fn check_overload(&self) -> Result<(), SessionError> {
        if self.cfg.spill_dir.is_none() {
            return Ok(()); // destroy-evict mode never sheds
        }
        let inner = self.inner.lock().unwrap();
        if inner.spill_failing && inner.state_bytes > self.cfg.byte_budget {
            return Err(SessionError::Overloaded { retry_after_ms: OVERLOAD_RETRY_MS });
        }
        Ok(())
    }

    /// Close a session; returns whether it existed (resident or spilled).
    /// Closing also deletes any spill file so a closed id can never
    /// rehydrate.
    pub fn close(&self, id: u64) -> bool {
        let resident = self.inner.lock().unwrap().remove(id).is_some();
        let on_disk = self
            .cfg
            .spill_dir
            .as_deref()
            .is_some_and(|d| std::fs::remove_file(spill::spill_path(d, id)).is_ok());
        resident || on_disk
    }

    /// Reload a spilled session under the table lock. Any validation
    /// failure (CRC, shape, model mismatch) deletes the file and counts a
    /// corrupt drop — a defective spill is never loaded and never retried.
    fn try_rehydrate(&self, inner: &mut Inner, id: u64) -> bool {
        let Some(dir) = self.cfg.spill_dir.as_deref() else { return false };
        let path = spill::spill_path(dir, id);
        if !path.exists() {
            return false;
        }
        let (meta, snap) = match spill::read_spill(&path) {
            Ok(ok) => ok,
            Err(_) => {
                let _ = std::fs::remove_file(&path);
                inner.corrupt_dropped += 1;
                metrics::SESSIONS_CORRUPT_DROPPED.inc();
                return false;
            }
        };
        if meta.model != self.model.name() {
            let _ = std::fs::remove_file(&path);
            inner.corrupt_dropped += 1;
            metrics::SESSIONS_CORRUPT_DROPPED.inc();
            return false;
        }
        // Re-opening with the recorded seed re-derives the engine seeds the
        // snapshot was captured under (import_state checks mem_seed).
        let mut state = self.model.open_session(meta.open_seed);
        if spill::restore_session(state.as_mut(), &snap).is_err() {
            let _ = std::fs::remove_file(&path);
            inner.corrupt_dropped += 1;
            metrics::SESSIONS_CORRUPT_DROPPED.inc();
            return false;
        }
        let _ = std::fs::remove_file(&path);
        inner.clock += 1;
        let entry = Entry {
            state,
            last_touch: inner.clock,
            last_used: Instant::now(),
            bytes: 0,
            open_seed: meta.open_seed,
        };
        inner.insert(id, entry);
        if inner.next_id <= id {
            inner.next_id = id + 1;
        }
        inner.rehydrated += 1;
        metrics::SESSIONS_REHYDRATED.inc();
        true
    }

    /// Cold-restart recovery: reload every surviving spill file in the
    /// configured directory. Returns (loaded, corrupt-dropped). Loading
    /// may exceed the byte budget; the next step's budget check demotes
    /// the LRU tail again rather than refusing recovery.
    pub fn rehydrate_all(&self) -> (usize, usize) {
        let Some(dir) = self.cfg.spill_dir.as_deref() else { return (0, 0) };
        let mut ids: Vec<u64> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                if let Some(id) = e.file_name().to_str().and_then(spill::parse_spill_id) {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let before_corrupt = inner.corrupt_dropped;
        let mut loaded = 0;
        for id in ids {
            if !inner.sessions.contains_key(&id) && self.try_rehydrate(inner, id) {
                loaded += 1;
            }
        }
        (loaded, (inner.corrupt_dropped - before_corrupt) as usize)
    }

    /// One forward step of one session. A spilled session rehydrates
    /// transparently — demotion is invisible to the caller.
    pub fn step(&self, id: u64, x: &[f32], y: &mut Vec<f32>) -> Result<(), SessionError> {
        if x.len() != self.model.x_dim() {
            return Err(SessionError::BadInput { want: self.model.x_dim(), got: x.len() });
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        inner.clock += 1;
        let clock = inner.clock;
        if !inner.sessions.contains_key(&id) && !self.try_rehydrate(inner, id) {
            return Err(SessionError::NoSuchSession(id));
        }
        let entry = inner.sessions.get_mut(&id).expect("session present after rehydrate");
        entry.last_touch = clock;
        let step_start = Instant::now();
        entry.last_used = step_start;
        self.model.step(entry.state.as_mut(), x, y);
        metrics::SERVE_STEPS.inc();
        metrics::SERVE_STEP_LATENCY_US.observe_since(step_start);
        debug_assert_eq!(entry.state.tape_bytes(), 0, "serving step grew a tape");
        let new_bytes = entry.state.heap_bytes();
        inner.state_bytes = inner.state_bytes - entry.bytes + new_bytes;
        entry.bytes = new_bytes;
        inner.enforce_budget(self.cfg.byte_budget, self.spill_opts());
        Ok(())
    }

    /// Reset a session's episode (memory + recurrent state to episode
    /// start) without closing it. Rehydrates a spilled session first.
    pub fn reset(&self, id: u64) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if !inner.sessions.contains_key(&id) && !self.try_rehydrate(inner, id) {
            return Err(SessionError::NoSuchSession(id));
        }
        let entry = inner.sessions.get_mut(&id).expect("session present after rehydrate");
        entry.state.reset();
        let new_bytes = entry.state.heap_bytes();
        inner.state_bytes = inner.state_bytes - entry.bytes + new_bytes;
        entry.bytes = new_bytes;
        Ok(())
    }

    /// The batched tick: step every request in `reqs`, coalescing all
    /// *distinct* sessions in the tick into one [`InferModel::step_batch`]
    /// call (one controller GEMM per projection). Requests that repeat a
    /// session id within one tick run in follow-up rounds, preserving
    /// arrival order per session. Each request's slot in `outs` receives
    /// the output or the error.
    pub fn step_many(
        &self,
        reqs: &[(u64, Vec<f32>)],
        outs: &mut Vec<Result<Vec<f32>, SessionError>>,
    ) {
        outs.clear();
        outs.resize(reqs.len(), Err(SessionError::NoSuchSession(0)));
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // One clock value for the whole tick: every served session is
        // equally recent, and none can be evicted by its own tick.
        inner.clock += 1;
        let tick_clock = inner.clock;
        let mut remaining: Vec<usize> = (0..reqs.len()).collect();
        // Width check up front so bad requests don't poison a round.
        remaining.retain(|&i| {
            if reqs[i].1.len() != self.model.x_dim() {
                outs[i] = Err(SessionError::BadInput {
                    want: self.model.x_dim(),
                    got: reqs[i].1.len(),
                });
                false
            } else {
                true
            }
        });
        let mut round: Vec<usize> = Vec::new();
        while !remaining.is_empty() {
            // Pop the first request per distinct session into this round.
            round.clear();
            let mut i = 0;
            while i < remaining.len() {
                let idx = remaining[i];
                let id = reqs[idx].0;
                if round.iter().any(|&r| reqs[r].0 == id) {
                    i += 1;
                } else {
                    round.push(idx);
                    remaining.remove(i);
                }
            }
            // Detach the round's sessions from the table so we can hold
            // simultaneous &muts (Box moves are cheap). A spilled id
            // rehydrates first, same as the single-step path.
            let mut taken: Vec<(usize, u64, Box<dyn Session>, Option<u64>)> =
                Vec::with_capacity(round.len());
            for &idx in &round {
                let id = reqs[idx].0;
                if !inner.sessions.contains_key(&id) {
                    self.try_rehydrate(inner, id);
                }
                match inner.remove(id) {
                    Some(entry) => taken.push((idx, id, entry.state, entry.open_seed)),
                    None => outs[idx] = Err(SessionError::NoSuchSession(id)),
                }
            }
            if !taken.is_empty() {
                let xs: Vec<&[f32]> =
                    taken.iter().map(|&(idx, _, _, _)| reqs[idx].1.as_slice()).collect();
                let mut ys: Vec<Vec<f32>> = taken.iter().map(|_| Vec::new()).collect();
                let round_start = Instant::now();
                {
                    let mut sessions: Vec<&mut dyn Session> =
                        taken.iter_mut().map(|(_, _, s, _)| s.as_mut()).collect();
                    self.model.step_batch(&mut sessions, &xs, &mut ys, &mut inner.batch);
                }
                // Each session in a coalesced round shares the round's
                // wall time — the per-session latency a client observes.
                let round_us = round_start.elapsed().as_micros() as u64;
                metrics::SERVE_STEPS.add(taken.len() as u64);
                for _ in 0..taken.len() {
                    metrics::SERVE_STEP_LATENCY_US.observe_us(round_us);
                }
                let now = Instant::now();
                for ((idx, id, state, open_seed), y) in taken.into_iter().zip(ys) {
                    outs[idx] = Ok(y);
                    inner.insert(
                        id,
                        Entry { state, last_touch: tick_clock, last_used: now, bytes: 0, open_seed },
                    );
                }
            }
        }
        inner.enforce_budget(self.cfg.byte_budget, self.spill_opts());
    }

    /// Drop sessions idle longer than the configured expiry (demote to
    /// disk instead when `spill_dir` is set); returns how many left the
    /// resident table. The server's accept loop calls this periodically.
    pub fn expire_idle(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let deadline = self.cfg.idle_expiry;
        let expired: Vec<u64> = inner
            .sessions
            .iter()
            .filter(|(_, e)| e.last_used.elapsed() > deadline)
            .map(|(id, _)| *id)
            .collect();
        let mut dropped = 0;
        for id in &expired {
            if let Some((dir, model)) = self.spill_opts() {
                // A failed spill keeps the session resident — idle state
                // is still user state.
                if inner.demote(*id, dir, model) {
                    dropped += 1;
                }
            } else {
                inner.remove(*id);
                dropped += 1;
            }
        }
        inner.expired += dropped as u64;
        metrics::SESSIONS_EXPIRED.add(dropped as u64);
        dropped
    }

    // -- accounting ---------------------------------------------------------

    pub fn session_count(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    /// Heap bytes of all per-session episodic state (params excluded).
    /// Served from the running total the budget checks maintain; pinned
    /// against a fresh per-session walk in the tests.
    pub fn state_heap_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        debug_assert_eq!(
            inner.state_bytes,
            inner.sessions.values().map(|e| e.bytes).sum::<usize>(),
            "cached state-byte total drifted"
        );
        inner.state_bytes
    }

    /// Heap bytes of the single shared parameter copy — constant in the
    /// session count by construction (asserted in rust/tests/serving.rs).
    pub fn params_heap_bytes(&self) -> usize {
        self.model.params_heap_bytes()
    }

    /// Total = one parameter copy + Σ session state + tick scratch; by
    /// construction exactly the sum of its parts.
    pub fn heap_bytes(&self) -> usize {
        self.params_heap_bytes() + self.state_heap_bytes() + self.batch_heap_bytes()
    }

    /// Gather/scatter scratch held by the batched tick.
    pub fn batch_heap_bytes(&self) -> usize {
        self.inner.lock().unwrap().batch.heap_bytes()
    }

    /// (evicted-by-budget, expired-by-idle) counters.
    pub fn eviction_stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.evicted, inner.expired)
    }

    /// (spilled, rehydrated, corrupt-dropped) durability counters.
    pub fn spill_stats(&self) -> (u64, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.spilled, inner.rehydrated, inner.corrupt_dropped)
    }

    /// Failed spill-write attempts (the overload-shedding signal).
    pub fn spill_failures(&self) -> u64 {
        self.inner.lock().unwrap().spill_failures
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::{CoreConfig, CoreKind};
    use crate::serving::build_infer_model;

    fn manager_with(budget: usize, spill_dir: Option<PathBuf>) -> SessionManager {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed: 7,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(7);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        SessionManager::new(
            model,
            SessionConfig { byte_budget: budget, spill_dir, ..SessionConfig::default() },
        )
    }

    fn manager(budget: usize) -> SessionManager {
        manager_with(budget, None)
    }

    #[test]
    fn open_step_close_lifecycle() {
        let mgr = manager(1 << 30);
        let id = mgr.open();
        let mut y = Vec::new();
        mgr.step(id, &[1.0, 0.0, 0.0, 1.0], &mut y).unwrap();
        assert_eq!(y.len(), 3);
        assert_eq!(
            mgr.step(id, &[1.0, 0.0], &mut y),
            Err(SessionError::BadInput { want: 4, got: 2 })
        );
        assert!(mgr.close(id));
        assert!(!mgr.close(id));
        assert_eq!(mgr.step(id, &[1.0, 0.0, 0.0, 1.0], &mut y), Err(SessionError::NoSuchSession(id)));
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        // A budget that fits exactly one fresh session: every open beyond
        // the first must evict the least-recently-touched session. (Fresh
        // sessions of the same config have identical heap footprints, so
        // the arithmetic is deterministic.)
        let probe_mgr = manager(1 << 30);
        probe_mgr.open();
        let one_session = probe_mgr.state_heap_bytes();
        let mgr = manager(one_session);
        let a = mgr.open();
        let b = mgr.open(); // two sessions exceed the budget → a (LRU) evicted
        assert_eq!(mgr.session_count(), 1);
        let mut y = Vec::new();
        assert_eq!(
            mgr.step(a, &[1.0, 0.0, 0.0, 1.0], &mut y),
            Err(SessionError::NoSuchSession(a)),
            "LRU session must have been evicted"
        );
        mgr.step(b, &[1.0, 0.0, 0.0, 1.0], &mut y).unwrap();
        // The just-touched session is never its own victim: b survives its
        // own step even if its pools grew past the budget.
        assert_eq!(mgr.session_count(), 1);
        assert_eq!(mgr.eviction_stats().0, 1);
    }

    #[test]
    fn spill_mode_demotes_and_rehydrates_transparently() {
        let dir = std::env::temp_dir()
            .join(format!("sam-session-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let probe = manager(1 << 30);
        probe.open();
        let one_session = probe.state_heap_bytes();

        let mgr = manager_with(one_session, Some(dir.clone()));
        let a = mgr.open_seeded(Some(11));
        let x = [1.0, 0.0, 0.0, 1.0];
        let mut y_a = Vec::new();
        mgr.step(a, &x, &mut y_a).unwrap();
        let _b = mgr.open_seeded(Some(12)); // over budget → a demoted to disk
        assert_eq!(mgr.session_count(), 1);
        assert_eq!(mgr.spill_stats(), (1, 0, 0));
        assert_eq!(mgr.eviction_stats().0, 0, "spill mode must not destroy-evict");
        assert!(spill::spill_path(&dir, a).exists());

        // Stepping the spilled id rehydrates transparently and matches the
        // never-evicted reference bitwise.
        let reference = manager(1 << 30);
        let a_ref = reference.open_seeded(Some(11));
        let mut y_ref = Vec::new();
        reference.step(a_ref, &x, &mut y_ref).unwrap();
        assert_eq!(y_a, y_ref);
        reference.step(a_ref, &x, &mut y_ref).unwrap();
        let mut y_a2 = Vec::new();
        mgr.step(a, &x, &mut y_a2).unwrap();
        assert_eq!(mgr.spill_stats().1, 1);
        assert_eq!(y_a2, y_ref, "rehydrated step must be bit-identical");

        // Closing a session also removes any spill file it left behind.
        mgr.step(a, &x, &mut y_a2).unwrap(); // keep a resident, b spilled
        assert!(mgr.close(_b));
        assert!(!spill::spill_path(&dir, _b).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn step_many_matches_per_session_round_order() {
        // Duplicate session ids inside one tick must run in arrival order.
        let mgr = manager(1 << 30);
        let a = mgr.open_seeded(Some(1));
        let b = mgr.open_seeded(Some(2));
        let x1 = vec![1.0, 0.0, 0.0, 0.0];
        let x2 = vec![0.0, 1.0, 0.0, 0.0];
        let reqs = vec![(a, x1.clone()), (b, x1.clone()), (a, x2.clone())];
        let mut outs = Vec::new();
        mgr.step_many(&reqs, &mut outs);
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.as_ref().unwrap().len(), 3);
        }
        // Reference: same seeds stepped through the batch path in the same
        // round structure.
        let mgr2 = manager(1 << 30);
        let a2 = mgr2.open_seeded(Some(1));
        let b2 = mgr2.open_seeded(Some(2));
        let mut outs2 = Vec::new();
        mgr2.step_many(&[(a2, x1.clone()), (b2, x1)], &mut outs2);
        let mut outs3 = Vec::new();
        mgr2.step_many(&[(a2, x2)], &mut outs3);
        assert_eq!(outs[0], outs2[0]);
        assert_eq!(outs[1], outs2[1]);
        assert_eq!(outs[2], outs3[0]);
    }

    #[test]
    fn idle_expiry_drops_sessions() {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 8,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(8);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let mgr = SessionManager::new(
            model,
            SessionConfig { idle_expiry: Duration::from_millis(0), ..SessionConfig::default() },
        );
        mgr.open();
        mgr.open();
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(mgr.expire_idle(), 2);
        assert_eq!(mgr.session_count(), 0);
        assert_eq!(mgr.eviction_stats().1, 2);
    }
}
