//! Multi-session lifecycle management for the serving runtime.
//!
//! A [`SessionManager`] owns the session table for one shared
//! [`InferModel`]: open / step / close, per-session RNG-derived memory
//! seeds, LRU eviction under a byte budget, and idle-session expiry. All
//! state sits behind one internal mutex, so any worker thread can serve
//! any session; the batched [`SessionManager::step_many`] is the
//! scheduler's tick entry and coalesces the controller math of every
//! distinct session in the tick into one GEMM per projection.

use super::{InferModel, Session};
use crate::cores::CtrlBatch;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Session-table policy knobs.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Total per-session state bytes to keep resident; the least-recently
    /// used sessions are evicted once the table exceeds this.
    pub byte_budget: usize,
    /// Sessions untouched for this long are dropped by
    /// [`SessionManager::expire_idle`].
    pub idle_expiry: Duration,
    /// Seed stream for per-session memory init.
    pub seed: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            byte_budget: 1 << 30, // 1 GiB of episodic state
            idle_expiry: Duration::from_secs(300),
            seed: 0x5E55_1045,
        }
    }
}

struct Entry {
    state: Box<dyn Session>,
    /// Monotonic touch tick (LRU order) — cheaper and more testable than
    /// wall-clock ordering.
    last_touch: u64,
    /// Wall clock of the last touch (idle expiry).
    last_used: Instant,
    /// Cached `state.heap_bytes()`, refreshed whenever the session is
    /// touched, so the byte-budget check never walks every session.
    bytes: usize,
}

struct Inner {
    sessions: HashMap<u64, Entry>,
    clock: u64,
    next_id: u64,
    rng: Rng,
    batch: CtrlBatch,
    /// Running Σ of the entries' cached `bytes` — kept exact at every
    /// insert/remove/touch so steps stay O(1) in the session count.
    state_bytes: usize,
    /// Sessions evicted by the byte budget since construction (stats).
    evicted: u64,
    /// Sessions dropped by idle expiry since construction (stats).
    expired: u64,
}

impl Inner {
    fn insert(&mut self, id: u64, mut entry: Entry) {
        entry.bytes = entry.state.heap_bytes();
        self.state_bytes += entry.bytes;
        self.sessions.insert(id, entry);
    }

    fn remove(&mut self, id: u64) -> Option<Entry> {
        let e = self.sessions.remove(&id)?;
        self.state_bytes -= e.bytes;
        Some(e)
    }

    /// Evict least-recently-touched sessions until the cached total fits
    /// the budget. Sessions touched at the CURRENT clock tick are exempt —
    /// a step (or batched tick) must never evict a session it just served.
    fn enforce_budget(&mut self, budget: usize) {
        while self.state_bytes > budget && self.sessions.len() > 1 {
            let clock = self.clock;
            let victim = self
                .sessions
                .iter()
                .filter(|(_, e)| e.last_touch < clock)
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    self.remove(id);
                    self.evicted += 1;
                }
                None => return, // everything live was touched this tick
            }
        }
    }
}

/// Errors a step can hit (string payloads keep the wire protocol simple).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// Unknown, closed, evicted or expired session id.
    NoSuchSession(u64),
    /// Input width did not match the model.
    BadInput { want: usize, got: usize },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::NoSuchSession(id) => write!(f, "no such session {id}"),
            SessionError::BadInput { want, got } => {
                write!(f, "input has {got} dims, model wants {want}")
            }
        }
    }
}

/// The session table for one shared-weight model. Cloneable by `Arc`;
/// every method takes `&self`.
pub struct SessionManager {
    model: Arc<dyn InferModel>,
    cfg: SessionConfig,
    inner: Mutex<Inner>,
}

impl SessionManager {
    pub fn new(model: Arc<dyn InferModel>, cfg: SessionConfig) -> SessionManager {
        let rng = Rng::new(cfg.seed);
        SessionManager {
            model,
            cfg,
            inner: Mutex::new(Inner {
                sessions: HashMap::new(),
                clock: 0,
                next_id: 1,
                rng,
                batch: CtrlBatch::new(),
                state_bytes: 0,
                evicted: 0,
                expired: 0,
            }),
        }
    }

    /// The shared model (one copy of the parameters, however many
    /// sessions exist).
    pub fn model(&self) -> &Arc<dyn InferModel> {
        &self.model
    }

    /// Open a session with a manager-drawn per-session memory seed.
    pub fn open(&self) -> u64 {
        let seed = {
            let mut inner = self.inner.lock().unwrap();
            inner.rng.next_u64()
        };
        self.open_seeded(Some(seed))
    }

    /// Open a session with an explicit seed policy (`None` = the trained
    /// core's own seeds, the bit-parity default used by the tests).
    pub fn open_seeded(&self, seed: Option<u64>) -> u64 {
        let state = self.model.open_session(seed);
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.clock += 1;
        let entry =
            Entry { state, last_touch: inner.clock, last_used: Instant::now(), bytes: 0 };
        inner.insert(id, entry);
        inner.enforce_budget(self.cfg.byte_budget);
        id
    }

    /// Close a session; returns whether it existed.
    pub fn close(&self, id: u64) -> bool {
        self.inner.lock().unwrap().remove(id).is_some()
    }

    /// One forward step of one session.
    pub fn step(&self, id: u64, x: &[f32], y: &mut Vec<f32>) -> Result<(), SessionError> {
        if x.len() != self.model.x_dim() {
            return Err(SessionError::BadInput { want: self.model.x_dim(), got: x.len() });
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        inner.clock += 1;
        let clock = inner.clock;
        let entry = inner.sessions.get_mut(&id).ok_or(SessionError::NoSuchSession(id))?;
        entry.last_touch = clock;
        entry.last_used = Instant::now();
        self.model.step(entry.state.as_mut(), x, y);
        debug_assert_eq!(entry.state.tape_bytes(), 0, "serving step grew a tape");
        let new_bytes = entry.state.heap_bytes();
        inner.state_bytes = inner.state_bytes - entry.bytes + new_bytes;
        entry.bytes = new_bytes;
        inner.enforce_budget(self.cfg.byte_budget);
        Ok(())
    }

    /// Reset a session's episode (memory + recurrent state to episode
    /// start) without closing it.
    pub fn reset(&self, id: u64) -> Result<(), SessionError> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let entry = inner.sessions.get_mut(&id).ok_or(SessionError::NoSuchSession(id))?;
        entry.state.reset();
        let new_bytes = entry.state.heap_bytes();
        inner.state_bytes = inner.state_bytes - entry.bytes + new_bytes;
        entry.bytes = new_bytes;
        Ok(())
    }

    /// The batched tick: step every request in `reqs`, coalescing all
    /// *distinct* sessions in the tick into one [`InferModel::step_batch`]
    /// call (one controller GEMM per projection). Requests that repeat a
    /// session id within one tick run in follow-up rounds, preserving
    /// arrival order per session. Each request's slot in `outs` receives
    /// the output or the error.
    pub fn step_many(
        &self,
        reqs: &[(u64, Vec<f32>)],
        outs: &mut Vec<Result<Vec<f32>, SessionError>>,
    ) {
        outs.clear();
        outs.resize(reqs.len(), Err(SessionError::NoSuchSession(0)));
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // One clock value for the whole tick: every served session is
        // equally recent, and none can be evicted by its own tick.
        inner.clock += 1;
        let tick_clock = inner.clock;
        let mut remaining: Vec<usize> = (0..reqs.len()).collect();
        // Width check up front so bad requests don't poison a round.
        remaining.retain(|&i| {
            if reqs[i].1.len() != self.model.x_dim() {
                outs[i] = Err(SessionError::BadInput {
                    want: self.model.x_dim(),
                    got: reqs[i].1.len(),
                });
                false
            } else {
                true
            }
        });
        let mut round: Vec<usize> = Vec::new();
        while !remaining.is_empty() {
            // Pop the first request per distinct session into this round.
            round.clear();
            let mut i = 0;
            while i < remaining.len() {
                let idx = remaining[i];
                let id = reqs[idx].0;
                if round.iter().any(|&r| reqs[r].0 == id) {
                    i += 1;
                } else {
                    round.push(idx);
                    remaining.remove(i);
                }
            }
            // Detach the round's sessions from the table so we can hold
            // simultaneous &muts (Box moves are cheap).
            let mut taken: Vec<(usize, u64, Box<dyn Session>)> = Vec::with_capacity(round.len());
            for &idx in &round {
                let id = reqs[idx].0;
                match inner.remove(id) {
                    Some(entry) => taken.push((idx, id, entry.state)),
                    None => outs[idx] = Err(SessionError::NoSuchSession(id)),
                }
            }
            if !taken.is_empty() {
                let xs: Vec<&[f32]> = taken.iter().map(|&(idx, _, _)| reqs[idx].1.as_slice()).collect();
                let mut ys: Vec<Vec<f32>> = taken.iter().map(|_| Vec::new()).collect();
                {
                    let mut sessions: Vec<&mut dyn Session> =
                        taken.iter_mut().map(|(_, _, s)| s.as_mut()).collect();
                    self.model.step_batch(&mut sessions, &xs, &mut ys, &mut inner.batch);
                }
                let now = Instant::now();
                for ((idx, id, state), y) in taken.into_iter().zip(ys) {
                    outs[idx] = Ok(y);
                    inner.insert(
                        id,
                        Entry { state, last_touch: tick_clock, last_used: now, bytes: 0 },
                    );
                }
            }
        }
        inner.enforce_budget(self.cfg.byte_budget);
    }

    /// Drop sessions idle longer than the configured expiry; returns how
    /// many were dropped. The server's accept loop calls this periodically.
    pub fn expire_idle(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let deadline = self.cfg.idle_expiry;
        let expired: Vec<u64> = inner
            .sessions
            .iter()
            .filter(|(_, e)| e.last_used.elapsed() > deadline)
            .map(|(id, _)| *id)
            .collect();
        for id in &expired {
            inner.remove(*id);
        }
        inner.expired += expired.len() as u64;
        expired.len()
    }

    // -- accounting ---------------------------------------------------------

    pub fn session_count(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    /// Heap bytes of all per-session episodic state (params excluded).
    /// Served from the running total the budget checks maintain; pinned
    /// against a fresh per-session walk in the tests.
    pub fn state_heap_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        debug_assert_eq!(
            inner.state_bytes,
            inner.sessions.values().map(|e| e.bytes).sum::<usize>(),
            "cached state-byte total drifted"
        );
        inner.state_bytes
    }

    /// Heap bytes of the single shared parameter copy — constant in the
    /// session count by construction (asserted in rust/tests/serving.rs).
    pub fn params_heap_bytes(&self) -> usize {
        self.model.params_heap_bytes()
    }

    /// Total = one parameter copy + Σ session state + tick scratch; by
    /// construction exactly the sum of its parts.
    pub fn heap_bytes(&self) -> usize {
        self.params_heap_bytes() + self.state_heap_bytes() + self.batch_heap_bytes()
    }

    /// Gather/scatter scratch held by the batched tick.
    pub fn batch_heap_bytes(&self) -> usize {
        self.inner.lock().unwrap().batch.heap_bytes()
    }

    /// (evicted-by-budget, expired-by-idle) counters.
    pub fn eviction_stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.evicted, inner.expired)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::{CoreConfig, CoreKind};
    use crate::serving::build_infer_model;

    fn manager(budget: usize) -> SessionManager {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed: 7,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(7);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        SessionManager::new(
            model,
            SessionConfig { byte_budget: budget, ..SessionConfig::default() },
        )
    }

    #[test]
    fn open_step_close_lifecycle() {
        let mgr = manager(1 << 30);
        let id = mgr.open();
        let mut y = Vec::new();
        mgr.step(id, &[1.0, 0.0, 0.0, 1.0], &mut y).unwrap();
        assert_eq!(y.len(), 3);
        assert_eq!(
            mgr.step(id, &[1.0, 0.0], &mut y),
            Err(SessionError::BadInput { want: 4, got: 2 })
        );
        assert!(mgr.close(id));
        assert!(!mgr.close(id));
        assert_eq!(mgr.step(id, &[1.0, 0.0, 0.0, 1.0], &mut y), Err(SessionError::NoSuchSession(id)));
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        // A budget that fits exactly one fresh session: every open beyond
        // the first must evict the least-recently-touched session. (Fresh
        // sessions of the same config have identical heap footprints, so
        // the arithmetic is deterministic.)
        let probe_mgr = manager(1 << 30);
        probe_mgr.open();
        let one_session = probe_mgr.state_heap_bytes();
        let mgr = manager(one_session);
        let a = mgr.open();
        let b = mgr.open(); // two sessions exceed the budget → a (LRU) evicted
        assert_eq!(mgr.session_count(), 1);
        let mut y = Vec::new();
        assert_eq!(
            mgr.step(a, &[1.0, 0.0, 0.0, 1.0], &mut y),
            Err(SessionError::NoSuchSession(a)),
            "LRU session must have been evicted"
        );
        mgr.step(b, &[1.0, 0.0, 0.0, 1.0], &mut y).unwrap();
        // The just-touched session is never its own victim: b survives its
        // own step even if its pools grew past the budget.
        assert_eq!(mgr.session_count(), 1);
        assert_eq!(mgr.eviction_stats().0, 1);
    }

    #[test]
    fn step_many_matches_per_session_round_order() {
        // Duplicate session ids inside one tick must run in arrival order.
        let mgr = manager(1 << 30);
        let a = mgr.open_seeded(Some(1));
        let b = mgr.open_seeded(Some(2));
        let x1 = vec![1.0, 0.0, 0.0, 0.0];
        let x2 = vec![0.0, 1.0, 0.0, 0.0];
        let reqs = vec![(a, x1.clone()), (b, x1.clone()), (a, x2.clone())];
        let mut outs = Vec::new();
        mgr.step_many(&reqs, &mut outs);
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.as_ref().unwrap().len(), 3);
        }
        // Reference: same seeds stepped through the batch path in the same
        // round structure.
        let mgr2 = manager(1 << 30);
        let a2 = mgr2.open_seeded(Some(1));
        let b2 = mgr2.open_seeded(Some(2));
        let mut outs2 = Vec::new();
        mgr2.step_many(&[(a2, x1.clone()), (b2, x1)], &mut outs2);
        let mut outs3 = Vec::new();
        mgr2.step_many(&[(a2, x2)], &mut outs3);
        assert_eq!(outs[0], outs2[0]);
        assert_eq!(outs[1], outs2[1]);
        assert_eq!(outs[2], outs3[0]);
    }

    #[test]
    fn idle_expiry_drops_sessions() {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 1,
            word: 6,
            mem_words: 8,
            seed: 8,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(8);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let mgr = SessionManager::new(
            model,
            SessionConfig { idle_expiry: Duration::from_millis(0), ..SessionConfig::default() },
        );
        mgr.open();
        mgr.open();
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(mgr.expire_idle(), 2);
        assert_eq!(mgr.session_count(), 0);
        assert_eq!(mgr.eviction_stats().1, 2);
    }
}
