//! The batched step scheduler: coalesces concurrent sessions' steps into
//! one [`crate::serving::SessionManager::step_many`] tick.
//!
//! Worker threads don't step the model directly — they
//! [`submit`](BatchScheduler::submit) `(session, input)` and block on the
//! reply. A dedicated scheduler thread drains the inbox every tick
//! (`tick` long, or immediately once `max_batch` requests are waiting) and
//! runs the whole tick through the manager, so the controller GEMMs of
//! every concurrent session coalesce (see `cores::infer_tick`). Under a
//! single client the added latency is bounded by one tick; under load the
//! tick fills and batching is free.

use super::session::{SessionError, SessionManager};
use crate::util::metrics;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Pending {
    id: u64,
    x: Vec<f32>,
    reply: Sender<Result<Vec<f32>, SessionError>>,
    /// Submit time, for the queue-latency histogram (observed at drain).
    enqueued: Instant,
}

struct Shared {
    inbox: Mutex<Vec<Pending>>,
    cv: Condvar,
    stop: AtomicBool,
}

/// Handle to the scheduler thread. Cheap to clone via `Arc`; dropping the
/// last handle does NOT stop the thread — call [`BatchScheduler::stop`].
pub struct BatchScheduler {
    shared: Arc<Shared>,
    mgr: Arc<SessionManager>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Spawn the scheduler thread. `tick` bounds the coalescing wait;
    /// `max_batch` triggers an early tick when enough requests queue up.
    pub fn start(mgr: Arc<SessionManager>, tick: Duration, max_batch: usize) -> BatchScheduler {
        let shared = Arc::new(Shared {
            inbox: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let thread = {
            let shared = shared.clone();
            let mgr = mgr.clone();
            std::thread::spawn(move || {
                // A panic inside a tick must not wedge the server: without
                // this, queued senders would sit in the inbox forever and
                // every later step_blocking would block on recv. Flag the
                // scheduler dead and drain with errors instead.
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    Self::run(&shared, &mgr, tick, max_batch)
                }));
                shared.stop.store(true, Ordering::SeqCst);
                for p in shared.inbox.lock().unwrap().drain(..) {
                    let _ = p.reply.send(Err(SessionError::SchedulerStopped));
                }
                if run.is_err() {
                    eprintln!("batch scheduler thread panicked; serving steps now error");
                }
            })
        };
        BatchScheduler { shared, mgr, thread: Mutex::new(Some(thread)) }
    }

    /// The manager this scheduler ticks.
    pub fn manager(&self) -> &Arc<SessionManager> {
        &self.mgr
    }

    /// Enqueue one step and block until its tick completes. A stopped or
    /// dead scheduler reports [`SessionError::SchedulerStopped`] — a
    /// retryable "server unavailable", NOT `NoSuchSession`: the session
    /// still exists (possibly spilled) and a client that retries against a
    /// restarted server will find it.
    pub fn step_blocking(&self, id: u64, x: Vec<f32>) -> Result<Vec<f32>, SessionError> {
        if self.shared.stop.load(Ordering::SeqCst) {
            return Err(SessionError::SchedulerStopped);
        }
        let (tx, rx) = channel();
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.push(Pending { id, x, reply: tx, enqueued: Instant::now() });
            self.shared.cv.notify_one();
        }
        // Re-check after publishing: if the scheduler died between our
        // first check and the push, its final drain may have missed us —
        // drain the inbox ourselves so nobody (including us) hangs.
        if self.shared.stop.load(Ordering::SeqCst) {
            for p in self.shared.inbox.lock().unwrap().drain(..) {
                let _ = p.reply.send(Err(SessionError::SchedulerStopped));
            }
        }
        // A dropped reply (scheduler stopped mid-request) also reads as
        // scheduler death rather than a panic.
        rx.recv().unwrap_or(Err(SessionError::SchedulerStopped))
    }

    /// Stop the scheduler thread and drain outstanding requests with
    /// errors. Idempotent.
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }

    fn run(shared: &Shared, mgr: &SessionManager, tick: Duration, max_batch: usize) {
        let mut reqs: Vec<(u64, Vec<f32>)> = Vec::new();
        let mut replies: Vec<Sender<Result<Vec<f32>, SessionError>>> = Vec::new();
        let mut outs: Vec<Result<Vec<f32>, SessionError>> = Vec::new();
        loop {
            // Wait for work (or stop).
            let mut inbox = shared.inbox.lock().unwrap();
            while inbox.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                let (guard, _) = shared.cv.wait_timeout(inbox, Duration::from_millis(50)).unwrap();
                inbox = guard;
            }
            if shared.stop.load(Ordering::SeqCst) {
                // Drain with errors so blocked callers wake.
                for p in inbox.drain(..) {
                    let _ = p.reply.send(Err(SessionError::SchedulerStopped));
                }
                return;
            }
            // Coalesce: give other submitters one tick to join, unless the
            // batch is already full.
            if inbox.len() < max_batch {
                let (guard, _) = shared.cv.wait_timeout(inbox, tick).unwrap();
                inbox = guard;
                // stop() may have fired during the coalescing wait (its
                // notify_all is exactly what ends it early). Without this
                // re-check the tick would proceed into step_many on a
                // manager that stop()'s caller already considers torn
                // down — drain with errors instead, like the check above.
                if shared.stop.load(Ordering::SeqCst) {
                    for p in inbox.drain(..) {
                        let _ = p.reply.send(Err(SessionError::SchedulerStopped));
                    }
                    return;
                }
            }
            reqs.clear();
            replies.clear();
            let n = inbox.len().min(max_batch);
            let now = Instant::now();
            for p in inbox.drain(..n) {
                metrics::SERVE_QUEUE_LATENCY_US
                    .observe_us(now.saturating_duration_since(p.enqueued).as_micros() as u64);
                reqs.push((p.id, p.x));
                replies.push(p.reply);
            }
            drop(inbox);
            metrics::SERVE_TICKS.inc();
            metrics::SERVE_TICK_REQUESTS.add(n as u64);
            metrics::SERVE_TICK_FILL_PERMILLE.set((n as u64 * 1000) / max_batch.max(1) as u64);
            // Fault-injection point for the crash-recovery tests: a worker
            // panic here exercises the catch_unwind + drain path above.
            if crate::util::fault::fire("sched.tick").is_some() {
                panic!("injected scheduler panic at sched.tick");
            }
            mgr.step_many(&reqs, &mut outs);
            for (reply, out) in replies.drain(..).zip(outs.drain(..)) {
                // Receiver may have given up; ignore.
                let _ = reply.send(out);
            }
        }
    }
}

impl Drop for BatchScheduler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;
    use crate::cores::{CoreConfig, CoreKind};
    use crate::serving::session::SessionConfig;
    use crate::serving::build_infer_model;
    use crate::util::rng::Rng;

    fn scheduler() -> BatchScheduler {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let mgr = Arc::new(SessionManager::new(model, SessionConfig::default()));
        BatchScheduler::start(mgr, Duration::from_micros(200), 64)
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let sched = Arc::new(scheduler());
        let ids: Vec<u64> = (0..6).map(|i| sched.manager().open_seeded(Some(i))).collect();
        let mut handles = Vec::new();
        for &id in &ids {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let mut last = Vec::new();
                for t in 0..10 {
                    let x = vec![(t % 2) as f32, 1.0, 0.0, 0.0];
                    last = sched.step_blocking(id, x).expect("step failed");
                }
                last
            }));
        }
        for h in handles {
            let y = h.join().unwrap();
            assert_eq!(y.len(), 3);
            assert!(y.iter().all(|v| v.is_finite()));
        }
        sched.stop();
    }

    #[test]
    fn scheduled_steps_match_direct_batched_steps() {
        // One client stream through the scheduler equals the same stream
        // through step_many directly (both take the padded batch path).
        let sched = scheduler();
        let id = sched.manager().open_seeded(Some(42));
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|t| vec![t as f32 * 0.1, 1.0 - t as f32 * 0.1, 0.5, 0.0])
            .collect();
        let via_sched: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| sched.step_blocking(id, x.clone()).unwrap())
            .collect();
        sched.stop();
        let direct = scheduler();
        let id2 = direct.manager().open_seeded(Some(42));
        let mut outs = Vec::new();
        for (t, x) in xs.iter().enumerate() {
            direct.manager().step_many(&[(id2, x.clone())], &mut outs);
            let y = outs[0].as_ref().unwrap();
            for (a, b) in via_sched[t].iter().zip(y) {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t}");
            }
        }
        direct.stop();
    }

    #[test]
    fn stop_unblocks_pending_requests() {
        let sched = Arc::new(scheduler());
        // A request for a session that never existed still gets a reply.
        let r = sched.step_blocking(999, vec![0.0; 4]);
        assert!(r.is_err());
        sched.stop();
        sched.stop(); // idempotent
    }

    #[test]
    fn stopped_scheduler_reports_scheduler_stopped_not_no_such_session() {
        // Regression: a stopped/dead scheduler used to answer
        // NoSuchSession — which the server renders as a *non-retryable*
        // error for a session that still exists. It must be the distinct,
        // retryable SchedulerStopped.
        let sched = scheduler();
        let id = sched.manager().open_seeded(Some(7));
        sched.step_blocking(id, vec![0.0; 4]).expect("live step works");
        sched.stop();
        let r = sched.step_blocking(id, vec![0.0; 4]);
        assert_eq!(r.unwrap_err(), SessionError::SchedulerStopped);
        assert!(SessionError::SchedulerStopped.retryable());
    }

    /// Regression for the coalescing-wait stop race: `run` used to skip
    /// the stop re-check after its `wait_timeout(tick)`, so a tick racing
    /// `stop()` would still call `step_many` on a tearing-down manager.
    /// With a tick long enough that stop() always lands inside the
    /// coalescing wait, the request must come back SchedulerStopped and
    /// the session must never be stepped.
    #[test]
    fn stop_during_coalescing_wait_drains_without_stepping() {
        let cfg = CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
        let mgr = Arc::new(SessionManager::new(model, SessionConfig::default()));
        // Huge coalescing tick + max_batch 64: a single request parks the
        // scheduler in the coalescing wait for 10 s unless stop() ends it.
        let sched = Arc::new(BatchScheduler::start(mgr, Duration::from_secs(10), 64));
        let id = sched.manager().open_seeded(Some(3));
        let stepper = {
            let sched = sched.clone();
            std::thread::spawn(move || sched.step_blocking(id, vec![0.0; 4]))
        };
        // Let the request reach the inbox and the scheduler enter the
        // coalescing wait, then stop. Generous sleep: the assertion below
        // is driven by the reply, not this timing.
        std::thread::sleep(Duration::from_millis(100));
        let t = Instant::now();
        sched.stop();
        let r = stepper.join().unwrap();
        assert_eq!(r.unwrap_err(), SessionError::SchedulerStopped);
        // stop() must not have waited out the 10 s coalescing tick.
        assert!(t.elapsed() < Duration::from_secs(5), "stop() waited out the tick");
        // The drained request never reached the manager: the session's
        // step counter is untouched (steps bump last_step time; cheapest
        // observable: a fresh step via step_many works and is step 0's
        // deterministic output — compare against an identical manager).
        let mut outs = Vec::new();
        sched.manager().step_many(&[(id, vec![0.0; 4])], &mut outs);
        let stepped = outs[0].as_ref().expect("session still exists").clone();
        let mut rng2 = Rng::new(9);
        let model2 = build_infer_model(CoreKind::Sam, &cfg, &mut rng2, None);
        let mgr2 = SessionManager::new(model2, SessionConfig::default());
        let id2 = mgr2.open_seeded(Some(3));
        let mut outs2 = Vec::new();
        mgr2.step_many(&[(id2, vec![0.0; 4])], &mut outs2);
        let fresh = outs2[0].as_ref().unwrap();
        for (a, b) in stepped.iter().zip(fresh) {
            assert_eq!(a.to_bits(), b.to_bits(), "drained request must not have stepped");
        }
    }

}
