//! The shared-weight inference runtime (the deployment story of §1: sparse
//! access makes very large memories *cheap enough to serve*).
//!
//! Training couples parameters and episodic state inside one `Box<dyn
//! Core>`; serving splits them. An [`InferModel`] is a trained core used
//! read-only — one copy of the parameters behind an `Arc`, shareable
//! across every worker thread — and a [`Session`] is the detachable
//! per-user episodic state (controller h/c, a private memory store + ANN +
//! usage ring, recurrent read vectors). Forward-only stepping skips the
//! StepJournal, the tape buffers and the carried memory gradient entirely:
//! a serving step allocates nothing in steady state and `tape_bytes()`
//! stays 0 (rust/tests/zero_alloc.rs, rust/tests/serving.rs).
//!
//! ```text
//!   Arc<dyn InferModel>  (one copy of trained weights)
//!        │  step / step_batch (&self — read-only)
//!        ▼
//!   Session #1   Session #2   …   Session #N     (per-user memory + h/c)
//! ```
//!
//! [`SessionManager`](session::SessionManager) owns the session table
//! (create/step/close, LRU eviction under a byte budget, idle expiry);
//! [`BatchScheduler`](scheduler::BatchScheduler) coalesces concurrent
//! sessions' steps into one controller GEMM per tick via
//! [`crate::cores::infer_tick`]. The TCP protocol lives in
//! `coordinator::server`.
//!
//! Sessions inherit the model's `CoreConfig::shards` (the `sam serve
//! --shards` flag): each session's private memory stripes across S
//! shards with the parallel fan-out query, bit-identical to S=1 for the
//! Linear index (rust/tests/shard_parity.rs pins this end-to-end through
//! the SessionManager).

pub mod scheduler;
pub mod session;
pub mod spill;

pub use scheduler::BatchScheduler;
pub use session::{SessionConfig, SessionError, SessionManager};
pub use spill::{SessionSnapshot, SpillDirReport, SpillMeta};

use crate::cores::dam::{DamCore, DamSession};
use crate::cores::dnc::{DncCore, DncSession};
use crate::cores::lstm_core::{LstmCore, LstmSession};
use crate::cores::ntm::{NtmCore, NtmSession};
use crate::cores::sam::{SamCore, SamSession};
use crate::cores::sdnc::{SdncCore, SdncSession};
use crate::cores::{Core, CoreConfig, CoreKind, CtrlBatch};
use crate::nn::param::HasParams;
use crate::util::rng::Rng;
use std::any::Any;
use std::sync::Arc;

/// Detachable per-session episodic state: everything an infer step
/// mutates. Parameters are deliberately absent — they live in the shared
/// [`InferModel`], which is what makes thousand-session serving hold
/// exactly one copy of the weights.
pub trait Session: Send {
    /// Downcast hook; each model steps only its own session type.
    fn as_any(&mut self) -> &mut dyn Any;

    /// Heap bytes held by this session (memory store dominates; parameters
    /// excluded by construction).
    fn heap_bytes(&self) -> usize;

    /// BPTT tape bytes — 0 by construction in infer mode; asserted while
    /// serving.
    fn tape_bytes(&self) -> usize;

    /// Start a new episode: memory back to its seeded init, recurrent
    /// state zeroed.
    fn reset(&mut self);
}

/// A trained model served read-only: `&self` everywhere, `Send + Sync`, so
/// one `Arc<dyn InferModel>` drives any number of sessions from any number
/// of threads.
pub trait InferModel: Send + Sync {
    fn name(&self) -> &'static str;
    fn x_dim(&self) -> usize;
    fn y_dim(&self) -> usize;

    /// Heap bytes of the trained parameters (the single shared copy).
    fn params_heap_bytes(&self) -> usize;

    /// Parameter scalar count.
    fn params_len(&self) -> usize;

    /// Open a fresh session. `seed: None` reuses the trained core's own
    /// memory-init seeds (bit-parity with train-mode forwards); `Some(s)`
    /// derives per-session init noise from `s`.
    fn open_session(&self, seed: Option<u64>) -> Box<dyn Session>;

    /// One forward-only step. Panics if handed a session this model did
    /// not open (wrong concrete type).
    fn step(&self, session: &mut dyn Session, x: &[f32], y: &mut Vec<f32>);

    /// One batched serving tick: implementations coalesce all sessions'
    /// controller projections into one GEMM each ([`crate::cores::infer_tick`]).
    /// The default serves models without a batched path by stepping each
    /// session in order.
    fn step_batch(
        &self,
        sessions: &mut [&mut dyn Session],
        xs: &[&[f32]],
        ys: &mut [Vec<f32>],
        _batch: &mut CtrlBatch,
    ) {
        for ((s, x), y) in sessions.iter_mut().zip(xs).zip(ys.iter_mut()) {
            self.step(&mut **s, x, y);
        }
    }
}

/// Glue: implement [`Session`] + the [`InferModel`] delegation for a
/// (core, session) pair whose inherent methods follow the shared shape.
macro_rules! impl_infer_model {
    ($core:ty, $session:ty, $label:expr) => {
        impl Session for $session {
            fn as_any(&mut self) -> &mut dyn Any {
                self
            }
            fn heap_bytes(&self) -> usize {
                <$session>::heap_bytes(self)
            }
            fn tape_bytes(&self) -> usize {
                <$session>::tape_bytes(self)
            }
            fn reset(&mut self) {
                <$session>::reset(self)
            }
        }

        impl InferModel for $core {
            fn name(&self) -> &'static str {
                Core::name(self)
            }
            fn x_dim(&self) -> usize {
                Core::x_dim(self)
            }
            fn y_dim(&self) -> usize {
                Core::y_dim(self)
            }
            fn params_heap_bytes(&self) -> usize {
                <$core>::params_heap_bytes(self)
            }
            fn params_len(&self) -> usize {
                <$core>::params_len(self)
            }
            fn open_session(&self, seed: Option<u64>) -> Box<dyn Session> {
                Box::new(self.infer_session(seed))
            }
            fn step(&self, session: &mut dyn Session, x: &[f32], y: &mut Vec<f32>) {
                let st = session
                    .as_any()
                    .downcast_mut::<$session>()
                    .unwrap_or_else(|| panic!("{} model handed a foreign session", $label));
                self.infer_step(st, x, y);
            }
        }
    };
}

impl_infer_model!(LstmCore, LstmSession, "lstm");
impl_infer_model!(NtmCore, NtmSession, "ntm");
impl_infer_model!(DncCore, DncSession, "dnc");

/// The three engine-backed cores override `step_batch` with the real
/// coalesced-GEMM tick; the macro only covers the default-loop models, so
/// these expand the body by hand.
macro_rules! impl_infer_model_batched {
    ($core:ty, $session:ty, $label:expr) => {
        impl Session for $session {
            fn as_any(&mut self) -> &mut dyn Any {
                self
            }
            fn heap_bytes(&self) -> usize {
                <$session>::heap_bytes(self)
            }
            fn tape_bytes(&self) -> usize {
                <$session>::tape_bytes(self)
            }
            fn reset(&mut self) {
                <$session>::reset(self)
            }
        }

        impl InferModel for $core {
            fn name(&self) -> &'static str {
                Core::name(self)
            }
            fn x_dim(&self) -> usize {
                Core::x_dim(self)
            }
            fn y_dim(&self) -> usize {
                Core::y_dim(self)
            }
            fn params_heap_bytes(&self) -> usize {
                <$core>::params_heap_bytes(self)
            }
            fn params_len(&self) -> usize {
                <$core>::params_len(self)
            }
            fn open_session(&self, seed: Option<u64>) -> Box<dyn Session> {
                Box::new(self.infer_session(seed))
            }
            fn step(&self, session: &mut dyn Session, x: &[f32], y: &mut Vec<f32>) {
                let st = session
                    .as_any()
                    .downcast_mut::<$session>()
                    .unwrap_or_else(|| panic!("{} model handed a foreign session", $label));
                self.infer_step(st, x, y);
            }
            fn step_batch(
                &self,
                sessions: &mut [&mut dyn Session],
                xs: &[&[f32]],
                ys: &mut [Vec<f32>],
                batch: &mut CtrlBatch,
            ) {
                let mut states: Vec<&mut $session> = sessions
                    .iter_mut()
                    .map(|s| {
                        s.as_any()
                            .downcast_mut::<$session>()
                            .unwrap_or_else(|| panic!("{} model handed a foreign session", $label))
                    })
                    .collect();
                self.infer_step_batch(batch, &mut states, xs, ys);
            }
        }
    };
}

impl_infer_model_batched!(SamCore, SamSession, "sam");
impl_infer_model_batched!(SdncCore, SdncSession, "sdnc");
impl_infer_model_batched!(DamCore, DamSession, "dam");

/// Build a shared-weight inference model of the requested kind. `params`,
/// when given, overwrites the fresh init with checkpointed values
/// (`HasParams::load_values` layout — see `coordinator::read_checkpoint`),
/// so the server serves trained weights rather than an RNG init.
pub fn build_infer_model(
    kind: CoreKind,
    cfg: &CoreConfig,
    rng: &mut Rng,
    params: Option<&[f32]>,
) -> Arc<dyn InferModel> {
    macro_rules! build {
        ($core:ty) => {{
            let mut core = <$core>::new(cfg, rng);
            if let Some(p) = params {
                core.load_values(p);
            }
            Arc::new(core) as Arc<dyn InferModel>
        }};
    }
    match kind {
        CoreKind::Lstm => build!(LstmCore),
        CoreKind::Ntm => build!(NtmCore),
        CoreKind::Dam => build!(DamCore),
        CoreKind::Sam => build!(SamCore),
        CoreKind::Dnc => build!(DncCore),
        CoreKind::Sdnc => build!(SdncCore),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::AnnKind;

    fn small_cfg() -> CoreConfig {
        CoreConfig {
            x_dim: 4,
            y_dim: 3,
            hidden: 8,
            heads: 2,
            word: 6,
            mem_words: 16,
            k: 3,
            ann: AnnKind::Linear,
            seed: 5,
            ..CoreConfig::default()
        }
    }

    #[test]
    fn every_core_kind_builds_an_infer_model() {
        for kind in CoreKind::all() {
            let mut rng = Rng::new(5);
            let model = build_infer_model(kind, &small_cfg(), &mut rng, None);
            let mut s = model.open_session(Some(1));
            let mut y = Vec::new();
            model.step(s.as_mut(), &[1.0, 0.0, 0.0, 1.0], &mut y);
            assert_eq!(y.len(), 3, "{kind:?}");
            assert!(y.iter().all(|v| v.is_finite()), "{kind:?}");
            assert_eq!(s.tape_bytes(), 0, "{kind:?} must serve with zero tape");
            assert!(s.heap_bytes() > 0);
            s.reset();
        }
    }

    #[test]
    fn checkpoint_params_are_applied() {
        let mut rng = Rng::new(6);
        let cfg = small_cfg();
        let mut core = SamCore::new(&cfg, &mut rng);
        let flat = core.save_values();
        let zeros = vec![0.0f32; flat.len()];
        let mut rng2 = Rng::new(6);
        let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng2, Some(&zeros));
        assert_eq!(model.params_len(), flat.len());
        // All-zero params ⇒ all-zero output (bias init is zero).
        let mut s = model.open_session(None);
        let mut y = Vec::new();
        model.step(s.as_mut(), &[1.0, 0.0, 0.0, 1.0], &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
