//! Versioned, checksummed session spill files — the durability layer under
//! [`SessionManager`](crate::serving::session::SessionManager).
//!
//! A spill file captures everything a [`SamSession`] step mutates: the
//! decoded memory rows (plus per-row Int8 dequant scales so compact
//! storage bits re-encode exactly), the LRA ring order, the controller's
//! LSTM h/c, and the recurrent read state (`w_read_prev`, `r_prev`).
//! Restoring replays the engine's own reinit discipline — set each row,
//! re-sync its ANN slot, restore the ring — so a rehydrated session is
//! bit-identical to the never-evicted one for ann=linear (kd/LSH/HNSW
//! rebuild deterministically from the same rows and seeds but may order
//! equal-score ties differently; see DESIGN.md).
//!
//! ## On-disk layout (version 1)
//!
//! ```text
//! magic  b"SAMSPILL"                       (8 bytes)
//! version u32 LE                           (4 bytes)
//! record* : tag u32 | len u64 | payload[len] | crc32(payload) u32   (all LE)
//! ```
//!
//! Tags: 1=META (JSON), 2=ROWS (f32), 3=SCALES (f32), 4=RING (u64),
//! 5=LSTM_H (f32), 6=LSTM_C (f32), 7=WREAD (per-head sparse pairs),
//! 8=RPREV (per-head f32 vectors), 9=END (empty). Every record carries its
//! own CRC32 (IEEE, hand-rolled table — the build is offline) and the
//! reader requires the full tag set terminated by END, so a torn tail, a
//! flipped byte or a truncated file is *detected and refused*, never
//! silently loaded. Writers stage the entire file in memory, write it to
//! `<name>.tmp`, fsync, then atomically rename — a crash mid-spill leaves
//! either the old complete file or an ignorable `.tmp`, and a
//! non-atomic-filesystem torn write still trips the CRC/END checks.
//!
//! u64 seeds are serialized as decimal strings inside the JSON meta (the
//! hand-rolled JSON holds numbers as f64, which cannot round-trip u64).

use crate::cores::sam::SamSession;
use crate::serving::Session;
use crate::tensor::rowcodec::RowFormat;
use crate::util::fault::{self, FaultKind};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write as _;
use std::path::{Path, PathBuf};

pub const MAGIC: &[u8; 8] = b"SAMSPILL";
pub const VERSION: u32 = 1;

const TAG_META: u32 = 1;
const TAG_ROWS: u32 = 2;
const TAG_SCALES: u32 = 3;
const TAG_RING: u32 = 4;
const TAG_LSTM_H: u32 = 5;
const TAG_LSTM_C: u32 = 6;
const TAG_WREAD: u32 = 7;
const TAG_RPREV: u32 = 8;
const TAG_END: u32 = 9;

// -- CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) --------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 of `data` (matches zlib's `crc32(0, ...)`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// -- snapshot ----------------------------------------------------------------

/// Everything a SAM serving step mutates, decoded to plain vectors. Built
/// by [`SamSession::export_state`], consumed by
/// [`SamSession::import_state`] and the codec below.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// Memory rows (global order).
    pub n: usize,
    /// Word width W.
    pub word: usize,
    /// Storage codec of the live store (restore target must match).
    pub row_format: RowFormat,
    /// The session engine's memory-init seed — a consistency check that a
    /// spill is restored into a session deriving identical init rows.
    pub mem_seed: u64,
    /// Decoded memory rows, n×word, global row order.
    pub rows: Vec<f32>,
    /// Per-row Int8 dequant scales (all 1.0 outside Int8), length n.
    pub scales: Vec<f32>,
    /// LRA ring order, least- to most-recently used, a permutation of 0..n.
    pub ring_order: Vec<usize>,
    /// Controller LSTM hidden state.
    pub h: Vec<f32>,
    /// Controller LSTM cell state.
    pub c: Vec<f32>,
    /// Previous read weights per head (sparse index/value pairs).
    pub w_read_prev: Vec<Vec<(usize, f32)>>,
    /// Previous read vectors per head, each of length `word`.
    pub r_prev: Vec<Vec<f32>>,
}

impl SessionSnapshot {
    pub fn heads(&self) -> usize {
        self.w_read_prev.len()
    }
}

/// Identity half of a spill file: which model and open-seed the session
/// belongs to, so a cold restart can re-open an equivalent session before
/// importing state.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillMeta {
    /// `InferModel::name()` of the owning model ("sam", ...).
    pub model: String,
    /// The seed the session was opened with (`None` = the model's own
    /// parity seeds). Re-opening with the same value re-derives identical
    /// engine seeds, which import_state verifies via `mem_seed`.
    pub open_seed: Option<u64>,
}

// -- downcast seam -----------------------------------------------------------

/// Capture a spillable snapshot from a type-erased session, or `None` if
/// this session type has no spill support (the manager falls back to
/// destroy-eviction for those).
pub fn snapshot_session(state: &mut dyn Session) -> Option<SessionSnapshot> {
    state.as_any().downcast_mut::<SamSession>().map(|s| s.export_state())
}

/// Restore a snapshot into a freshly opened session of the same model.
pub fn restore_session(state: &mut dyn Session, snap: &SessionSnapshot) -> Result<()> {
    let s = state
        .as_any()
        .downcast_mut::<SamSession>()
        .ok_or_else(|| anyhow!("spill restore: session type does not support spill"))?;
    s.import_state(snap)
}

// -- paths -------------------------------------------------------------------

/// Spill file path for session `id`: `<dir>/sess-<id>.spill`.
pub fn spill_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("sess-{id}.spill"))
}

/// Parse a session id back out of a spill file name.
pub fn parse_spill_id(file_name: &str) -> Option<u64> {
    file_name.strip_prefix("sess-")?.strip_suffix(".spill")?.parse().ok()
}

// -- encode ------------------------------------------------------------------

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(buf: &mut Vec<u8>, vals: &[f32]) {
    for &x in vals {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_record(buf: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    push_u32(buf, tag);
    push_u64(buf, payload.len() as u64);
    buf.extend_from_slice(payload);
    push_u32(buf, crc32(payload));
}

/// Serialize a complete spill file into memory (staged, so the on-disk
/// write is a single write_all + fsync + rename).
pub fn encode_spill(meta: &SpillMeta, snap: &SessionSnapshot) -> Vec<u8> {
    let mut header = vec![("model", Json::str(meta.model.clone()))];
    if let Some(s) = meta.open_seed {
        header.push(("open_seed", Json::str(format!("{s}"))));
    }
    header.push(("n", Json::num(snap.n as f64)));
    header.push(("word", Json::num(snap.word as f64)));
    header.push(("heads", Json::num(snap.heads() as f64)));
    header.push(("row_format", Json::str(snap.row_format.name())));
    header.push(("mem_seed", Json::str(format!("{}", snap.mem_seed))));
    let meta_json = Json::obj(header).encode();

    let mut buf = Vec::with_capacity(64 + snap.rows.len() * 4 + snap.n * 12);
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    push_record(&mut buf, TAG_META, meta_json.as_bytes());

    let mut payload = Vec::with_capacity(snap.rows.len() * 4);
    push_f32s(&mut payload, &snap.rows);
    push_record(&mut buf, TAG_ROWS, &payload);

    payload.clear();
    push_f32s(&mut payload, &snap.scales);
    push_record(&mut buf, TAG_SCALES, &payload);

    payload.clear();
    for &i in &snap.ring_order {
        push_u64(&mut payload, i as u64);
    }
    push_record(&mut buf, TAG_RING, &payload);

    payload.clear();
    push_f32s(&mut payload, &snap.h);
    push_record(&mut buf, TAG_LSTM_H, &payload);

    payload.clear();
    push_f32s(&mut payload, &snap.c);
    push_record(&mut buf, TAG_LSTM_C, &payload);

    payload.clear();
    for head in &snap.w_read_prev {
        push_u64(&mut payload, head.len() as u64);
        for &(i, v) in head {
            push_u64(&mut payload, i as u64);
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    push_record(&mut buf, TAG_WREAD, &payload);

    payload.clear();
    for r in &snap.r_prev {
        push_u64(&mut payload, r.len() as u64);
        push_f32s(&mut payload, r);
    }
    push_record(&mut buf, TAG_RPREV, &payload);

    push_record(&mut buf, TAG_END, &[]);
    buf
}

/// Write a spill file atomically: stage to `<path>.tmp`, fsync, rename.
///
/// Fault-injection points (`fault-inject` feature only): `spill.write`
/// (IoError fails the staging write; ShortWrite truncates the staged bytes
/// *and still renames*, simulating a non-atomic filesystem tearing the
/// file so the reader's CRC/END checks are exercised) and `spill.rename`
/// (IoError fails after staging, leaving an ignorable `.tmp`).
pub fn write_spill(path: &Path, meta: &SpillMeta, snap: &SessionSnapshot) -> std::io::Result<()> {
    let buf = encode_spill(meta, snap);
    let cut = match fault::check_io("spill.write")? {
        Some(FaultKind::ShortWrite) => buf.len() / 2,
        _ => buf.len(),
    };
    let tmp = path.with_extension("spill.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf[..cut])?;
        f.sync_all()?;
    }
    if let Err(e) = fault::check_io("spill.rename") {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
}

// -- decode ------------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            bail!("truncated spill file ({} bytes short)", n - (self.b.len() - self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn f32s(payload: &[u8]) -> Result<Vec<f32>> {
    if payload.len() % 4 != 0 {
        bail!("f32 record length {} not a multiple of 4", payload.len());
    }
    Ok(payload.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn meta_u64(meta: &Json, key: &str) -> Result<u64> {
    meta.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("spill meta missing {key}"))?
        .parse()
        .map_err(|_| anyhow!("spill meta {key} is not a u64"))
}

fn meta_usize(meta: &Json, key: &str) -> Result<usize> {
    let v = meta
        .get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("spill meta missing {key}"))?;
    Ok(v as usize)
}

fn row_format_from_name(name: &str) -> Result<RowFormat> {
    match name {
        "f32" => Ok(RowFormat::F32),
        "bf16" => Ok(RowFormat::Bf16),
        "int8" => Ok(RowFormat::Int8),
        other => bail!("unknown row format in spill meta: {other:?}"),
    }
}

/// Decode and fully validate a spill image. Any defect — bad magic, bad
/// version, CRC mismatch, missing record, truncation, shape inconsistency
/// — is an error; a partially valid file is never returned.
pub fn decode_spill(bytes: &[u8]) -> Result<(SpillMeta, SessionSnapshot)> {
    let mut cur = Cursor { b: bytes, i: 0 };
    if cur.take(8)? != MAGIC {
        bail!("bad spill magic (not a spill file)");
    }
    let version = cur.u32()?;
    if version != VERSION {
        bail!("unsupported spill version {version} (want {VERSION})");
    }

    let mut records: Vec<(u32, &[u8])> = Vec::new();
    let mut saw_end = false;
    while cur.i < bytes.len() {
        let tag = cur.u32()?;
        let len = cur.u64()? as usize;
        let payload = cur.take(len).with_context(|| format!("record tag {tag}"))?;
        let crc = cur.u32()?;
        if crc != crc32(payload) {
            bail!("CRC mismatch in record tag {tag} (torn or corrupted spill)");
        }
        if tag == TAG_END {
            saw_end = true;
            break;
        }
        records.push((tag, payload));
    }
    if !saw_end {
        bail!("spill file has no END record (torn write)");
    }

    let get = |tag: u32| -> Result<&[u8]> {
        records
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, p)| *p)
            .ok_or_else(|| anyhow!("spill file missing record tag {tag}"))
    };

    let meta_json = std::str::from_utf8(get(TAG_META)?).context("spill meta is not UTF-8")?;
    let meta = Json::parse(meta_json).map_err(|e| anyhow!("spill meta parse: {e}"))?;
    let model = meta
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("spill meta missing model"))?
        .to_string();
    let open_seed = match meta.get("open_seed") {
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| anyhow!("spill meta open_seed is not a string"))?
                .parse::<u64>()
                .map_err(|_| anyhow!("spill meta open_seed is not a u64"))?,
        ),
        None => None,
    };
    let n = meta_usize(&meta, "n")?;
    let word = meta_usize(&meta, "word")?;
    let heads = meta_usize(&meta, "heads")?;
    let row_format = row_format_from_name(
        meta.get("row_format")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("spill meta missing row_format"))?,
    )?;
    let mem_seed = meta_u64(&meta, "mem_seed")?;

    let rows = f32s(get(TAG_ROWS)?)?;
    if rows.len() != n * word {
        bail!("spill rows length {} != n*word {}", rows.len(), n * word);
    }
    let scales = f32s(get(TAG_SCALES)?)?;
    if scales.len() != n {
        bail!("spill scales length {} != n {}", scales.len(), n);
    }

    let ring_bytes = get(TAG_RING)?;
    if ring_bytes.len() != n * 8 {
        bail!("spill ring length {} != n*8 {}", ring_bytes.len(), n * 8);
    }
    let ring_order: Vec<usize> = ring_bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    let mut seen = vec![false; n];
    for &i in &ring_order {
        if i >= n || seen[i] {
            bail!("spill ring order is not a permutation of 0..{n}");
        }
        seen[i] = true;
    }

    let h = f32s(get(TAG_LSTM_H)?)?;
    let c = f32s(get(TAG_LSTM_C)?)?;
    if h.len() != c.len() {
        bail!("spill LSTM h/c length mismatch ({} vs {})", h.len(), c.len());
    }

    let wread_bytes = get(TAG_WREAD)?;
    let mut wc = Cursor { b: wread_bytes, i: 0 };
    let mut w_read_prev = Vec::with_capacity(heads);
    for _ in 0..heads {
        let cnt = wc.u64().context("spill w_read_prev head count")? as usize;
        if cnt > n {
            bail!("spill w_read_prev head has {cnt} entries for {n} rows");
        }
        let mut head: Vec<(usize, f32)> = Vec::with_capacity(cnt);
        for _ in 0..cnt {
            let idx = wc.u64()? as usize;
            let val = f32::from_le_bytes(wc.take(4)?.try_into().unwrap());
            if idx >= n {
                bail!("spill w_read_prev index {idx} out of range (n={n})");
            }
            // SparseVec indices are strictly ascending by contract.
            if head.last().is_some_and(|&(last, _)| last >= idx) {
                bail!("spill w_read_prev indices out of order");
            }
            head.push((idx, val));
        }
        w_read_prev.push(head);
    }
    if wc.i != wread_bytes.len() {
        bail!("spill w_read_prev record has trailing bytes");
    }

    let rprev_bytes = get(TAG_RPREV)?;
    let mut rc = Cursor { b: rprev_bytes, i: 0 };
    let mut r_prev = Vec::with_capacity(heads);
    for _ in 0..heads {
        let len = rc.u64().context("spill r_prev head length")? as usize;
        if len != word {
            bail!("spill r_prev head length {len} != word {word}");
        }
        r_prev.push(f32s(rc.take(len * 4)?)?);
    }
    if rc.i != rprev_bytes.len() {
        bail!("spill r_prev record has trailing bytes");
    }

    Ok((
        SpillMeta { model, open_seed },
        SessionSnapshot {
            n,
            word,
            row_format,
            mem_seed,
            rows,
            scales,
            ring_order,
            h,
            c,
            w_read_prev,
            r_prev,
        },
    ))
}

/// Read and validate a spill file. Fault-injection point: `spill.read`
/// (IoError).
pub fn read_spill(path: &Path) -> Result<(SpillMeta, SessionSnapshot)> {
    fault::check_io("spill.read").map_err(|e| anyhow!("{e}"))?;
    let bytes =
        std::fs::read(path).with_context(|| format!("reading spill {}", path.display()))?;
    decode_spill(&bytes).with_context(|| format!("decoding spill {}", path.display()))
}

// -- directory audit ---------------------------------------------------------

/// What a spill directory holds — `sam info --spill-dir` and the cold
/// restart both scan with this.
#[derive(Debug, Default, Clone)]
pub struct SpillDirReport {
    /// Session ids of spill files that decoded and validated cleanly.
    pub ids: Vec<u64>,
    /// Total bytes across recognized spill files (valid + corrupt).
    pub bytes: u64,
    /// Files matching the spill naming scheme that failed validation.
    pub corrupt: usize,
}

impl SpillDirReport {
    pub fn files(&self) -> usize {
        self.ids.len() + self.corrupt
    }
}

/// Scan `dir` for `sess-*.spill` files and validate each one. Stale
/// `*.tmp` staging files (a crash mid-spill) and unrelated files are
/// ignored. A missing directory reads as empty.
pub fn scan_dir(dir: &Path) -> SpillDirReport {
    let mut report = SpillDirReport::default();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return report,
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(id) = parse_spill_id(name) else { continue };
        let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
        report.bytes += len;
        match read_spill(&entry.path()) {
            Ok(_) => report.ids.push(id),
            Err(_) => report.corrupt += 1,
        }
    }
    report.ids.sort_unstable();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> SessionSnapshot {
        SessionSnapshot {
            n: 4,
            word: 3,
            row_format: RowFormat::F32,
            mem_seed: 0xDEAD_BEEF_CAFE_F00D,
            rows: (0..12).map(|i| i as f32 * 0.25 - 1.0).collect(),
            scales: vec![1.0; 4],
            ring_order: vec![2, 0, 3, 1],
            h: vec![0.5, -0.5],
            c: vec![1.5, -1.5],
            w_read_prev: vec![vec![(1, 0.75), (3, 0.25)], vec![]],
            r_prev: vec![vec![0.1, 0.2, 0.3], vec![0.0, 0.0, 0.0]],
        }
    }

    fn sample_meta() -> SpillMeta {
        SpillMeta { model: "sam".into(), open_seed: Some(u64::MAX - 7) }
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn encode_decode_round_trip() {
        let (meta, snap) = (sample_meta(), sample_snapshot());
        let bytes = encode_spill(&meta, &snap);
        let (m2, s2) = decode_spill(&bytes).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(s2, snap);
    }

    #[test]
    fn u64_seeds_survive_json_meta() {
        // f64 JSON numbers cannot hold u64::MAX-7; the string encoding must.
        let (meta, mut snap) = (sample_meta(), sample_snapshot());
        snap.mem_seed = u64::MAX;
        let (m2, s2) = decode_spill(&encode_spill(&meta, &snap)).unwrap();
        assert_eq!(m2.open_seed, Some(u64::MAX - 7));
        assert_eq!(s2.mem_seed, u64::MAX);
    }

    #[test]
    fn every_truncation_is_detected() {
        let bytes = encode_spill(&sample_meta(), &sample_snapshot());
        for cut in 0..bytes.len() {
            assert!(
                decode_spill(&bytes[..cut]).is_err(),
                "truncation to {cut}/{} bytes went undetected",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let bytes = encode_spill(&sample_meta(), &sample_snapshot());
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            // A flip must either fail decode or (never) silently change
            // contents; CRC-per-record means it always fails.
            assert!(decode_spill(&bad).is_err(), "byte flip at {i} went undetected");
        }
    }

    #[test]
    fn wrong_version_and_magic_rejected() {
        let mut bytes = encode_spill(&sample_meta(), &sample_snapshot());
        bytes[0] = b'X';
        assert!(decode_spill(&bytes).is_err());
        let mut bytes = encode_spill(&sample_meta(), &sample_snapshot());
        bytes[8] = 0xFF; // version
        assert!(decode_spill(&bytes).is_err());
    }

    #[test]
    fn atomic_write_then_scan() {
        let dir = std::env::temp_dir().join(format!("sam-spill-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (meta, snap) = (sample_meta(), sample_snapshot());
        write_spill(&spill_path(&dir, 7), &meta, &snap).unwrap();
        write_spill(&spill_path(&dir, 9), &meta, &snap).unwrap();
        // A corrupt file and an orphaned .tmp must be counted / ignored.
        std::fs::write(spill_path(&dir, 11), b"SAMSPILLgarbage").unwrap();
        std::fs::write(dir.join("sess-5.spill.tmp"), b"partial").unwrap();
        let report = scan_dir(&dir);
        assert_eq!(report.ids, vec![7, 9]);
        assert_eq!(report.corrupt, 1);
        assert_eq!(report.files(), 3);
        assert!(report.bytes > 0);
        let (m2, s2) = read_spill(&spill_path(&dir, 7)).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(s2, snap);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spill_id_naming_round_trips() {
        assert_eq!(parse_spill_id("sess-42.spill"), Some(42));
        assert_eq!(parse_spill_id("sess-42.spill.tmp"), None);
        assert_eq!(parse_spill_id("other.spill"), None);
        let p = spill_path(Path::new("/tmp/x"), 42);
        assert_eq!(parse_spill_id(p.file_name().unwrap().to_str().unwrap()), Some(42));
    }
}
