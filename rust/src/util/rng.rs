//! Deterministic PRNG used everywhere in the library.
//!
//! The offline build environment has no `rand` crate, so we ship our own
//! xoshiro256** generator (Blackman & Vigna) seeded through SplitMix64.
//! All experiment entry points take an explicit seed so every result in
//! EXPERIMENTS.md is reproducible bit-for-bit.

/// xoshiro256** PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box-Muller.
    spare_normal: Option<f32>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    /// Derive an independent child generator (for per-worker / per-task streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> f32 in [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift is fine for our n << 2^32.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Random boolean with probability `p` of being true.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Standard normal sample (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Rejection sampling is cheap when k << n.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0f64;
        let n = 20_000;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 40_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.normal() as f64;
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        // Every value in a small range should be hit.
        let mut hits = [false; 8];
        for _ in 0..1000 {
            hits[r.below(8)] = true;
        }
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (50, 20)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
