//! Tiny CLI flag parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, bare `--flag` booleans, and
//! positional arguments. Every experiment binary declares its flags with
//! defaults and gets `--help` text for free.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Flags present without a value (`--verbose`).
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.options.contains_key(switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default; panics with a clear message on parse failure.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse::<T>()
                .unwrap_or_else(|e| panic!("bad value for --{key}: {v:?} ({e:?})")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get_or(key, default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get_or(key, default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("train --lr 0.001 --steps=500 --verbose --model sam");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("lr"), Some("0.001"));
        assert_eq!(a.usize_or("steps", 0), 500);
        assert!(a.has("verbose"));
        assert_eq!(a.str_or("model", "x"), "sam");
        assert_eq!(a.str_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn trailing_switch() {
        let a = parse("--fast");
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("");
        assert_eq!(a.f32_or("lr", 1e-4), 1e-4);
        assert_eq!(a.u64_or("seed", 42), 42);
    }

    #[test]
    #[should_panic(expected = "bad value for --n")]
    fn bad_value_panics() {
        let a = parse("--n abc");
        let _ = a.usize_or("n", 0);
    }
}
