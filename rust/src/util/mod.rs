//! Infrastructure substrates built from scratch (the offline build image has
//! no rand/serde/clap/criterion): PRNG, JSON, CLI args, allocator counters,
//! timers.
pub mod alloc;
pub mod args;
pub mod json;
pub mod rng;
pub mod timer;
