//! Infrastructure substrates built from scratch (the offline build image has
//! no rand/serde/clap/criterion): PRNG, JSON, CLI args, allocator counters,
//! timers.
pub mod alloc;
pub mod args;
pub mod fault;
pub mod json;
pub mod metrics;
pub mod pool;
pub mod rng;
pub mod timer;

/// Shard-count override for shard-sensitive test suites: CI's
/// `SAM_TEST_SHARDS=4` matrix leg re-runs them at that S in addition to
/// their built-in shard sets (see rust/tests/shard_parity.rs).
pub fn env_shards() -> Option<usize> {
    std::env::var("SAM_TEST_SHARDS").ok().and_then(|v| v.parse().ok()).filter(|&s| s >= 1)
}

/// Batch-lane override for batch-sensitive test suites: CI's
/// `SAM_TEST_BATCH=4` matrix leg re-runs them at that B in addition to
/// their built-in lane sets (see rust/tests/batch_parity.rs).
pub fn env_batch() -> Option<usize> {
    std::env::var("SAM_TEST_BATCH").ok().and_then(|v| v.parse().ok()).filter(|&b| b >= 1)
}
