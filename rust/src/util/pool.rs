//! A persistent worker pool for deterministic same-shape fan-out — the
//! thread substrate behind [`crate::memory::sharded::ShardedMemoryEngine`]'s
//! parallel ANN query.
//!
//! `std::thread::scope` would spawn and join OS threads on every call,
//! which at a few tens of microseconds per spawn swamps the win of
//! splitting a single memory-query step. [`ShardPool`] keeps its workers
//! alive for the process lifetime and hands them *claimable task batches*
//! instead of closures:
//!
//! * A batch is an index range `0..total` plus type-erased pointers to the
//!   task storage; workers (and the dispatching caller itself) claim task
//!   indices with a CAS loop, so a batch completes even if every worker is
//!   busy elsewhere — the caller never blocks on pool availability.
//! * Dispatch order never affects results: callers get back per-task
//!   output slots, written disjointly. Determinism is the *caller's*
//!   merge-rule job (see the sharded engine's rank merge); the pool only
//!   guarantees every task ran exactly once and completed before
//!   [`ShardPool::run2`] returns.
//! * Steady-state dispatch performs **zero heap allocations** on the
//!   calling thread: the batch object is a thread-local `Arc` allocated
//!   once per calling thread and reused, and the queue is a `VecDeque`
//!   whose capacity converges (asserted in rust/tests/zero_alloc.rs).
//!
//! Safety model: `run2` borrows two equal-length `&mut` slices and a
//! shared context. Workers only touch `a[i]`/`b[i]` for indices they
//! claimed; a claim is a CAS on a single `(epoch << 32) | next` word whose
//! success proves the claimed index was validated against the *current*
//! epoch's task count (the epoch bumps on every open and close, so the
//! word is strictly increasing and a stale bound can never pass the CAS —
//! see [`Batch`]); and the caller does not return until `done == total`,
//! so the borrows outlive every access. Late queue entries from a
//! previous dispatch observe the closed sentinel, or legitimately help
//! the current dispatch of the same thread-local batch — never stale
//! pointers: pointers are republished *before* the epoch opens, all
//! `SeqCst`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// `state` low-word sentinel marking the batch closed (no claimable work).
const CLOSED: u64 = u32::MAX as u64;

/// One dispatch's shared claim state. Reused across dispatches from the
/// same calling thread (thread-local), kept alive by the `Arc`s the queue
/// and workers hold.
///
/// The claim word packs `(epoch << 32) | next` into ONE atomic. The epoch
/// bumps on every open *and* every close (owner-thread-only writes), so
/// the state value is strictly increasing and a successful CAS on it
/// proves the state did not change between a worker's bound check and its
/// claim — closing the stale-`total` race where a preempted worker holds
/// an old bound across a dispatch boundary and claims an out-of-range
/// index of a newer, smaller dispatch. (Epoch wrap needs 2^32 dispatches
/// from one thread AND an exact state collision at the wrap point —
/// beyond any realistic session.)
struct Batch {
    /// `(epoch << 32) | next`; low word is [`CLOSED`] between dispatches.
    state: AtomicU64,
    /// Completed task count; `done == total` unblocks the caller.
    done: AtomicUsize,
    /// Open task count of the current epoch. Written only while the batch
    /// is closed; readers validate it via the `state` CAS.
    total: AtomicUsize,
    /// Type-erased `RunCtx<A, B, C>` for the live dispatch.
    data: AtomicPtr<()>,
    /// Monomorphized trampoline: `run(data, i)` executes task `i`.
    run: AtomicPtr<()>,
    /// Set when any task panicked; the dispatching caller re-panics after
    /// the batch drains (a silent deadlock would be strictly worse).
    poisoned: AtomicUsize,
    m: Mutex<()>,
    cv: Condvar,
}

impl Batch {
    fn new() -> Batch {
        Batch {
            state: AtomicU64::new(CLOSED),
            done: AtomicUsize::new(0),
            total: AtomicUsize::new(0),
            data: AtomicPtr::new(std::ptr::null_mut()),
            run: AtomicPtr::new(std::ptr::null_mut()),
            poisoned: AtomicUsize::new(0),
            m: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Claim-and-run until no task is claimable. Returns how many tasks
    /// this thread executed.
    fn work(&self) -> usize {
        let mut ran = 0;
        loop {
            let s = self.state.load(SeqCst);
            let i = s & 0xFFFF_FFFF;
            if i == CLOSED {
                return ran;
            }
            let t = self.total.load(SeqCst) as u64;
            if i >= t {
                return ran;
            }
            // CAS on the packed word: success proves `state` (and hence
            // the epoch) did not change since `s` was read, so `t` is THIS
            // epoch's bound and index `i` is in range — the pointers
            // published before this epoch opened are the ones loaded below.
            if self.state.compare_exchange(s, s + 1, SeqCst, SeqCst).is_err() {
                continue;
            }
            let run: unsafe fn(*mut (), usize) =
                unsafe { std::mem::transmute(self.run.load(SeqCst)) };
            let data = self.data.load(SeqCst);
            // Task panics must still count toward `done`, or the caller
            // deadlocks; the caller re-raises after the batch drains.
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                run(data, i as usize)
            }));
            if ok.is_err() {
                self.poisoned.fetch_add(1, SeqCst);
            }
            ran += 1;
            let d = self.done.fetch_add(1, SeqCst) + 1;
            if d >= self.total.load(SeqCst) {
                // Lock-then-notify so a caller between its predicate check
                // and `cv.wait` cannot miss the wakeup.
                let _g = self.m.lock().unwrap();
                self.cv.notify_all();
            }
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Batch>>>,
    available: Condvar,
}

/// The persistent fan-out pool. One global instance ([`ShardPool::global`])
/// serves every sharded engine in the process; concurrent dispatches (e.g.
/// from data-parallel trainer threads) interleave safely on the shared
/// worker set.
pub struct ShardPool {
    shared: Arc<PoolShared>,
    workers: usize,
}

/// Typed context a dispatch pins on its stack; the trampoline reconstructs
/// the types from the monomorphized fn pointer stored alongside.
struct RunCtx<A, B, C> {
    a: *mut A,
    b: *mut B,
    ctx: *const C,
    f: fn(usize, &mut A, &mut B, &C),
}

unsafe fn trampoline<A, B, C>(data: *mut (), i: usize) {
    let rc = &*(data as *const RunCtx<A, B, C>);
    (rc.f)(i, &mut *rc.a.add(i), &mut *rc.b.add(i), &*rc.ctx);
}

thread_local! {
    /// Per-calling-thread reusable batch (one allocation per thread, ever).
    static LOCAL_BATCH: Arc<Batch> = Arc::new(Batch::new());
}

impl ShardPool {
    /// Spawn a pool with `workers` background threads. Workers park on a
    /// condvar between dispatches; they are never joined (process-lifetime,
    /// like the global allocator — there is deliberately no shutdown).
    pub fn new(workers: usize) -> ShardPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        });
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("sam-shard-{w}"))
                .spawn(move || loop {
                    let batch = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(b) = q.pop_front() {
                                break b;
                            }
                            q = sh.available.wait(q).unwrap();
                        }
                    };
                    batch.work();
                })
                .expect("spawn shard worker");
        }
        ShardPool { shared, workers }
    }

    /// The process-wide pool, created on first use with
    /// `min(available_parallelism - 1, 7)` workers (overridable via
    /// `SAM_SHARD_THREADS`). The dispatching thread always participates,
    /// so even `SAM_SHARD_THREADS=0` completes every batch (serially).
    pub fn global() -> &'static ShardPool {
        static POOL: OnceLock<ShardPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let default = std::thread::available_parallelism()
                .map(|p| p.get().saturating_sub(1).min(7))
                .unwrap_or(3);
            let workers = std::env::var("SAM_SHARD_THREADS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(default);
            ShardPool::new(workers)
        })
    }

    /// Background worker count (the caller thread is an extra worker during
    /// its own dispatches).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i, &mut a[i], &mut b[i], ctx)` for every `i`, distributing
    /// across the pool; returns when all calls completed. `f` is a plain fn
    /// pointer (capture state in `ctx` / the task slices) so dispatches
    /// stay allocation-free. A panic inside any task is caught on the
    /// worker (so the batch still drains), then re-raised here.
    pub fn run2<A: Send, B: Send, C: Sync>(
        &self,
        a: &mut [A],
        b: &mut [B],
        ctx: &C,
        f: fn(usize, &mut A, &mut B, &C),
    ) {
        assert_eq!(a.len(), b.len());
        let total = a.len();
        assert!((total as u64) < CLOSED, "task count overflows the claim word");
        if total == 0 {
            return;
        }
        if total == 1 || self.workers == 0 {
            for i in 0..total {
                f(i, &mut a[i], &mut b[i], ctx);
            }
            return;
        }
        let rc = RunCtx::<A, B, C> { a: a.as_mut_ptr(), b: b.as_mut_ptr(), ctx, f };
        LOCAL_BATCH.with(|batch| {
            // Publish pointers and counters first, then open the claim
            // window by bumping the epoch with next = 0. Stale workers
            // either see a closed low word, or a live epoch whose bound
            // they validate atomically with their claim (see Batch docs) —
            // never stale pointers or a stale bound.
            batch.data.store(&rc as *const _ as *mut (), SeqCst);
            let tramp: unsafe fn(*mut (), usize) = trampoline::<A, B, C>;
            batch.run.store(tramp as *mut (), SeqCst);
            batch.poisoned.store(0, SeqCst);
            batch.done.store(0, SeqCst);
            batch.total.store(total, SeqCst);
            let epoch = batch.state.load(SeqCst) >> 32;
            batch.state.store((epoch + 1) << 32, SeqCst);
            {
                let mut q = self.shared.queue.lock().unwrap();
                let helpers = self.workers.min(total - 1);
                for _ in 0..helpers {
                    q.push_back(Arc::clone(batch));
                }
                self.shared.available.notify_all();
            }
            // The caller is a worker too: claim until dry, then wait for
            // stragglers.
            batch.work();
            let mut g = batch.m.lock().unwrap();
            while batch.done.load(SeqCst) < total {
                g = batch.cv.wait(g).unwrap();
            }
            drop(g);
            // Close the claim window before the task storage goes out of
            // scope: bump the epoch again with the CLOSED sentinel.
            // `done == total` proves no claimed task is still running;
            // unclaimed stale pops now see the closed low word (or fail
            // their claim CAS against the newer epoch).
            batch.state.store(((epoch + 2) << 32) | CLOSED, SeqCst);
            let poisoned = batch.poisoned.load(SeqCst);
            assert!(poisoned == 0, "{poisoned} pool task(s) panicked");
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ShardPool::new(3);
        let mut counts = vec![0u32; 64];
        let mut outs = vec![0usize; 64];
        pool.run2(&mut counts, &mut outs, &7usize, |i, c, o, ctx| {
            *c += 1;
            *o = i * ctx;
        });
        assert!(counts.iter().all(|&c| c == 1));
        for (i, &o) in outs.iter().enumerate() {
            assert_eq!(o, i * 7);
        }
    }

    #[test]
    fn reuse_across_dispatches_is_clean() {
        let pool = ShardPool::new(2);
        for round in 0..200usize {
            let n = 1 + round % 5;
            let mut a = vec![0usize; n];
            let mut b = vec![0usize; n];
            pool.run2(&mut a, &mut b, &round, |i, a, b, ctx| {
                *a = i + ctx;
                *b = i * 2;
            });
            for i in 0..n {
                assert_eq!(a[i], i + round, "round {round}");
                assert_eq!(b[i], i * 2);
            }
        }
    }

    #[test]
    fn zero_workers_degrades_to_serial() {
        let pool = ShardPool::new(0);
        let mut a = vec![0u8; 9];
        let mut b = vec![0u8; 9];
        pool.run2(&mut a, &mut b, &(), |i, a, _b, _| *a = i as u8 + 1);
        assert_eq!(a, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let pool = Arc::new(ShardPool::new(2));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for round in 0..50usize {
                    let mut a = vec![0u64; 8];
                    let mut b = vec![0u64; 8];
                    p.run2(&mut a, &mut b, &t, |i, a, b, ctx| {
                        *a = i as u64 + ctx * 100;
                        *b = 1;
                    });
                    for i in 0..8 {
                        assert_eq!(a[i], i as u64 + t * 100, "thread {t} round {round}");
                    }
                    assert_eq!(b.iter().sum::<u64>(), 8);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn heavy_tasks_actually_parallelize_without_loss() {
        // Not a timing assertion (CI noise), just correctness under real
        // contention: tasks big enough that workers and caller interleave.
        let pool = ShardPool::new(3);
        let mut sums = vec![0u64; 16];
        let mut dummy = vec![(); 16];
        pool.run2(&mut sums, &mut dummy, &(), |i, s, _d, _| {
            let mut acc = 0u64;
            for x in 0..200_000u64 {
                acc = acc.wrapping_add(x ^ i as u64);
            }
            *s = acc;
        });
        let expect: Vec<u64> = (0..16)
            .map(|i| {
                let mut acc = 0u64;
                for x in 0..200_000u64 {
                    acc = acc.wrapping_add(x ^ i as u64);
                }
                acc
            })
            .collect();
        assert_eq!(sums, expect);
    }
}
