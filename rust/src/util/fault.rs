//! Deterministic fault injection for crash-recovery testing.
//!
//! Test code arms named injection points ("spill.write", "sched.tick", …)
//! with a fault kind and a deterministic trigger (fire on the Nth hit, or
//! with a seeded probability); production code consults
//! [`fire`] at each point and simulates the fault it is told to. With the
//! `fault-inject` cargo feature **off** (the default), every hook compiles
//! to an inlined `None`/no-op — zero branches, zero globals, zero cost on
//! the serving hot path. The feature is enabled only by the dedicated CI
//! leg running rust/tests/durability.rs' crash-recovery suite.
//!
//! Determinism: triggers are hit-counted or drawn from a seeded
//! [`crate::util::rng::Rng`] stream per rule — the same arm() sequence
//! produces the same fault schedule on every run, which is what makes a
//! torn-write reproduction a regression test rather than a flake.

/// What a triggered injection point should simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the operation with a synthetic I/O error.
    IoError,
    /// Complete only part of a write (torn write / truncation).
    ShortWrite,
    /// Panic on the worker thread (crash mid-operation).
    Panic,
}

#[cfg(feature = "fault-inject")]
mod imp {
    use super::FaultKind;
    use crate::util::rng::Rng;
    use std::sync::Mutex;

    enum Trigger {
        /// Fire on hits `after < hit <= after + count` (0-based `after`).
        Nth { after: usize, count: usize },
        /// Fire each hit independently with probability `p` from a seeded
        /// stream.
        Prob { rng: Rng, p: f64 },
    }

    struct Rule {
        point: &'static str,
        kind: FaultKind,
        trigger: Trigger,
        hits: usize,
        fired: usize,
    }

    static RULES: Mutex<Vec<Rule>> = Mutex::new(Vec::new());

    /// Arm `point` to fire `kind` on `count` consecutive hits after
    /// skipping the first `after` hits.
    pub fn arm(point: &'static str, kind: FaultKind, after: usize, count: usize) {
        RULES.lock().unwrap().push(Rule {
            point,
            kind,
            trigger: Trigger::Nth { after, count },
            hits: 0,
            fired: 0,
        });
    }

    /// Arm `point` to fire `kind` on each hit with probability `p`, drawn
    /// from a stream seeded with `seed` (deterministic per rule).
    pub fn arm_prob(point: &'static str, kind: FaultKind, seed: u64, p: f64) {
        RULES.lock().unwrap().push(Rule {
            point,
            kind,
            trigger: Trigger::Prob { rng: Rng::new(seed), p },
            hits: 0,
            fired: 0,
        });
    }

    /// Disarm every rule (test teardown).
    pub fn clear() {
        RULES.lock().unwrap().clear();
    }

    /// Times any rule for `point` has actually fired.
    pub fn fired_count(point: &str) -> usize {
        RULES.lock().unwrap().iter().filter(|r| r.point == point).map(|r| r.fired).sum()
    }

    /// Consult the registry at an injection point. First matching rule that
    /// triggers wins.
    pub fn fire(point: &str) -> Option<FaultKind> {
        let mut rules = RULES.lock().unwrap();
        for r in rules.iter_mut() {
            if r.point != point {
                continue;
            }
            let hit = r.hits;
            r.hits += 1;
            let fires = match &mut r.trigger {
                Trigger::Nth { after, count } => hit >= *after && hit < *after + *count,
                Trigger::Prob { rng, p } => (rng.next_u64() as f64 / u64::MAX as f64) < *p,
            };
            if fires {
                r.fired += 1;
                return Some(r.kind);
            }
        }
        None
    }
}

#[cfg(feature = "fault-inject")]
pub use imp::{arm, arm_prob, clear, fire, fired_count};

/// No-op stubs: with the feature off every consultation inlines to `None`
/// and the arming API disappears (so production code cannot arm faults by
/// accident — only `#[cfg(feature = "fault-inject")]` test code can).
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn fire(_point: &str) -> Option<FaultKind> {
    None
}

/// Convenience: fail with a synthetic I/O error if `point` is armed with
/// [`FaultKind::IoError`]; panic if armed with [`FaultKind::Panic`].
/// [`FaultKind::ShortWrite`] is reported back for the caller to simulate
/// (only writers know how to tear their own writes).
pub fn check_io(point: &str) -> std::io::Result<Option<FaultKind>> {
    match fire(point) {
        Some(FaultKind::IoError) => Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("injected I/O fault at {point}"),
        )),
        Some(FaultKind::Panic) => panic!("injected panic at {point}"),
        other => Ok(other),
    }
}

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    use super::*;

    #[test]
    fn nth_trigger_fires_deterministically() {
        clear();
        arm("t.point", FaultKind::IoError, 2, 1);
        assert_eq!(fire("t.point"), None);
        assert_eq!(fire("t.point"), None);
        assert_eq!(fire("t.point"), Some(FaultKind::IoError));
        assert_eq!(fire("t.point"), None);
        assert_eq!(fired_count("t.point"), 1);
        clear();
        assert_eq!(fire("t.point"), None);
    }

    #[test]
    fn prob_trigger_is_reproducible() {
        let run = || {
            clear();
            arm_prob("t.prob", FaultKind::ShortWrite, 42, 0.5);
            let seq: Vec<bool> = (0..32).map(|_| fire("t.prob").is_some()).collect();
            clear();
            seq
        };
        assert_eq!(run(), run(), "seeded probability schedule must be reproducible");
    }
}
