//! Wall-clock timing + simple statistics used by the benchmark harness.

use std::time::Instant;

/// Stopwatch with lap support.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Restart and return the elapsed seconds of the finished lap.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Mean / standard deviation / min / max of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    /// Stats over the **finite** samples in `xs`. A NaN or ±Inf sample
    /// (a zero-duration division, a poisoned measurement) must not poison
    /// mean/min/max — BENCH_*.json verdict comparisons read these fields
    /// and `NaN >= floor` is silently false. Non-finite samples are
    /// dropped and `n` reports the finite count; an all-non-finite (or
    /// empty) input panics, as an empty sample always has.
    pub fn of(xs: &[f64]) -> Stats {
        let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        assert!(
            !finite.is_empty(),
            "Stats::of needs at least one finite sample ({} given, all non-finite or empty)",
            xs.len()
        );
        let n = finite.len();
        let mean = finite.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            finite.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        // total_cmp folds: immune to the NaN-absorbing behaviour of
        // f64::min/max (defense in depth — the filter above already
        // removed non-finite values).
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: finite.iter().copied().fold(f64::INFINITY, |a, b| match a.total_cmp(&b) {
                std::cmp::Ordering::Greater => b,
                _ => a,
            }),
            max: finite.iter().copied().fold(f64::NEG_INFINITY, |a, b| {
                match a.total_cmp(&b) {
                    std::cmp::Ordering::Less => b,
                    _ => a,
                }
            }),
        }
    }
}

/// Time a closure `reps` times after `warmup` runs; returns per-rep stats in seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
    }
    Stats::of(&samples)
}

/// Human-readable duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn stats_reject_non_finite_samples() {
        // A NaN sample used to poison mean AND min/max (f64::min/max
        // propagate differently depending on argument order); now it is
        // dropped and n counts only the finite samples.
        let s = Stats::of(&[1.0, f64::NAN, 3.0, f64::INFINITY, f64::NEG_INFINITY]);
        assert_eq!(s.n, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!(s.std.is_finite());
    }

    #[test]
    #[should_panic(expected = "finite sample")]
    fn stats_all_nan_panics() {
        Stats::of(&[f64::NAN, f64::NAN]);
    }

    #[test]
    fn time_reps_runs() {
        let mut count = 0;
        let s = time_reps(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.5 µs");
    }
}
