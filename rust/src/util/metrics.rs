//! Process-global, lock-light metrics registry: relaxed-atomic counters and
//! gauges plus fixed-bucket latency histograms, all statically registered so
//! the hot path is one `fetch_add(Relaxed)` on a `static` — no locks, no
//! lazy-init, and **zero heap allocations** (pinned in
//! rust/tests/zero_alloc.rs for the train-tick and serving-step paths).
//!
//! Design constraints, in order:
//!
//! 1. **Always-on.** Metrics are not feature-gated; the cost budget is one
//!    relaxed atomic add (plus an `Instant::now()` pair for timed sections)
//!    per event. That keeps every build honest — there is no "metrics
//!    disabled" configuration whose performance differs from production.
//! 2. **Const-constructible.** Every handle is a `static` built by a `const
//!    fn`, so registration is the Rust linker's job: no registry mutex, no
//!    `OnceLock`, no first-use branch on the hot path.
//! 3. **Fixed buckets.** Histograms use power-of-2 µs buckets (`le = 1, 2,
//!    4, … 2^24 µs ≈ 16.8 s`, then `+Inf`): bucket selection is a
//!    `leading_zeros`, readout is a cumulative walk. Quantiles (p50/p95/p99)
//!    are therefore upper-bound estimates with ≤ 2× resolution — exactly
//!    what a regression gate needs, at zero allocation.
//!
//! Naming follows the Prometheus convention: `sam_<layer>_<what>_total` for
//! counters, `sam_<layer>_<what>` for gauges, `sam_<layer>_<what>_us` for
//! latency histograms (exposed with `_bucket`/`_sum`/`_count` series). The
//! three layers are `train` (per-phase tick timers, episodes,
//! gradient-reduce), `serve`/`sessions` (scheduler ticks, queue/step
//! latency, session lifecycle) and `mem`/`ann` (reads, writes, rollbacks,
//! ANN query volume). Readout surfaces: the server's `{"metrics"}` op
//! (Prometheus text via [`render_prometheus`]), the enriched `{"stats"}`
//! reply, `sam train --metrics-json` snapshots ([`snapshot_json`]), and
//! the BENCH_serve/BENCH_train histogram summaries ([`hist_summary_json`]).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

/// Monotonic event counter. `inc`/`add` are single relaxed atomic adds.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Relaxed)
    }
}

/// Instantaneous value (current open sessions, last tick fill). `inc`/`dec`
/// are relaxed adds/subs; `set` is a relaxed store — last writer wins, which
/// is the right semantics for a sampled level.
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, n: u64) {
        self.v.store(n, Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        // Saturating on readout rather than here would race; a transient
        // underflow can only come from a bug in paired inc/dec call sites,
        // so wrap loudly (u64::MAX in the readout) instead of masking it.
        self.v.fetch_sub(1, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Relaxed)
    }
}

/// Number of finite histogram buckets; bucket `i < BUCKETS-1` counts
/// observations with `us <= 2^i`, the last bucket is `+Inf`.
pub const BUCKETS: usize = 26;

/// Upper bound (µs) of finite bucket `i`.
#[inline]
fn bucket_le(i: usize) -> u64 {
    1u64 << i
}

/// Fixed-bucket latency histogram over microseconds. Preallocated
/// power-of-2 buckets: `observe_us` is two relaxed adds plus a
/// `leading_zeros` — no locks, no allocation, safe from any thread.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

/// p50/p95/p99 + count/sum readout of a [`Histogram`], as embedded in
/// BENCH JSON and the `{"stats"}` reply. Quantiles are bucket upper
/// bounds (≤ 2× overestimates by construction).
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSummary {
    pub count: u64,
    pub sum_us: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [Z; BUCKETS], count: AtomicU64::new(0), sum_us: AtomicU64::new(0) }
    }

    /// Bucket index for a duration: smallest `i` with `us <= 2^i`, clamped
    /// into the `+Inf` bucket past `2^(BUCKETS-2)` µs.
    #[inline]
    fn idx(us: u64) -> usize {
        if us <= 1 {
            0
        } else {
            (64 - (us - 1).leading_zeros() as usize).min(BUCKETS - 1)
        }
    }

    #[inline]
    pub fn observe_us(&self, us: u64) {
        self.buckets[Self::idx(us)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
    }

    /// Observe the elapsed time since `start`. The idiom at call sites:
    /// `let t = Instant::now(); …work…; HIST.observe_since(t);`
    #[inline]
    pub fn observe_since(&self, start: Instant) {
        self.observe_us(start.elapsed().as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Relaxed)
    }

    /// Upper-bound quantile estimate: the `le` bound of the first bucket
    /// whose cumulative count reaches `q * count`. The `+Inf` bucket
    /// reports its predecessor's bound (the histogram's measurable
    /// ceiling). 0 when empty.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count.load(Relaxed);
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum >= target {
                return bucket_le(i.min(BUCKETS - 2));
            }
        }
        bucket_le(BUCKETS - 2)
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            sum_us: self.sum_us(),
            p50_us: self.quantile_us(0.50),
            p95_us: self.quantile_us(0.95),
            p99_us: self.quantile_us(0.99),
        }
    }
}

/// Sequential section timer for multi-phase hot loops (the F1–F9/B2–B8
/// training-tick phases): each [`PhaseClock::lap`] observes the time since
/// the previous lap into the given histogram and restarts the clock — one
/// `Instant::now()` per boundary, zero allocations.
pub struct PhaseClock {
    t: Instant,
}

impl PhaseClock {
    #[inline]
    pub fn start() -> PhaseClock {
        PhaseClock { t: Instant::now() }
    }

    #[inline]
    pub fn lap(&mut self, h: &Histogram) {
        let now = Instant::now();
        h.observe_us(now.saturating_duration_since(self.t).as_micros() as u64);
        self.t = now;
    }
}

// ---------------------------------------------------------------------------
// The static registry. Adding a metric = adding a static here plus one line
// in each of render_prometheus()/snapshot_json() below; the hot path stays
// a single atomic add on a linker-placed static.
// ---------------------------------------------------------------------------

/// Training-tick forward phases (F1..F9), indexable by phase number - 1.
pub const FWD_PHASES: usize = 9;
/// Training-tick backward phases (B2..B8), indexable by phase number - 2.
pub const BWD_PHASES: usize = 7;

const H: Histogram = Histogram::new();

// -- training ---------------------------------------------------------------
/// Episodes completed across all trainer kinds.
pub static TRAIN_EPISODES: Counter = Counter::new();
/// Fused training ticks (one forward+backward lockstep across lanes).
pub static TRAIN_TICKS: Counter = Counter::new();
/// Cross-worker gradient reduce + optimizer step time per update.
pub static TRAIN_GRAD_REDUCE_US: Histogram = Histogram::new();
/// Per-phase forward tick timers F1 (input gather) .. F9 (output notes).
pub static TRAIN_FWD_PHASE_US: [Histogram; FWD_PHASES] = [H; FWD_PHASES];
/// Per-phase backward tick timers B2 (output GEMM) .. B8 (finish).
pub static TRAIN_BWD_PHASE_US: [Histogram; BWD_PHASES] = [H; BWD_PHASES];

// -- serving ----------------------------------------------------------------
/// Session steps executed (scheduler ticks + direct step calls).
pub static SERVE_STEPS: Counter = Counter::new();
/// Per-session step latency inside `step`/`step_many`.
pub static SERVE_STEP_LATENCY_US: Histogram = Histogram::new();
/// Submit-to-drain wait of a scheduled request in the coalescing inbox.
pub static SERVE_QUEUE_LATENCY_US: Histogram = Histogram::new();
/// Coalescing ticks executed by the batch scheduler.
pub static SERVE_TICKS: Counter = Counter::new();
/// Requests drained across all ticks (fill ratio = requests/ticks/max_batch).
pub static SERVE_TICK_REQUESTS: Counter = Counter::new();
/// Fill of the most recent tick, in permille of max_batch.
pub static SERVE_TICK_FILL_PERMILLE: Gauge = Gauge::new();

// -- sessions ---------------------------------------------------------------
/// Currently open (resident or spilled) sessions.
pub static SESSIONS_OPEN: Gauge = Gauge::new();
pub static SESSIONS_OPENED: Counter = Counter::new();
pub static SESSIONS_EVICTED: Counter = Counter::new();
pub static SESSIONS_EXPIRED: Counter = Counter::new();
pub static SESSIONS_SPILLED: Counter = Counter::new();
pub static SESSIONS_REHYDRATED: Counter = Counter::new();
pub static SESSIONS_CORRUPT_DROPPED: Counter = Counter::new();
pub static SESSIONS_SPILL_FAILURES: Counter = Counter::new();

// -- memory / ANN -----------------------------------------------------------
/// Content-read queries answered by the memory engine (per head×lane).
pub static MEM_READS: Counter = Counter::new();
/// Sparse writes applied (journaled + forward-only).
pub static MEM_WRITES: Counter = Counter::new();
/// Episode rollbacks (tape reverts).
pub static MEM_ROLLBACKS: Counter = Counter::new();
/// Queries answered by ANN backends (all kinds).
pub static ANN_QUERIES: Counter = Counter::new();
/// Candidate rows scored across ANN queries (linear: present rows/query;
/// graph/tree/hash backends: rows actually distance-evaluated).
pub static ANN_CANDIDATES: Counter = Counter::new();
/// Full index rebuilds — the incremental-maintenance regression signal;
/// the default paths pin this at 0.
pub static ANN_FULL_REBUILDS: Counter = Counter::new();

// ---------------------------------------------------------------------------
// Readout: Prometheus text + JSON snapshot
// ---------------------------------------------------------------------------

fn render_counter(out: &mut String, name: &str, c: &Counter) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" counter\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&c.get().to_string());
    out.push('\n');
}

fn render_gauge(out: &mut String, name: &str, g: &Gauge) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" gauge\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&g.get().to_string());
    out.push('\n');
}

/// One histogram series in Prometheus exposition format. `labels` is either
/// empty or a `key="value"` fragment (joined with the `le` label); pass
/// `emit_type` = false for the 2nd+ member of a labelled family so the
/// `# TYPE` line appears once per family.
fn render_hist(out: &mut String, name: &str, labels: &str, h: &Histogram, emit_type: bool) {
    if emit_type {
        out.push_str("# TYPE ");
        out.push_str(name);
        out.push_str(" histogram\n");
    }
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for i in 0..BUCKETS {
        cum += h.buckets[i].load(Relaxed);
        let le = if i == BUCKETS - 1 { "+Inf".to_string() } else { bucket_le(i).to_string() };
        out.push_str(name);
        out.push_str("_bucket{");
        out.push_str(labels);
        out.push_str(sep);
        out.push_str("le=\"");
        out.push_str(&le);
        out.push_str("\"} ");
        out.push_str(&cum.to_string());
        out.push('\n');
    }
    let tail = |out: &mut String, suffix: &str, v: u64| {
        out.push_str(name);
        out.push_str(suffix);
        if !labels.is_empty() {
            out.push('{');
            out.push_str(labels);
            out.push('}');
        }
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    };
    tail(out, "_sum", h.sum_us());
    tail(out, "_count", h.count());
}

/// Render every registered metric in Prometheus text exposition format.
/// Cold path (the `{"metrics"}` server op, CI smoke): allocates freely.
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(16 * 1024);

    render_counter(&mut out, "sam_train_episodes_total", &TRAIN_EPISODES);
    render_counter(&mut out, "sam_train_ticks_total", &TRAIN_TICKS);
    render_hist(&mut out, "sam_train_grad_reduce_us", "", &TRAIN_GRAD_REDUCE_US, true);
    for (i, h) in TRAIN_FWD_PHASE_US.iter().enumerate() {
        let label = format!("phase=\"f{}\"", i + 1);
        render_hist(&mut out, "sam_train_fwd_phase_us", &label, h, i == 0);
    }
    for (i, h) in TRAIN_BWD_PHASE_US.iter().enumerate() {
        let label = format!("phase=\"b{}\"", i + 2);
        render_hist(&mut out, "sam_train_bwd_phase_us", &label, h, i == 0);
    }

    render_counter(&mut out, "sam_serve_steps_total", &SERVE_STEPS);
    render_hist(&mut out, "sam_serve_step_latency_us", "", &SERVE_STEP_LATENCY_US, true);
    render_hist(&mut out, "sam_serve_queue_latency_us", "", &SERVE_QUEUE_LATENCY_US, true);
    render_counter(&mut out, "sam_serve_ticks_total", &SERVE_TICKS);
    render_counter(&mut out, "sam_serve_tick_requests_total", &SERVE_TICK_REQUESTS);
    render_gauge(&mut out, "sam_serve_tick_fill_permille", &SERVE_TICK_FILL_PERMILLE);

    render_gauge(&mut out, "sam_sessions_open", &SESSIONS_OPEN);
    render_counter(&mut out, "sam_sessions_opened_total", &SESSIONS_OPENED);
    render_counter(&mut out, "sam_sessions_evicted_total", &SESSIONS_EVICTED);
    render_counter(&mut out, "sam_sessions_expired_total", &SESSIONS_EXPIRED);
    render_counter(&mut out, "sam_sessions_spilled_total", &SESSIONS_SPILLED);
    render_counter(&mut out, "sam_sessions_rehydrated_total", &SESSIONS_REHYDRATED);
    render_counter(&mut out, "sam_sessions_corrupt_dropped_total", &SESSIONS_CORRUPT_DROPPED);
    render_counter(&mut out, "sam_sessions_spill_failures_total", &SESSIONS_SPILL_FAILURES);

    render_counter(&mut out, "sam_mem_reads_total", &MEM_READS);
    render_counter(&mut out, "sam_mem_writes_total", &MEM_WRITES);
    render_counter(&mut out, "sam_mem_rollbacks_total", &MEM_ROLLBACKS);
    render_counter(&mut out, "sam_ann_queries_total", &ANN_QUERIES);
    render_counter(&mut out, "sam_ann_candidates_scanned_total", &ANN_CANDIDATES);
    render_counter(&mut out, "sam_ann_full_rebuilds_total", &ANN_FULL_REBUILDS);

    out
}

/// Histogram summary as a JSON object (BENCH_serve/BENCH_train embeds,
/// `{"stats"}` reply, `--metrics-json` snapshots).
pub fn hist_summary_json(h: &Histogram) -> Json {
    let s = h.summary();
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("sum_us", Json::num(s.sum_us as f64)),
        ("p50_us", Json::num(s.p50_us as f64)),
        ("p95_us", Json::num(s.p95_us as f64)),
        ("p99_us", Json::num(s.p99_us as f64)),
    ])
}

/// Full registry snapshot as JSON: counters/gauges as numbers, histograms
/// as summary objects. The `sam train --metrics-json <path>` flag writes
/// this periodically and at exit.
pub fn snapshot_json() -> Json {
    let phases = |hs: &'static [Histogram], base: usize, prefix: &str| {
        Json::Obj(
            hs.iter()
                .enumerate()
                .map(|(i, h)| (format!("{prefix}{}", base + i), hist_summary_json(h)))
                .collect(),
        )
    };
    Json::obj(vec![
        (
            "train",
            Json::obj(vec![
                ("episodes", Json::num(TRAIN_EPISODES.get() as f64)),
                ("ticks", Json::num(TRAIN_TICKS.get() as f64)),
                ("grad_reduce_us", hist_summary_json(&TRAIN_GRAD_REDUCE_US)),
                ("fwd_phase_us", phases(&TRAIN_FWD_PHASE_US, 1, "f")),
                ("bwd_phase_us", phases(&TRAIN_BWD_PHASE_US, 2, "b")),
            ]),
        ),
        (
            "serve",
            Json::obj(vec![
                ("steps", Json::num(SERVE_STEPS.get() as f64)),
                ("step_latency_us", hist_summary_json(&SERVE_STEP_LATENCY_US)),
                ("queue_latency_us", hist_summary_json(&SERVE_QUEUE_LATENCY_US)),
                ("ticks", Json::num(SERVE_TICKS.get() as f64)),
                ("tick_requests", Json::num(SERVE_TICK_REQUESTS.get() as f64)),
                ("tick_fill_permille", Json::num(SERVE_TICK_FILL_PERMILLE.get() as f64)),
            ]),
        ),
        (
            "sessions",
            Json::obj(vec![
                ("open", Json::num(SESSIONS_OPEN.get() as f64)),
                ("opened", Json::num(SESSIONS_OPENED.get() as f64)),
                ("evicted", Json::num(SESSIONS_EVICTED.get() as f64)),
                ("expired", Json::num(SESSIONS_EXPIRED.get() as f64)),
                ("spilled", Json::num(SESSIONS_SPILLED.get() as f64)),
                ("rehydrated", Json::num(SESSIONS_REHYDRATED.get() as f64)),
                ("corrupt_dropped", Json::num(SESSIONS_CORRUPT_DROPPED.get() as f64)),
                ("spill_failures", Json::num(SESSIONS_SPILL_FAILURES.get() as f64)),
            ]),
        ),
        (
            "memory",
            Json::obj(vec![
                ("reads", Json::num(MEM_READS.get() as f64)),
                ("writes", Json::num(MEM_WRITES.get() as f64)),
                ("rollbacks", Json::num(MEM_ROLLBACKS.get() as f64)),
                ("ann_queries", Json::num(ANN_QUERIES.get() as f64)),
                ("ann_candidates_scanned", Json::num(ANN_CANDIDATES.get() as f64)),
                ("ann_full_rebuilds", Json::num(ANN_FULL_REBUILDS.get() as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        static C: Counter = Counter::new();
        static G: Gauge = Gauge::new();
        C.inc();
        C.add(4);
        assert_eq!(C.get(), 5);
        G.set(7);
        G.inc();
        G.dec();
        assert_eq!(G.get(), 7);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        assert_eq!(Histogram::idx(0), 0);
        assert_eq!(Histogram::idx(1), 0);
        assert_eq!(Histogram::idx(2), 1);
        assert_eq!(Histogram::idx(3), 2);
        assert_eq!(Histogram::idx(4), 2);
        assert_eq!(Histogram::idx(5), 3);
        assert_eq!(Histogram::idx(u64::MAX), BUCKETS - 1);

        assert_eq!(h.quantile_us(0.5), 0); // empty
        for us in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 1000] {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum_us(), 1009);
        assert_eq!(h.quantile_us(0.50), 1);
        // p95 of 10 samples lands on the 10th (ceil(0.95*10) = 10): the
        // 1000 µs outlier, reported as its bucket bound 1024.
        assert_eq!(h.quantile_us(0.95), 1024);
        assert_eq!(h.quantile_us(0.99), 1024);
        let s = h.summary();
        assert_eq!((s.count, s.p50_us, s.p99_us), (10, 1, 1024));
    }

    #[test]
    fn histogram_overflow_bucket_reports_ceiling() {
        let h = Histogram::new();
        h.observe_us(u64::MAX / 2);
        assert_eq!(h.quantile_us(0.5), 1u64 << (BUCKETS - 2));
    }

    #[test]
    fn prometheus_render_is_well_formed() {
        // Touch a few metrics so the render has nonzero series too.
        MEM_READS.inc();
        SERVE_STEP_LATENCY_US.observe_us(42);
        let text = render_prometheus();
        assert!(text.starts_with("# TYPE "));
        for family in [
            "sam_train_episodes_total",
            "sam_train_fwd_phase_us_bucket{phase=\"f1\",le=\"1\"}",
            "sam_train_bwd_phase_us_bucket{phase=\"b2\",le=\"+Inf\"}",
            "sam_serve_step_latency_us_sum",
            "sam_serve_step_latency_us_count",
            "sam_sessions_open",
            "sam_mem_reads_total",
            "sam_ann_queries_total",
        ] {
            assert!(text.contains(family), "missing {family} in render");
        }
        // Every non-comment line is `name[{labels}] <integer>`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("line has a value");
            assert!(!name.is_empty());
            assert!(value.parse::<u64>().is_ok(), "non-integer value in {line:?}");
        }
        // Histogram bucket series are cumulative: the +Inf bucket of the
        // step-latency family equals its _count.
        let count_line = text
            .lines()
            .find(|l| l.starts_with("sam_serve_step_latency_us_count"))
            .unwrap();
        let inf_line = text
            .lines()
            .find(|l| l.starts_with("sam_serve_step_latency_us_bucket{le=\"+Inf\"}"))
            .unwrap();
        let tail = |l: &str| l.rsplit_once(' ').unwrap().1.parse::<u64>().unwrap();
        assert_eq!(tail(count_line), tail(inf_line));
    }

    #[test]
    fn snapshot_json_round_trips() {
        TRAIN_EPISODES.inc();
        let snap = snapshot_json();
        let text = snap.encode();
        let parsed = crate::util::json::Json::parse(&text).expect("snapshot parses");
        for key in ["train", "serve", "sessions", "memory"] {
            assert!(parsed.get(key).is_some(), "snapshot missing {key:?}");
        }
    }
}
