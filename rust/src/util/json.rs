//! Minimal JSON value + encoder/parser (serde is unavailable offline).
//!
//! Used for experiment logs (EXPERIMENTS.md data), run configs, and the
//! inference server's wire protocol. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn floats(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Lookup a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact encoding.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns the value and rejects trailing garbage.
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err("unknown escape".into()),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passthrough).
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("sam")),
            ("n", Json::num(65536)),
            ("ratio", Json::num(0.125)),
            ("tags", Json::arr(vec![Json::str("a"), Json::Null, Json::Bool(true)])),
        ]);
        let enc = v.encode();
        let back = Json::parse(&enc).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#" {"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}} "#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
    }

    #[test]
    fn escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).encode();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(65536.0).encode(), "65536");
    }
}
