//! Counting global allocator for the paper's memory benchmarks (Fig 1b, Fig 7b).
//!
//! The paper reports "physical memory used to train over a sequence of 100
//! time steps, excluding initialization of external memory". We reproduce
//! that with a global allocator wrapper that tracks live and peak bytes;
//! benchmarks snapshot the counters around the region of interest
//! (`MemRegion`), so initialization can be excluded exactly as the paper did.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Live (currently allocated) bytes.
static LIVE: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of `LIVE`.
static PEAK: AtomicUsize = AtomicUsize::new(0);
/// Total bytes ever allocated (monotonic).
static TOTAL: AtomicUsize = AtomicUsize::new(0);

std::thread_local! {
    /// Per-thread count of allocation events (alloc + realloc), for the
    /// zero-allocation hot-path tests: the global counters are polluted by
    /// concurrently running tests, a thread-local count is not. Const-init
    /// so first access inside the allocator itself cannot recurse.
    static THREAD_ALLOCS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

#[inline]
fn bump_thread_allocs() {
    // try_with: TLS may be unavailable during thread teardown.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// Number of allocation events performed by the calling thread since it
/// started. Diff around a region to prove the region allocates nothing
/// (see rust/tests/zero_alloc.rs).
pub fn thread_alloc_count() -> usize {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Global allocator that counts bytes. Install with:
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
/// (done in `lib.rs` so every binary in the crate gets it).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            track_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            track_alloc(new_size);
        }
        p
    }
}

#[inline]
fn track_alloc(size: usize) {
    bump_thread_allocs();
    TOTAL.fetch_add(size, Ordering::Relaxed);
    let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
    // Racy max update is fine: benches are effectively single-threaded.
    let mut peak = PEAK.load(Ordering::Relaxed);
    while live > peak {
        match PEAK.compare_exchange_weak(peak, live, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

/// Currently live heap bytes.
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Peak live heap bytes since process start (or last `reset_peak`).
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Total bytes ever allocated.
pub fn total_bytes() -> usize {
    TOTAL.load(Ordering::Relaxed)
}

/// Reset the peak high-water mark to the current live value.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Measures the *additional* peak heap consumed by a region of code,
/// relative to the live bytes at region entry — this is exactly the
/// paper's "memory used to train over a sequence, excluding initialization".
pub struct MemRegion {
    base_live: usize,
}

impl MemRegion {
    /// Start measuring; resets the peak to the current live level.
    pub fn start() -> Self {
        reset_peak();
        MemRegion { base_live: live_bytes() }
    }

    /// Extra peak bytes over the baseline since `start`.
    pub fn peak_overhead(&self) -> usize {
        peak_bytes().saturating_sub(self.base_live)
    }

    /// Extra live bytes over the baseline right now.
    pub fn live_overhead(&self) -> usize {
        live_bytes().saturating_sub(self.base_live)
    }
}

/// Pretty-print a byte count (MiB/GiB) the way the paper does.
pub fn fmt_bytes(b: usize) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_vec_alloc() {
        let region = MemRegion::start();
        let v = vec![0u8; 1 << 20];
        assert!(region.peak_overhead() >= 1 << 20, "peak {}", region.peak_overhead());
        drop(v);
        // After drop, live overhead should fall back near zero.
        assert!(region.live_overhead() < 1 << 16);
    }

    #[test]
    fn thread_alloc_count_sees_local_allocs_only() {
        let before = thread_alloc_count();
        let v = vec![0u8; 4096];
        drop(v);
        let here = thread_alloc_count() - before;
        assert!(here >= 1, "local alloc not counted");
        // A no-op region counts zero even if other test threads allocate.
        let before = thread_alloc_count();
        std::hint::black_box(());
        assert_eq!(thread_alloc_count() - before, 0);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(29 * 1024 * 1024 * 1024), "29.00 GiB");
    }
}
