//! Dense row-major f32 matrix and the handful of BLAS-like kernels the
//! cores need. This fills the role Eigen played in the paper's reference
//! implementation (Supp E). Hot loops are written to autovectorize.

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Frobenius norm squared.
    pub fn norm_sq(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// self += other * scale
    pub fn axpy(&mut self, scale: f32, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += scale * b;
        }
    }

    /// Heap bytes held by this matrix (for the memory benchmarks).
    pub fn heap_bytes(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f32>()
    }
}

// ---------------------------------------------------------------------------
// Vector kernels
// ---------------------------------------------------------------------------

/// y += a * x
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 8 independent accumulator lanes over bounds-check-free chunks so
    // LLVM emits wide FMA SIMD without reassociating a serial reduction.
    const LANES: usize = 8;
    let mut acc = [0.0f32; LANES];
    let (ca, ra) = a.split_at(a.len() - a.len() % LANES);
    let (cb, rb) = b.split_at(ca.len());
    for (xa, xb) in ca.chunks_exact(LANES).zip(cb.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Cosine similarity with epsilon guard (the paper's d(q, M(i))).
#[inline]
pub fn cosine(a: &[f32], b: &[f32], eps: f32) -> f32 {
    dot(a, b) / (norm(a) * norm(b) + eps)
}

// ---------------------------------------------------------------------------
// GEMM-like kernels (all accumulate into the output: C += op(A) op(B))
// ---------------------------------------------------------------------------

/// y += A x  (A: m×n, x: n, y: m)
pub fn gemv(y: &mut [f32], a: &Matrix, x: &[f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] += dot(a.row(i), x);
    }
}

/// y += Aᵀ x  (A: m×n, x: m, y: n)
pub fn gemv_t(y: &mut [f32], a: &Matrix, x: &[f32]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, y.len());
    for i in 0..a.rows {
        axpy(y, x[i], a.row(i));
    }
}

/// C += A B  (A: m×k, B: k×n, C: m×n); ikj loop order for cache-friendliness.
pub fn gemm(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    for i in 0..a.rows {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for k in 0..a.cols {
            let aik = a.get(i, k);
            if aik != 0.0 {
                axpy(crow, aik, b.row(k));
            }
        }
    }
}

/// C += a bᵀ (outer product; a: m, b: n, C: m×n)
pub fn outer_acc(c: &mut Matrix, a: &[f32], b: &[f32]) {
    assert_eq!(c.rows, a.len());
    assert_eq!(c.cols, b.len());
    for i in 0..a.len() {
        axpy(c.row_mut(i), a[i], b);
    }
}

/// C += Aᵀ B  (A: k×m, B: k×n, C: m×n).
///
/// This is the batched form of `outer_acc`: a stack of k outer products
/// `Σ_t A(t,:) B(t,:)ᵀ` done as one GEMM. The layers' deferred backward
/// passes use it to turn T per-step rank-1 weight-gradient updates into a
/// single cache-friendly matrix multiply over the whole episode.
pub fn gemm_tn(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.rows, b.rows);
    assert_eq!(c.rows, a.cols);
    assert_eq!(c.cols, b.cols);
    for t in 0..a.rows {
        let arow = a.row(t);
        for (i, &ati) in arow.iter().enumerate() {
            if ati != 0.0 {
                axpy(c.row_mut(i), ati, b.row(t));
            }
        }
    }
}

/// C += A Bᵀ  (A: m×k, B: n×k, C: m×n).
///
/// The batched linear forward Y = X Wᵀ (X: T×in, W: out×in) is this with
/// no transposition of the stored row-major weights.
pub fn gemm_nt(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * b.rows..(i + 1) * b.rows];
        for (j, cj) in crow.iter_mut().enumerate() {
            *cj += dot(arow, b.row(j));
        }
    }
}

/// y += Σ_t A(t, :)  (column sums; A: k×n, y: n).
pub fn col_sum_acc(y: &mut [f32], a: &Matrix) {
    assert_eq!(y.len(), a.cols);
    for t in 0..a.rows {
        axpy(y, 1.0, a.row(t));
    }
}

// ---------------------------------------------------------------------------
// Softmax and friends
// ---------------------------------------------------------------------------

/// In-place stable softmax. Returns nothing; `x` becomes the distribution.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Backward of softmax: given y = softmax(x) and dL/dy, compute dL/dx.
pub fn softmax_backward(y: &[f32], dy: &[f32], dx: &mut [f32]) {
    let s = dot(y, dy);
    for i in 0..y.len() {
        dx[i] = y[i] * (dy[i] - s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_rows(vec![vec![1., 2., 3.], vec![4., 5., 6.]]);
        let b = Matrix::from_rows(vec![vec![7., 8.], vec![9., 10.], vec![11., 12.]]);
        let mut c = Matrix::zeros(2, 2);
        gemm(&mut c, &a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
        // accumulation semantics
        gemm(&mut c, &a, &b);
        assert_eq!(c.data, vec![116., 128., 278., 308.]);
    }

    #[test]
    fn gemv_and_transpose() {
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![3., 4.], vec![5., 6.]]);
        let mut y = vec![0.0; 3];
        gemv(&mut y, &a, &[1., 1.]);
        assert_eq!(y, vec![3., 7., 11.]);
        let mut yt = vec![0.0; 2];
        gemv_t(&mut yt, &a, &[1., 1., 1.]);
        assert_eq!(yt, vec![9., 12.]);
    }

    #[test]
    fn dot_odd_lengths() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..13).map(|i| (i * i * 2) as f32).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0, 0.0, 0.0];
        let b = [1.0, 0.0, 0.0];
        let c = [-1.0, 0.0, 0.0];
        assert!((cosine(&a, &b, 1e-6) - 1.0).abs() < 1e-4);
        assert!((cosine(&a, &c, 1e-6) + 1.0).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn softmax_backward_matches_fd() {
        let x0 = vec![0.3f32, -0.7, 1.1, 0.05];
        let dy = vec![0.2f32, -0.1, 0.4, 0.3];
        let mut y = x0.clone();
        softmax_inplace(&mut y);
        let mut dx = vec![0.0; 4];
        softmax_backward(&y, &dy, &mut dx);
        let eps = 1e-3;
        for i in 0..4 {
            let mut xp = x0.clone();
            xp[i] += eps;
            softmax_inplace(&mut xp);
            let mut xm = x0.clone();
            xm[i] -= eps;
            softmax_inplace(&mut xm);
            let fd: f32 = (0..4).map(|j| (xp[j] - xm[j]) / (2.0 * eps) * dy[j]).sum();
            assert!((fd - dx[i]).abs() < 1e-3, "i={i} fd={fd} an={}", dx[i]);
        }
    }

    #[test]
    fn outer_product() {
        let mut c = Matrix::zeros(2, 3);
        outer_acc(&mut c, &[2.0, 3.0], &[1.0, 10.0, 100.0]);
        assert_eq!(c.data, vec![2., 20., 200., 3., 30., 300.]);
    }

    #[test]
    fn gemm_tn_matches_stacked_outer_products() {
        // A: 3×2, B: 3×4 — Aᵀ B must equal Σ_t outer(A(t,:), B(t,:)).
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![-0.5, 3.], vec![0., 1.5]]);
        let b = Matrix::from_rows(vec![
            vec![1., 0., 2., -1.],
            vec![0.5, 1., 0., 2.],
            vec![-1., 3., 1., 0.],
        ]);
        let mut c = Matrix::zeros(2, 4);
        gemm_tn(&mut c, &a, &b);
        let mut want = Matrix::zeros(2, 4);
        for t in 0..3 {
            outer_acc(&mut want, &[a.get(t, 0), a.get(t, 1)], b.row(t));
        }
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_nt_matches_gemv_per_row() {
        // A: 2×3, B: 4×3 — row i of A Bᵀ is B·A(i,:).
        let a = Matrix::from_rows(vec![vec![1., 2., 3.], vec![0., -1., 0.5]]);
        let b = Matrix::from_rows(vec![
            vec![1., 0., 0.],
            vec![0., 1., 0.],
            vec![0., 0., 1.],
            vec![1., 1., 1.],
        ]);
        let mut c = Matrix::zeros(2, 4);
        gemm_nt(&mut c, &a, &b);
        for i in 0..2 {
            let mut want = vec![0.0; 4];
            gemv(&mut want, &b, a.row(i));
            assert_eq!(c.row(i), &want[..]);
        }
    }

    #[test]
    fn col_sums() {
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![3., 4.], vec![5., 6.]]);
        let mut y = vec![1.0, 0.0];
        col_sum_acc(&mut y, &a);
        assert_eq!(y, vec![10.0, 12.0]);
    }
}
