//! Dense row-major f32 matrix and the BLAS-like kernels the cores need.
//! This fills the role Eigen played in the paper's reference implementation
//! (Supp E).
//!
//! The GEMM-family kernels (`gemm`, `gemm_tn`, `gemm_nt`, `gemv`) are
//! register-blocked: a shared 4×8 micro-kernel accumulates a C tile held in
//! registers while streaming a packed k-major A panel against rows of B,
//! with unrolled bounds-check-free inner loops (fixed-size array views) so
//! LLVM emits wide FMA SIMD. `gemm_nt` additionally packs the B panel
//! (its k index is the row-contiguous one on *both* operands, so packing
//! turns the episode-length batched backward into pure streaming loads).
//! The pre-blocking scalar kernels live on in [`reference`] as the ground
//! truth for the parity tests and the `benches/kernels.rs` speedup
//! measurements (BENCH_kernels.json).
//!
//! NOTE: blocking reorders float additions relative to [`reference`], so
//! results agree to ~1e-6 relative, not bitwise. The engine-parity fixture
//! (rust/tests/engine_parity.rs) is blessed on top of the blocked kernels.
//!
//! The lane-fused kernels (`gemv_many`, `gemm_rowsweep`) are the batched-
//! training pair: they stream the shared weight operand once across L
//! independent lanes while keeping each lane's op sequence identical to
//! the single-lane `gemv`/axpy-sweep path — so batched training is bitwise
//! equal to serial training, which the micro-kernel GEMMs (reassociating)
//! could not provide. See DESIGN.md "Batched training".
//!
//! The hot kernels (`dot`, `dist_sq`, `gemv`'s row blocks, and the 4×8
//! micro-kernel) additionally dispatch once per process to explicit
//! AVX2+FMA intrinsics when the host supports them
//! ([`crate::tensor::simd`]; `SAM_FORCE_SCALAR=1` pins the scalar path).
//! The scalar bodies below are the fallback *and* the ground truth the
//! SIMD parity tests compare against; both paths share the same
//! lane/remainder structure, so cross-path drift is bounded by FMA
//! contraction (~1e-6 relative), and within one process all results are
//! bit-deterministic because the path never changes mid-run.

#[cfg(target_arch = "x86_64")]
use crate::tensor::simd::{self, KernelPath};

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Frobenius norm squared.
    pub fn norm_sq(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// self += other * scale
    pub fn axpy(&mut self, scale: f32, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += scale * b;
        }
    }

    /// Heap bytes held by this matrix (for the memory benchmarks).
    pub fn heap_bytes(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f32>()
    }
}

// ---------------------------------------------------------------------------
// Vector kernels
// ---------------------------------------------------------------------------

/// y += a * x
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product (dispatched: AVX2+FMA when the process-wide kernel path is
/// vectorized, the scalar body below otherwise).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd::kernel_path() == KernelPath::Avx2Fma {
        return unsafe { simd::avx2::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// The scalar dot body: 8 independent accumulator lanes over
/// bounds-check-free chunks (so LLVM emits wide FMA SIMD without
/// reassociating a serial reduction), serial lane sum, serial remainder —
/// the same reduction shape as the AVX2 path, which keeps cross-path drift
/// down to FMA contraction.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 8;
    let mut acc = [0.0f32; LANES];
    let (ca, ra) = a.split_at(a.len() - a.len() % LANES);
    let (cb, rb) = b.split_at(ca.len());
    for (xa, xb) in ca.chunks_exact(LANES).zip(cb.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance (dispatched like [`dot`]). The scalar body
/// is a strictly serial sum while the AVX2 path uses an 8-lane
/// accumulator, so the two *paths* reorder additions — fine, because the
/// path is fixed per process and d² values are only ever compared within
/// one run (ANN rank keys, shard merges).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd::kernel_path() == KernelPath::Avx2Fma {
        return unsafe { simd::avx2::dist_sq(a, b) };
    }
    dist_sq_scalar(a, b)
}

/// The scalar [`dist_sq`] body (serial accumulation).
#[inline]
pub fn dist_sq_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Cosine similarity with epsilon guard (the paper's d(q, M(i))).
#[inline]
pub fn cosine(a: &[f32], b: &[f32], eps: f32) -> f32 {
    dot(a, b) / (norm(a) * norm(b) + eps)
}

// ---------------------------------------------------------------------------
// Register-blocked GEMM kernels (all accumulate: C += op(A) op(B))
// ---------------------------------------------------------------------------

/// Micro-tile rows (rows of C per register block).
const MR: usize = 4;
/// Micro-tile cols (cols of C per register block).
const NR: usize = 8;

/// Public alias for the GEMM row-tile: a row of `gemm`/`gemm_nt` output is
/// computed by the register micro-kernel iff it lies inside a full MR-row
/// block (tail rows fall back to [`dot`]-shaped scalar code, which sums in
/// a different lane order). Callers that need *batch-size-independent*
/// bits — the serving tick coalesces a variable number of sessions into
/// one GEMM — pad their row count to a multiple of this so every real row
/// always takes the micro-kernel path. Within that path a row's result is
/// a serial k-order sum independent of the row's position, so the same
/// session stepped in a batch of 1 or of 64 produces identical bits.
pub const GEMM_ROW_TILE: usize = MR;

std::thread_local! {
    /// Packing scratch (A panel, B panel) reused across calls so the GEMMs
    /// allocate nothing in steady state (the zero-allocation step property
    /// extends through the episode-end gradient flush GEMMs).
    static PACK: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// The shared micro-kernel: `tile[r][c] += Σ_kk ap[kk·MR+r] · b(kk)[c]`
/// where `b(kk)` is the NR-wide slice at `bdata[bpos + kk·bstride ..]`.
/// Dispatched to the AVX2+FMA body when the process kernel path is
/// vectorized; both bodies accumulate each tile element in serial k-order,
/// so the `GEMM_ROW_TILE` batch-size-independence contract holds on either
/// path (cross-path difference is FMA contraction only).
#[inline(always)]
fn microkernel_4x8(
    kr: usize,
    ap: &[f32],
    bdata: &[f32],
    bpos: usize,
    bstride: usize,
    tile: &mut [[f32; NR]; MR],
) {
    #[cfg(target_arch = "x86_64")]
    if simd::kernel_path() == KernelPath::Avx2Fma {
        return unsafe { simd::avx2::microkernel_4x8(kr, ap, bdata, bpos, bstride, tile) };
    }
    microkernel_4x8_scalar(kr, ap, bdata, bpos, bstride, tile)
}

/// Scalar micro-kernel body: fixed-size array views keep the inner 4×8
/// fully unrolled with no bounds checks; the tile (32 floats) stays in
/// registers across the k loop.
#[inline(always)]
fn microkernel_4x8_scalar(
    kr: usize,
    ap: &[f32],
    bdata: &[f32],
    bpos: usize,
    bstride: usize,
    tile: &mut [[f32; NR]; MR],
) {
    let mut pos = bpos;
    for kk in 0..kr {
        let a4: &[f32; MR] = ap[kk * MR..kk * MR + MR].try_into().unwrap();
        let b8: &[f32; NR] = bdata[pos..pos + NR].try_into().unwrap();
        for r in 0..MR {
            for c in 0..NR {
                tile[r][c] += a4[r] * b8[c];
            }
        }
        pos += bstride;
    }
}

/// One MR-row block of C (rows i0..i0+MR over all n cols) accumulated from
/// the packed k×MR A panel `ap` against B rows at `bdata[kk·bstride..]`.
fn row_block_4(
    cdata: &mut [f32],
    cstride: usize,
    i0: usize,
    kr: usize,
    ap: &[f32],
    bdata: &[f32],
    bstride: usize,
    bcol0: usize,
    n: usize,
) {
    let mut j0 = 0;
    while j0 + NR <= n {
        let mut tile = [[0.0f32; NR]; MR];
        for (r, row) in tile.iter_mut().enumerate() {
            let base = (i0 + r) * cstride + j0;
            row.copy_from_slice(&cdata[base..base + NR]);
        }
        microkernel_4x8(kr, ap, bdata, bcol0 + j0, bstride, &mut tile);
        for (r, row) in tile.iter().enumerate() {
            let base = (i0 + r) * cstride + j0;
            cdata[base..base + NR].copy_from_slice(row);
        }
        j0 += NR;
    }
    if j0 < n {
        // Tail columns: same tile shape, dynamic width.
        let tw = n - j0;
        let mut tile = [[0.0f32; NR]; MR];
        for (r, row) in tile.iter_mut().enumerate() {
            for (c, t) in row.iter_mut().take(tw).enumerate() {
                *t = cdata[(i0 + r) * cstride + j0 + c];
            }
        }
        let mut pos = bcol0 + j0;
        for kk in 0..kr {
            let a4: &[f32; MR] = ap[kk * MR..kk * MR + MR].try_into().unwrap();
            let b = &bdata[pos..pos + tw];
            for (r, row) in tile.iter_mut().enumerate() {
                for (c, &bv) in b.iter().enumerate() {
                    row[c] += a4[r] * bv;
                }
            }
            pos += bstride;
        }
        for (r, row) in tile.iter().enumerate() {
            for (c, t) in row.iter().take(tw).enumerate() {
                cdata[(i0 + r) * cstride + j0 + c] = *t;
            }
        }
    }
}

/// y += A x  (A: m×n, x: n, y: m). Blocked over 4 rows × 8 lanes: x is
/// loaded once per 4 output elements instead of once per element. The
/// per-row summation order matches [`dot`], so results are bit-identical
/// to the reference.
pub fn gemv(y: &mut [f32], a: &Matrix, x: &[f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let m_main = a.rows - a.rows % MR;
    let mut i0 = 0;
    while i0 < m_main {
        let rows: [&[f32]; MR] = [a.row(i0), a.row(i0 + 1), a.row(i0 + 2), a.row(i0 + 3)];
        let s = gemv_block4(rows, x);
        for r in 0..MR {
            y[i0 + r] += s[r];
        }
        i0 += MR;
    }
    for i in m_main..a.rows {
        y[i] += dot(a.row(i), x);
    }
}

/// Four complete row·x dots at once (the gemv row-block body), dispatched
/// like [`dot`]. On the vectorized path each row runs exactly the AVX2
/// `dot` op sequence (x chunks shared across the 4 rows); the scalar body
/// keeps [`dot_scalar`]'s lane/remainder structure per row. Either way a
/// returned dot's bits equal `dot(rows[r], x)`, which is what makes
/// [`gemv`] — and the lane-fused [`gemv_many`] — bitwise equal to the
/// one-dot-per-row reference.
#[inline]
fn gemv_block4(rows: [&[f32]; MR], x: &[f32]) -> [f32; MR] {
    #[cfg(target_arch = "x86_64")]
    if simd::kernel_path() == KernelPath::Avx2Fma {
        return unsafe { simd::avx2::gemv_block4(rows, x) };
    }
    gemv_block4_scalar(rows, x)
}

/// Scalar body of [`gemv_block4`]: 8 accumulator lanes per row over
/// bounds-check-free NR chunks, serial lane sum, serial remainder — the
/// former inline scalar block of [`gemv`], factored out unchanged so the
/// single-x and many-x entry points share one op sequence.
#[inline]
fn gemv_block4_scalar(rows: [&[f32]; MR], x: &[f32]) -> [f32; MR] {
    let n = x.len();
    let nfull = n - n % NR;
    let mut acc = [[0.0f32; NR]; MR];
    let mut kk = 0;
    while kk < nfull {
        let xv: &[f32; NR] = x[kk..kk + NR].try_into().unwrap();
        for r in 0..MR {
            let av: &[f32; NR] = rows[r][kk..kk + NR].try_into().unwrap();
            for l in 0..NR {
                acc[r][l] += av[l] * xv[l];
            }
        }
        kk += NR;
    }
    let mut s = [0.0f32; MR];
    for r in 0..MR {
        let mut sr = acc[r].iter().sum::<f32>();
        for k in nfull..n {
            sr += rows[r][k] * x[k];
        }
        s[r] = sr;
    }
    s
}

/// Lane-fused gemv: `ys.row(l) += A · xs.row(l)` for every lane l.
///
/// This is the batched-training controller kernel (A: out×in weights,
/// xs: L×in lane inputs, ys: L×out lane outputs). The weight matrix is
/// streamed ONCE per 4-row block across all L lanes — the bandwidth win
/// over L separate [`gemv`] calls at M=1 — while each lane's per-element
/// op sequence is exactly `gemv(ys.row_mut(l), a, xs.row(l))`: every
/// output element receives one `+=` of one complete [`gemv_block4`]/
/// [`dot`] result, so lane bits are identical to the serial path at any
/// lane count and any lane position (unlike the micro-kernel GEMMs, which
/// reassociate — see the module NOTE).
pub fn gemv_many(ys: &mut Matrix, a: &Matrix, xs: &Matrix) {
    assert_eq!(ys.rows, xs.rows);
    assert_eq!(a.cols, xs.cols);
    assert_eq!(a.rows, ys.cols);
    let lanes = xs.rows;
    let m_main = a.rows - a.rows % MR;
    let mut i0 = 0;
    while i0 < m_main {
        let rows: [&[f32]; MR] = [a.row(i0), a.row(i0 + 1), a.row(i0 + 2), a.row(i0 + 3)];
        for l in 0..lanes {
            let s = gemv_block4(rows, xs.row(l));
            let y = ys.row_mut(l);
            for r in 0..MR {
                y[i0 + r] += s[r];
            }
        }
        i0 += MR;
    }
    for i in m_main..a.rows {
        for l in 0..lanes {
            ys.data[l * a.rows + i] += dot(a.row(i), xs.row(l));
        }
    }
}

/// Lane-fused axpy-sweep GEMM: `C.row(l) += A.row(l) · B` for every lane l.
///
/// The batched-training backward kernel (A: L×k lane coefficients, B: k×n
/// weights, C: L×n lane accumulators). Loop order is k outer / lanes
/// inner so each B row is streamed once across all lanes, but a fixed
/// lane's op sequence — including the `!= 0.0` sparsity skip — is exactly
/// the serial backward's `for k { if a[k] != 0 { axpy(c, a[k], B.row(k)) } }`
/// sweep, so lane bits match the serial path at any lane count.
pub fn gemm_rowsweep(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.rows, c.rows);
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.cols, b.cols);
    let (lanes, n) = (a.rows, b.cols);
    for k in 0..b.rows {
        let brow = b.row(k);
        for l in 0..lanes {
            let alk = a.get(l, k);
            if alk != 0.0 {
                axpy(&mut c.data[l * n..(l + 1) * n], alk, brow);
            }
        }
    }
}

/// y += Aᵀ x  (A: m×n, x: m, y: n)
pub fn gemv_t(y: &mut [f32], a: &Matrix, x: &[f32]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, y.len());
    for i in 0..a.rows {
        axpy(y, x[i], a.row(i));
    }
}

/// C += A B  (A: m×k, B: k×n, C: m×n).
///
/// Register-blocked: per 4-row block of C the A sub-panel is packed
/// k-major (one strided read per element, then pure streaming), and each
/// 4×8 C tile is held in registers while B rows stream through the shared
/// micro-kernel.
pub fn gemm(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let m_main = m - m % MR;
    PACK.with(|p| {
        let mut packs = p.borrow_mut();
        let ap = &mut packs.0;
        let mut i0 = 0;
        while i0 < m_main {
            ap.clear();
            for kk in 0..k {
                for r in 0..MR {
                    ap.push(a.get(i0 + r, kk));
                }
            }
            row_block_4(&mut c.data, n, i0, k, ap, &b.data, n, 0, n);
            i0 += MR;
        }
    });
    // Tail rows: axpy sweeps (the reference kernel's shape).
    for i in m_main..m {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a.get(i, kk);
            if aik != 0.0 {
                axpy(crow, aik, b.row(kk));
            }
        }
    }
}

/// C += a bᵀ (outer product; a: m, b: n, C: m×n)
pub fn outer_acc(c: &mut Matrix, a: &[f32], b: &[f32]) {
    assert_eq!(c.rows, a.len());
    assert_eq!(c.cols, b.len());
    for i in 0..a.len() {
        axpy(c.row_mut(i), a[i], b);
    }
}

/// C += Aᵀ B  (A: k×m, B: k×n, C: m×n).
///
/// This is the batched form of `outer_acc`: a stack of k outer products
/// `Σ_t A(t,:) B(t,:)ᵀ` done as one GEMM. The layers' deferred backward
/// passes use it to turn T per-step rank-1 weight-gradient updates into a
/// single cache-friendly matrix multiply over the whole episode.
///
/// Blocked exactly like [`gemm`]; the A panel pack reads *contiguous* row
/// segments here (A's k index is its row index), so the episode-length
/// backward is pure streaming.
pub fn gemm_tn(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.rows, b.rows);
    assert_eq!(c.rows, a.cols);
    assert_eq!(c.cols, b.cols);
    let (k, m, n) = (a.rows, a.cols, b.cols);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let m_main = m - m % MR;
    PACK.with(|p| {
        let mut packs = p.borrow_mut();
        let ap = &mut packs.0;
        let mut i0 = 0;
        while i0 < m_main {
            ap.clear();
            for kk in 0..k {
                ap.extend_from_slice(&a.data[kk * m + i0..kk * m + i0 + MR]);
            }
            row_block_4(&mut c.data, n, i0, k, ap, &b.data, n, 0, n);
            i0 += MR;
        }
    });
    // Tail rows of C: rank-1 sweeps restricted to the leftover A columns.
    if m_main < m {
        for kk in 0..k {
            let arow = a.row(kk);
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (i, &ati) in arow.iter().enumerate().skip(m_main) {
                if ati != 0.0 {
                    axpy(&mut c.data[i * n..(i + 1) * n], ati, brow);
                }
            }
        }
    }
}

/// C += A Bᵀ  (A: m×k, B: n×k, C: m×n).
///
/// The batched linear forward Y = X Wᵀ (X: T×in, W: out×in) is this with
/// no transposition of the stored row-major weights.
///
/// Packed-panel path: both operands are row-contiguous in k, so all 8-row
/// B panels are packed k-major up front (once — they are reused by every
/// row block) and each 4-row A panel is packed per block; the shared
/// micro-kernel then streams both packs with stride NR.
pub fn gemm_nt(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        return;
    }
    let m_main = m - m % MR;
    let n_main = n - n % NR;
    if m_main > 0 {
        PACK.with(|p| {
            let mut packs = p.borrow_mut();
            let (ap, bp) = &mut *packs;
            // Pre-pack every full 8-row B panel k-major, exactly once — the
            // panels are reused by all m/4 row blocks, so packing here keeps
            // total pack traffic at O(m·k + k·n) instead of O(m·k·n/4).
            bp.clear();
            let mut j0 = 0;
            while j0 < n_main {
                for kk in 0..k {
                    for cc in 0..NR {
                        bp.push(b.get(j0 + cc, kk));
                    }
                }
                j0 += NR;
            }
            let mut i0 = 0;
            while i0 < m_main {
                ap.clear();
                for kk in 0..k {
                    for r in 0..MR {
                        ap.push(a.get(i0 + r, kk));
                    }
                }
                let mut j0 = 0;
                let mut panel = 0usize;
                while j0 < n_main {
                    let bpanel = &bp[panel * k * NR..(panel + 1) * k * NR];
                    let mut tile = [[0.0f32; NR]; MR];
                    for (r, row) in tile.iter_mut().enumerate() {
                        let base = (i0 + r) * n + j0;
                        row.copy_from_slice(&c.data[base..base + NR]);
                    }
                    microkernel_4x8(k, ap, bpanel, 0, NR, &mut tile);
                    for (r, row) in tile.iter().enumerate() {
                        let base = (i0 + r) * n + j0;
                        c.data[base..base + NR].copy_from_slice(row);
                    }
                    j0 += NR;
                    panel += 1;
                }
                // Tail B rows: scalar dots against the 4 A rows.
                for cc in n_main..n {
                    let brow = b.row(cc);
                    for r in 0..MR {
                        c.data[(i0 + r) * n + cc] += dot(a.row(i0 + r), brow);
                    }
                }
                i0 += MR;
            }
        });
    }
    // Tail A rows: the reference kernel's per-element dots.
    for i in m_main..m {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            *cj += dot(arow, b.row(j));
        }
    }
}

/// y += Σ_t A(t, :)  (column sums; A: k×n, y: n).
pub fn col_sum_acc(y: &mut [f32], a: &Matrix) {
    assert_eq!(y.len(), a.cols);
    for t in 0..a.rows {
        axpy(y, 1.0, a.row(t));
    }
}

// ---------------------------------------------------------------------------
// Reference kernels
// ---------------------------------------------------------------------------

pub mod reference {
    //! The pre-blocking scalar kernels, kept verbatim as ground truth.
    //!
    //! Compiled into the library (not `#[cfg(test)]`) because they serve
    //! two callers: the odd-shape parity tests in this module, and
    //! `benches/kernels.rs`, which measures blocked-vs-reference GFLOP/s
    //! into BENCH_kernels.json — the perf-regression floor every future
    //! kernel change is judged against. Nothing on the hot path calls them.

    use super::{axpy, dot, Matrix};

    /// y += A x, one [`dot`] per row.
    pub fn gemv(y: &mut [f32], a: &Matrix, x: &[f32]) {
        assert_eq!(a.cols, x.len());
        assert_eq!(a.rows, y.len());
        for i in 0..a.rows {
            y[i] += dot(a.row(i), x);
        }
    }

    /// C += A B; ikj loop order, axpy sweeps.
    pub fn gemm(c: &mut Matrix, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.rows);
        assert_eq!(c.rows, a.rows);
        assert_eq!(c.cols, b.cols);
        let n = b.cols;
        for i in 0..a.rows {
            let crow = &mut c.data[i * n..(i + 1) * n];
            for k in 0..a.cols {
                let aik = a.get(i, k);
                if aik != 0.0 {
                    axpy(crow, aik, b.row(k));
                }
            }
        }
    }

    /// C += Aᵀ B as a stack of rank-1 axpy sweeps.
    pub fn gemm_tn(c: &mut Matrix, a: &Matrix, b: &Matrix) {
        assert_eq!(a.rows, b.rows);
        assert_eq!(c.rows, a.cols);
        assert_eq!(c.cols, b.cols);
        for t in 0..a.rows {
            let arow = a.row(t);
            for (i, &ati) in arow.iter().enumerate() {
                if ati != 0.0 {
                    axpy(c.row_mut(i), ati, b.row(t));
                }
            }
        }
    }

    /// C += A Bᵀ, one [`dot`] per output element.
    pub fn gemm_nt(c: &mut Matrix, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.cols);
        assert_eq!(c.rows, a.rows);
        assert_eq!(c.cols, b.rows);
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = &mut c.data[i * b.rows..(i + 1) * b.rows];
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += dot(arow, b.row(j));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Softmax and friends
// ---------------------------------------------------------------------------

/// In-place stable softmax. Returns nothing; `x` becomes the distribution.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Backward of softmax: given y = softmax(x) and dL/dy, compute dL/dx.
pub fn softmax_backward(y: &[f32], dy: &[f32], dx: &mut [f32]) {
    let s = dot(y, dy);
    for i in 0..y.len() {
        dx[i] = y[i] * (dy[i] - s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_rows(vec![vec![1., 2., 3.], vec![4., 5., 6.]]);
        let b = Matrix::from_rows(vec![vec![7., 8.], vec![9., 10.], vec![11., 12.]]);
        let mut c = Matrix::zeros(2, 2);
        gemm(&mut c, &a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
        // accumulation semantics
        gemm(&mut c, &a, &b);
        assert_eq!(c.data, vec![116., 128., 278., 308.]);
    }

    #[test]
    fn gemv_and_transpose() {
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![3., 4.], vec![5., 6.]]);
        let mut y = vec![0.0; 3];
        gemv(&mut y, &a, &[1., 1.]);
        assert_eq!(y, vec![3., 7., 11.]);
        let mut yt = vec![0.0; 2];
        gemv_t(&mut yt, &a, &[1., 1., 1.]);
        assert_eq!(yt, vec![9., 12.]);
    }

    #[test]
    fn dot_odd_lengths() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..13).map(|i| (i * i * 2) as f32).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0, 0.0, 0.0];
        let b = [1.0, 0.0, 0.0];
        let c = [-1.0, 0.0, 0.0];
        assert!((cosine(&a, &b, 1e-6) - 1.0).abs() < 1e-4);
        assert!((cosine(&a, &c, 1e-6) + 1.0).abs() < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn softmax_backward_matches_fd() {
        let x0 = vec![0.3f32, -0.7, 1.1, 0.05];
        let dy = vec![0.2f32, -0.1, 0.4, 0.3];
        let mut y = x0.clone();
        softmax_inplace(&mut y);
        let mut dx = vec![0.0; 4];
        softmax_backward(&y, &dy, &mut dx);
        let eps = 1e-3;
        for i in 0..4 {
            let mut xp = x0.clone();
            xp[i] += eps;
            softmax_inplace(&mut xp);
            let mut xm = x0.clone();
            xm[i] -= eps;
            softmax_inplace(&mut xm);
            let fd: f32 = (0..4).map(|j| (xp[j] - xm[j]) / (2.0 * eps) * dy[j]).sum();
            assert!((fd - dx[i]).abs() < 1e-3, "i={i} fd={fd} an={}", dx[i]);
        }
    }

    #[test]
    fn outer_product() {
        let mut c = Matrix::zeros(2, 3);
        outer_acc(&mut c, &[2.0, 3.0], &[1.0, 10.0, 100.0]);
        assert_eq!(c.data, vec![2., 20., 200., 3., 30., 300.]);
    }

    #[test]
    fn gemm_nt_rows_are_batch_size_independent_when_tile_padded() {
        // The serving tick's correctness contract: with the row count padded
        // to a multiple of GEMM_ROW_TILE, a given input row's output bits do
        // not depend on how many other rows share the GEMM. (Tail rows take
        // a different summation path, which is why padding matters.)
        let mut rng = Rng::new(31);
        let (k, n) = (37, 19); // deliberately odd shapes
        let w = Matrix::from_rows(
            (0..n).map(|_| (0..k).map(|_| rng.normal()).collect()).collect(),
        );
        let rows: Vec<Vec<f32>> =
            (0..GEMM_ROW_TILE * 4).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();
        // Small batch: rows[0..4] padded to one tile.
        let mut small = Matrix::zeros(GEMM_ROW_TILE, n);
        let mut a_small = Matrix::zeros(GEMM_ROW_TILE, k);
        a_small.row_mut(0).copy_from_slice(&rows[0]);
        a_small.row_mut(1).copy_from_slice(&rows[1]);
        gemm_nt(&mut small, &a_small, &w);
        // Large batch: the same two rows embedded among 16.
        let mut a_big = Matrix::from_rows(rows.clone());
        a_big.row_mut(0).copy_from_slice(&rows[0]);
        let mut big = Matrix::zeros(GEMM_ROW_TILE * 4, n);
        gemm_nt(&mut big, &a_big, &w);
        for j in 0..n {
            assert_eq!(
                small.get(0, j).to_bits(),
                big.get(0, j).to_bits(),
                "row 0 col {j} depends on batch size"
            );
            assert_eq!(small.get(1, j).to_bits(), big.get(1, j).to_bits(), "row 1 col {j}");
        }
    }

    #[test]
    fn gemm_tn_matches_stacked_outer_products() {
        // A: 3×2, B: 3×4 — Aᵀ B must equal Σ_t outer(A(t,:), B(t,:)).
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![-0.5, 3.], vec![0., 1.5]]);
        let b = Matrix::from_rows(vec![
            vec![1., 0., 2., -1.],
            vec![0.5, 1., 0., 2.],
            vec![-1., 3., 1., 0.],
        ]);
        let mut c = Matrix::zeros(2, 4);
        gemm_tn(&mut c, &a, &b);
        let mut want = Matrix::zeros(2, 4);
        for t in 0..3 {
            outer_acc(&mut want, &[a.get(t, 0), a.get(t, 1)], b.row(t));
        }
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_nt_matches_gemv_per_row() {
        // A: 2×3, B: 4×3 — row i of A Bᵀ is B·A(i,:).
        let a = Matrix::from_rows(vec![vec![1., 2., 3.], vec![0., -1., 0.5]]);
        let b = Matrix::from_rows(vec![
            vec![1., 0., 0.],
            vec![0., 1., 0.],
            vec![0., 0., 1.],
            vec![1., 1., 1.],
        ]);
        let mut c = Matrix::zeros(2, 4);
        gemm_nt(&mut c, &a, &b);
        for i in 0..2 {
            let mut want = vec![0.0; 4];
            gemv(&mut want, &b, a.row(i));
            for (x, y) in c.row(i).iter().zip(&want) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn col_sums() {
        let a = Matrix::from_rows(vec![vec![1., 2.], vec![3., 4.], vec![5., 6.]]);
        let mut y = vec![1.0, 0.0];
        col_sum_acc(&mut y, &a);
        assert_eq!(y, vec![10.0, 12.0]);
    }

    // -- blocked vs reference parity across odd shapes ----------------------

    fn random_matrix(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    /// Shapes that exercise every tile-edge case: zero/unit dims, every
    /// residue class of the 4-row and 8-col blocking, and > one full block.
    const DIMS: [usize; 9] = [0, 1, 2, 3, 4, 5, 7, 8, 17];

    fn assert_close(tag: &str, got: &Matrix, want: &Matrix) {
        assert_eq!(got.rows, want.rows);
        assert_eq!(got.cols, want.cols);
        for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
            let tol = 1e-5 * y.abs().max(1.0);
            assert!((x - y).abs() <= tol, "{tag}[{i}]: blocked {x} vs reference {y}");
        }
    }

    #[test]
    fn gemm_parity_odd_shapes() {
        let mut rng = Rng::new(101);
        for &m in &DIMS {
            for &k in &DIMS {
                for &n in &DIMS {
                    let a = random_matrix(m, k, &mut rng);
                    let b = random_matrix(k, n, &mut rng);
                    // Non-zero C start exercises accumulation semantics.
                    let mut c = random_matrix(m, n, &mut rng);
                    let mut want = c.clone();
                    gemm(&mut c, &a, &b);
                    reference::gemm(&mut want, &a, &b);
                    assert_close(&format!("gemm {m}x{k}x{n}"), &c, &want);
                }
            }
        }
    }

    #[test]
    fn gemm_tn_parity_odd_shapes() {
        let mut rng = Rng::new(102);
        for &k in &DIMS {
            for &m in &DIMS {
                for &n in &DIMS {
                    let a = random_matrix(k, m, &mut rng);
                    let b = random_matrix(k, n, &mut rng);
                    let mut c = random_matrix(m, n, &mut rng);
                    let mut want = c.clone();
                    gemm_tn(&mut c, &a, &b);
                    reference::gemm_tn(&mut want, &a, &b);
                    assert_close(&format!("gemm_tn {k}x{m}x{n}"), &c, &want);
                }
            }
        }
    }

    #[test]
    fn gemm_nt_parity_odd_shapes() {
        let mut rng = Rng::new(103);
        for &m in &DIMS {
            for &k in &DIMS {
                for &n in &DIMS {
                    let a = random_matrix(m, k, &mut rng);
                    let b = random_matrix(n, k, &mut rng);
                    let mut c = random_matrix(m, n, &mut rng);
                    let mut want = c.clone();
                    gemm_nt(&mut c, &a, &b);
                    reference::gemm_nt(&mut want, &a, &b);
                    assert_close(&format!("gemm_nt {m}x{k}x{n}"), &c, &want);
                }
            }
        }
    }

    #[test]
    fn gemv_parity_odd_shapes() {
        let mut rng = Rng::new(104);
        for &m in &DIMS {
            for &n in &DIMS {
                let a = random_matrix(m, n, &mut rng);
                let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let mut y: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
                let mut want = y.clone();
                gemv(&mut y, &a, &x);
                reference::gemv(&mut want, &a, &x);
                for (g, w) in y.iter().zip(&want) {
                    // gemv keeps dot's summation order: exact match.
                    assert_eq!(g.to_bits(), w.to_bits(), "gemv {m}x{n}");
                }
            }
        }
    }

    #[test]
    fn gemv_many_matches_gemv_per_lane_bitwise() {
        // The batched-training forward contract: every lane of gemv_many
        // carries exactly the serial gemv's bits, at any lane count and
        // any lane position.
        let mut rng = Rng::new(107);
        for &m in &DIMS {
            for &n in &DIMS {
                for lanes in [1usize, 2, 3, 8] {
                    let a = random_matrix(m, n, &mut rng);
                    let xs = random_matrix(lanes, n, &mut rng);
                    // Non-zero ys start exercises accumulation semantics.
                    let mut ys = random_matrix(lanes, m, &mut rng);
                    let mut want = ys.clone();
                    for l in 0..lanes {
                        let mut y = want.row(l).to_vec();
                        gemv(&mut y, &a, xs.row(l));
                        want.row_mut(l).copy_from_slice(&y);
                    }
                    gemv_many(&mut ys, &a, &xs);
                    for (i, (g, w)) in ys.data.iter().zip(&want.data).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "gemv_many {m}x{n} lanes={lanes} elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_rowsweep_matches_serial_axpy_sweep_bitwise() {
        // The batched-training backward contract: a fixed lane's bits match
        // the serial per-row axpy sweep (with its != 0.0 skip) exactly.
        let mut rng = Rng::new(108);
        for &k in &DIMS {
            for &n in &DIMS {
                for lanes in [1usize, 2, 5, 8] {
                    let a = random_matrix(lanes, k, &mut rng);
                    let b = random_matrix(k, n, &mut rng);
                    let mut c = random_matrix(lanes, n, &mut rng);
                    let mut want = c.clone();
                    for l in 0..lanes {
                        let crow = &mut want.data[l * n..(l + 1) * n];
                        for kk in 0..k {
                            let alk = a.get(l, kk);
                            if alk != 0.0 {
                                axpy(crow, alk, b.row(kk));
                            }
                        }
                    }
                    gemm_rowsweep(&mut c, &a, &b);
                    for (i, (g, w)) in c.data.iter().zip(&want.data).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "gemm_rowsweep k={k} n={n} lanes={lanes} elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    // -- SIMD vs scalar parity ---------------------------------------------

    /// AVX2 kernels vs the scalar bodies, across every 4/8/16 residue
    /// class. Runs only where the CPU has AVX2+FMA (the dispatcher would
    /// never pick the path elsewhere); CI's SAM_FORCE_SCALAR leg covers the
    /// env-override route end-to-end. Tolerance is FMA contraction only —
    /// both paths share lane structure and reduction order.
    #[test]
    #[cfg(target_arch = "x86_64")]
    fn simd_kernels_match_scalar_bodies() {
        use crate::tensor::simd::{avx2, host_has_avx2_fma};
        if !host_has_avx2_fma() {
            eprintln!("skipping SIMD parity: host lacks avx2+fma");
            return;
        }
        let close = |tag: &str, got: f32, want: f32| {
            let tol = 1e-5 * want.abs().max(1.0);
            assert!((got - want).abs() <= tol, "{tag}: avx2 {got} vs scalar {want}");
        };
        let mut rng = Rng::new(106);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 24, 31, 32, 33, 64] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            close(&format!("dot n={n}"), unsafe { avx2::dot(&a, &b) }, dot_scalar(&a, &b));
            close(
                &format!("dist_sq n={n}"),
                unsafe { avx2::dist_sq(&a, &b) },
                dist_sq_scalar(&a, &b),
            );
        }
        // gemv 4-row block: per-row bits must equal avx2::dot's.
        for n in [1usize, 7, 8, 9, 16, 33] {
            let rows_v: Vec<Vec<f32>> =
                (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let rows: [&[f32]; 4] =
                [&rows_v[0], &rows_v[1], &rows_v[2], &rows_v[3]];
            let s = unsafe { avx2::gemv_block4(rows, &x) };
            for r in 0..4 {
                let d = unsafe { avx2::dot(rows[r], &x) };
                assert_eq!(
                    s[r].to_bits(),
                    d.to_bits(),
                    "gemv_block4 row {r} n={n} diverges from avx2 dot"
                );
            }
        }
        // Micro-kernel: every kr residue, non-zero starting tile.
        for kr in [0usize, 1, 2, 3, 4, 5, 8, 13] {
            let ap: Vec<f32> = (0..kr * MR).map(|_| rng.normal()).collect();
            let bdata: Vec<f32> = (0..(kr.max(1)) * NR + 3).map(|_| rng.normal()).collect();
            let mut t_simd = [[0.0f32; NR]; MR];
            for row in t_simd.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.normal();
                }
            }
            let mut t_scalar = t_simd;
            unsafe { avx2::microkernel_4x8(kr, &ap, &bdata, 0, NR, &mut t_simd) };
            microkernel_4x8_scalar(kr, &ap, &bdata, 0, NR, &mut t_scalar);
            for r in 0..MR {
                for c in 0..NR {
                    close(&format!("micro kr={kr} [{r}][{c}]"), t_simd[r][c], t_scalar[r][c]);
                }
            }
        }
    }

    #[test]
    fn blocked_kernels_handle_lstm_sized_shapes() {
        // The exact shape class the LSTM backward flush produces
        // (T×4H ᵀ· T×(I|H)) at a reduced scale, against reference.
        let mut rng = Rng::new(105);
        let (t, fourh, i_dim) = (23, 36, 19);
        let dz = random_matrix(t, fourh, &mut rng);
        let x = random_matrix(t, i_dim, &mut rng);
        let mut g = Matrix::zeros(fourh, i_dim);
        let mut want = Matrix::zeros(fourh, i_dim);
        gemm_tn(&mut g, &dz, &x);
        reference::gemm_tn(&mut want, &dz, &x);
        assert_close("lstm flush", &g, &want);
    }
}
