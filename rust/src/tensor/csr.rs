//! Sparse vector and CSR matrix formats.
//!
//! These play the role Eigen's CSC/CSR formats played in the paper's
//! implementation (Supp E): sparse read/write weights `w̃`, the SDNC's
//! row-truncated temporal link matrices `N_t`/`P_t` (Supp D), and the
//! sparse gradients of Supp A. All per-step operations touch only the
//! stored non-zeros, which is what delivers the paper's O(1)-per-step
//! claims once the non-zero counts are bounded by K.

use std::collections::HashMap;

/// Sparse vector: parallel (index, value) arrays, indices strictly ascending.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SparseVec {
    pub idx: Vec<usize>,
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn new() -> SparseVec {
        SparseVec::default()
    }

    /// Build from unsorted pairs, combining duplicate indices by addition.
    pub fn from_pairs(mut pairs: Vec<(usize, f32)>) -> SparseVec {
        let mut out = SparseVec::new();
        out.assign_from_pairs(&mut pairs);
        out
    }

    /// `from_pairs` into an existing vector: sorts `pairs` in place (it is
    /// left in sorted order for recycling) and rebuilds `self` from them,
    /// reusing idx/val capacity. The workspace-pooled twin of
    /// [`SparseVec::from_pairs`] — allocation-free once capacities are warm.
    pub fn assign_from_pairs(&mut self, pairs: &mut Vec<(usize, f32)>) {
        pairs.sort_unstable_by_key(|p| p.0);
        self.idx.clear();
        self.val.clear();
        for &(i, v) in pairs.iter() {
            if let Some(&last) = self.idx.last() {
                if last == i {
                    *self.val.last_mut().unwrap() += v;
                    continue;
                }
            }
            self.idx.push(i);
            self.val.push(v);
        }
    }

    /// Remove all entries, keeping capacity.
    pub fn clear(&mut self) {
        self.idx.clear();
        self.val.clear();
    }

    /// Become a copy of `other`, reusing capacity.
    pub fn copy_from(&mut self, other: &SparseVec) {
        self.idx.clear();
        self.idx.extend_from_slice(&other.idx);
        self.val.clear();
        self.val.extend_from_slice(&other.val);
    }

    /// Append an entry with index strictly greater than the current last
    /// (caller guarantees ordering — debug-asserted).
    pub fn push(&mut self, i: usize, v: f32) {
        debug_assert!(self.idx.last().map_or(true, |&last| last < i));
        self.idx.push(i);
        self.val.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (usize, f32)> + '_ {
        self.idx.iter().copied().zip(self.val.iter().copied())
    }

    /// Value at index i (binary search), 0.0 if absent.
    pub fn get(&self, i: usize) -> f32 {
        match self.idx.binary_search(&i) {
            Ok(p) => self.val[p],
            Err(_) => 0.0,
        }
    }

    /// Scale all values in place.
    pub fn scale(&mut self, a: f32) {
        for v in &mut self.val {
            *v *= a;
        }
    }

    /// Sum of values (∑ᵢ w(i) — used by DNC precedence updates).
    pub fn sum(&self) -> f32 {
        self.val.iter().sum()
    }

    /// Sparse a + b (union of supports).
    pub fn add(&self, other: &SparseVec) -> SparseVec {
        let mut pairs: Vec<(usize, f32)> = self.iter().collect();
        pairs.extend(other.iter());
        SparseVec::from_pairs(pairs)
    }

    /// self + scale * other.
    pub fn add_scaled(&self, scale: f32, other: &SparseVec) -> SparseVec {
        let mut out = SparseVec::new();
        self.add_scaled_into(scale, other, &mut out);
        out
    }

    /// out = self + other (sorted two-pointer union merge, no allocation
    /// beyond `out`'s capacity growth).
    pub fn add_into(&self, other: &SparseVec, out: &mut SparseVec) {
        self.add_scaled_into(1.0, other, out);
    }

    /// out = self + scale·other (union merge into a reused buffer).
    pub fn add_scaled_into(&self, scale: f32, other: &SparseVec, out: &mut SparseVec) {
        out.clear();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.nnz() && j < other.nnz() {
            match self.idx[i].cmp(&other.idx[j]) {
                std::cmp::Ordering::Less => {
                    out.push(self.idx[i], self.val[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(other.idx[j], scale * other.val[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push(self.idx[i], self.val[i] + scale * other.val[j]);
                    i += 1;
                    j += 1;
                }
            }
        }
        while i < self.nnz() {
            out.push(self.idx[i], self.val[i]);
            i += 1;
        }
        while j < other.nnz() {
            out.push(other.idx[j], scale * other.val[j]);
            j += 1;
        }
    }

    /// Dot with another sparse vector (two-pointer merge).
    pub fn dot_sparse(&self, other: &SparseVec) -> f32 {
        let (mut i, mut j, mut s) = (0usize, 0usize, 0.0f32);
        while i < self.nnz() && j < other.nnz() {
            match self.idx[i].cmp(&other.idx[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    s += self.val[i] * other.val[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        s
    }

    /// Keep the k largest entries by |value| (the paper's top-K truncation).
    /// In place, allocation-free: a partial selection of the k largest
    /// followed by an insertion sort back to ascending-index order (k is
    /// small — ≤ K + 2·K_L in the SDNC).
    pub fn truncate_top_k(&mut self, k: usize) {
        if self.nnz() <= k {
            return;
        }
        for j in 0..k {
            let mut best = j;
            for t in j + 1..self.val.len() {
                if self.val[t].abs() > self.val[best].abs() {
                    best = t;
                }
            }
            self.idx.swap(j, best);
            self.val.swap(j, best);
        }
        self.idx.truncate(k);
        self.val.truncate(k);
        for a in 1..k {
            let mut b = a;
            while b > 0 && self.idx[b - 1] > self.idx[b] {
                self.idx.swap(b - 1, b);
                self.val.swap(b - 1, b);
                b -= 1;
            }
        }
    }

    /// Densify into a length-n vector.
    pub fn to_dense(&self, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; n];
        for (i, v) in self.iter() {
            out[i] = v;
        }
        out
    }

    /// Build from a dense slice keeping entries with |v| > threshold.
    pub fn from_dense_thresholded(x: &[f32], threshold: f32) -> SparseVec {
        let mut out = SparseVec::new();
        for (i, &v) in x.iter().enumerate() {
            if v.abs() > threshold {
                out.idx.push(i);
                out.val.push(v);
            }
        }
        out
    }

    pub fn heap_bytes(&self) -> usize {
        self.idx.capacity() * std::mem::size_of::<usize>()
            + self.val.capacity() * std::mem::size_of::<f32>()
    }
}

/// Row-sparse matrix: a map from row index to a dense row vector.
///
/// This is the natural format for the gradients ∂L/∂M of Supp A: only rows
/// touched by a (sparse) read in the *future* of the backward pass are live,
/// and a full-row erase kills a row outright. It also backs the SDNC's
/// K_L-truncated link matrices where each stored row has ≤ K_L non-zeros.
#[derive(Debug, Clone, Default)]
pub struct RowSparse {
    pub cols: usize,
    pub rows: HashMap<usize, Vec<f32>>,
    /// Recycled row buffers: a cleared/removed row parks here and is reused
    /// by the next insertion, so steady-state episodes (which touch the
    /// same number of rows each time) allocate nothing after warm-up.
    spare: Vec<Vec<f32>>,
}

impl RowSparse {
    pub fn new(cols: usize) -> RowSparse {
        RowSparse { cols, rows: HashMap::new(), spare: Vec::new() }
    }

    pub fn nnz_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn row(&self, i: usize) -> Option<&[f32]> {
        self.rows.get(&i).map(|v| v.as_slice())
    }

    /// Mutable access, inserting a zero row (recycled if available) if absent.
    pub fn row_mut(&mut self, i: usize) -> &mut Vec<f32> {
        let cols = self.cols;
        let spare = &mut self.spare;
        self.rows.entry(i).or_insert_with(|| match spare.pop() {
            Some(mut v) => {
                v.clear();
                v.resize(cols, 0.0);
                v
            }
            None => vec![0.0; cols],
        })
    }

    /// row(i) += a * x
    pub fn axpy_row(&mut self, i: usize, a: f32, x: &[f32]) {
        assert_eq!(x.len(), self.cols);
        let r = self.row_mut(i);
        for (ri, xi) in r.iter_mut().zip(x) {
            *ri += a * xi;
        }
    }

    pub fn clear_row(&mut self, i: usize) {
        if let Some(v) = self.rows.remove(&i) {
            self.spare.push(v);
        }
    }

    /// Drop all rows, retaining their buffers for reuse.
    pub fn clear(&mut self) {
        // HashMap::drain keeps the map's bucket capacity.
        let spare = &mut self.spare;
        spare.extend(self.rows.drain().map(|(_, v)| v));
    }

    /// Live rows only — the spare pool is scratch, not per-episode state.
    pub fn heap_bytes(&self) -> usize {
        self.rows.len() * (self.cols * std::mem::size_of::<f32>() + 64)
    }
}

/// CSR matrix with a bounded number of non-zeros per row (the SDNC's
/// `N_t`, `P_t` ∈ [0,1]^{N×N} with ≤ K_L entries per row, Supp D eq 17-20).
///
/// Rows are stored in a HashMap keyed by row index so that the structure
/// costs O(#touched-rows), not O(N): for the SDNC only rows that were ever
/// written to exist at all.
#[derive(Debug, Clone, Default)]
pub struct SparseLinkMatrix {
    /// Per-row sparse entries (col -> value), each row holds ≤ k_max entries.
    pub rows: HashMap<usize, SparseVec>,
    pub k_max: usize,
}

impl SparseLinkMatrix {
    pub fn new(k_max: usize) -> SparseLinkMatrix {
        SparseLinkMatrix { rows: HashMap::new(), k_max }
    }

    pub fn row(&self, i: usize) -> Option<&SparseVec> {
        self.rows.get(&i)
    }

    /// Remove and return row i by move (for journaled updates that revert
    /// by re-inserting the old row — no clone needed).
    pub fn take_row(&mut self, i: usize) -> Option<SparseVec> {
        self.rows.remove(&i)
    }

    /// Replace row i, truncating to the k_max largest entries. Returns
    /// displaced storage (the old row if any, or the new one if it
    /// truncated to empty) so hot-path callers can recycle it; callers that
    /// `take_row`-ed first get at most one buffer back.
    pub fn set_row_recycling(&mut self, i: usize, mut row: SparseVec) -> Option<SparseVec> {
        row.truncate_top_k(self.k_max);
        if row.nnz() == 0 {
            match self.rows.remove(&i) {
                Some(old) => Some(old),
                None => Some(row),
            }
        } else {
            self.rows.insert(i, row)
        }
    }

    /// Replace row i, truncating to the k_max largest entries.
    pub fn set_row(&mut self, i: usize, row: SparseVec) {
        let _ = self.set_row_recycling(i, row);
    }

    /// y = Self · w  for sparse w: only rows in `row_filter` (the candidate
    /// output support) need evaluating. For the SDNC the candidate support
    /// is the set of rows that exist, intersected per eq (21).
    pub fn mul_sparse(&self, w: &SparseVec) -> SparseVec {
        // Touch only existing rows: O(#rows * K_L) worst case, but callers
        // keep #rows bounded by the write history, and the product of two
        // K-sparse structures is cheap.
        let mut pairs = Vec::new();
        for (&i, row) in &self.rows {
            let v = row.dot_sparse(w);
            if v != 0.0 {
                pairs.push((i, v));
            }
        }
        SparseVec::from_pairs(pairs)
    }

    pub fn nnz(&self) -> usize {
        self.rows.values().map(|r| r.nnz()).sum()
    }

    pub fn heap_bytes(&self) -> usize {
        self.rows.values().map(|r| r.heap_bytes() + 64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pairs_sorts_and_merges() {
        let v = SparseVec::from_pairs(vec![(5, 1.0), (2, 2.0), (5, 3.0)]);
        assert_eq!(v.idx, vec![2, 5]);
        assert_eq!(v.val, vec![2.0, 4.0]);
    }

    #[test]
    fn get_and_dense_roundtrip() {
        let v = SparseVec::from_pairs(vec![(1, 0.5), (7, -2.0)]);
        assert_eq!(v.get(1), 0.5);
        assert_eq!(v.get(3), 0.0);
        let d = v.to_dense(10);
        assert_eq!(d[7], -2.0);
        let back = SparseVec::from_dense_thresholded(&d, 0.0);
        assert_eq!(back, v);
    }

    #[test]
    fn add_scaled_union() {
        let a = SparseVec::from_pairs(vec![(0, 1.0), (2, 1.0)]);
        let b = SparseVec::from_pairs(vec![(2, 1.0), (5, 4.0)]);
        let c = a.add_scaled(0.5, &b);
        assert_eq!(c.to_dense(6), vec![1.0, 0.0, 1.5, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn dot_sparse_matches_dense() {
        let a = SparseVec::from_pairs(vec![(1, 2.0), (4, 3.0), (9, -1.0)]);
        let b = SparseVec::from_pairs(vec![(0, 5.0), (4, 2.0), (9, 2.0)]);
        let dense: f32 = a
            .to_dense(10)
            .iter()
            .zip(b.to_dense(10).iter())
            .map(|(x, y)| x * y)
            .sum();
        assert_eq!(a.dot_sparse(&b), dense);
    }

    #[test]
    fn truncate_keeps_largest() {
        let mut v = SparseVec::from_pairs(vec![(0, 0.1), (1, -5.0), (2, 2.0), (3, 0.01)]);
        v.truncate_top_k(2);
        assert_eq!(v.idx, vec![1, 2]);
        assert_eq!(v.val, vec![-5.0, 2.0]);
    }

    #[test]
    fn row_sparse_axpy() {
        let mut m = RowSparse::new(3);
        m.axpy_row(7, 2.0, &[1.0, 0.0, 3.0]);
        m.axpy_row(7, 1.0, &[0.0, 1.0, 0.0]);
        assert_eq!(m.row(7).unwrap(), &[2.0, 1.0, 6.0]);
        assert!(m.row(0).is_none());
        m.clear_row(7);
        assert_eq!(m.nnz_rows(), 0);
    }

    #[test]
    fn link_matrix_mul_matches_dense() {
        // 4x4 dense reference
        let mut lm = SparseLinkMatrix::new(3);
        lm.set_row(0, SparseVec::from_pairs(vec![(1, 0.5), (2, 0.5)]));
        lm.set_row(2, SparseVec::from_pairs(vec![(3, 1.0)]));
        let w = SparseVec::from_pairs(vec![(1, 1.0), (3, 2.0)]);
        let y = lm.mul_sparse(&w);
        // row0 . w = 0.5, row2 . w = 2.0
        assert_eq!(y.to_dense(4), vec![0.5, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn link_matrix_row_truncation() {
        let mut lm = SparseLinkMatrix::new(2);
        lm.set_row(
            0,
            SparseVec::from_pairs(vec![(0, 0.9), (1, 0.1), (2, 0.5), (3, 0.01)]),
        );
        assert_eq!(lm.row(0).unwrap().nnz(), 2);
        assert_eq!(lm.row(0).unwrap().idx, vec![0, 2]);
    }
}
