//! Reusable scratch arena for the step hot path.
//!
//! Every per-step buffer the cores, engine and layers used to allocate
//! fresh (`vec![0.0; ..]`, `to_vec()`, `SparseVec::from_pairs`) now comes
//! out of a [`Workspace`] and is recycled back when its step is
//! backpropagated. After a warm-up episode the pools hold every buffer the
//! episode shape needs, so steady-state episode execution performs **zero
//! heap allocations** (asserted by `rust/tests/zero_alloc.rs`).
//!
//! Design rules (see DESIGN.md "Kernels & workspace"):
//!
//! * A workspace is **purely an optimization**: buffers handed out are
//!   ordinary `Vec`s, zeroed/cleared exactly as a fresh allocation would
//!   be, so *which* workspace serves a call can never change numerics.
//! * `f32`/`usize` buffers are pooled in power-of-two capacity classes: a
//!   `take_*(len)` is served by a buffer of capacity ≥ `len`'s class, so a
//!   small recycled buffer is never grown for a large request (which would
//!   reallocate every episode).
//! * Buffers must be recycled to the workspace they were taken from.
//!   Ownership is therefore simple: each core owns one `Workspace` and
//!   threads `&mut` through its engine calls; `Lstm`/`Linear` own private
//!   workspaces because their tape buffers never escape the layer.
//! * Fixed-shape per-step scratch (controller concatenation buffers, dense
//!   gradient accumulators) uses plain persistent `Vec` fields instead —
//!   pooling only pays where buffers live on a tape with O(T) of them.

use super::csr::SparseVec;
use super::matrix::Matrix;

/// Number of power-of-two capacity classes (class c holds buffers with
/// capacity ≥ 2^c); 48 covers any realistic allocation.
const CLASSES: usize = 48;

/// Capacity class for a request of `len` elements: smallest c with
/// 2^c ≥ len.
#[inline]
fn class_of_len(len: usize) -> usize {
    (len.max(1).next_power_of_two().trailing_zeros() as usize).min(CLASSES - 1)
}

/// Capacity class a buffer with `cap` elements can *serve*: largest c with
/// 2^c ≤ cap.
#[inline]
fn class_of_cap(cap: usize) -> usize {
    debug_assert!(cap > 0);
    ((usize::BITS - 1 - cap.leading_zeros()) as usize).min(CLASSES - 1)
}

/// A single-class LIFO free list for arbitrary element types. Buffer
/// capacities grow monotonically toward the maximum ever requested, so a
/// deterministic take/recycle cycle stops allocating after warm-up.
#[derive(Debug, Default)]
pub struct Pool<T> {
    free: Vec<Vec<T>>,
}

impl<T> Pool<T> {
    pub fn new() -> Pool<T> {
        Pool { free: Vec::new() }
    }

    /// Pop a cleared buffer (empty, capacity retained) or a fresh one.
    pub fn take(&mut self) -> Vec<T> {
        self.free.pop().unwrap_or_default()
    }

    pub fn recycle(&mut self, mut v: Vec<T>) {
        v.clear();
        if v.capacity() > 0 {
            self.free.push(v);
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.free.iter().map(|v| v.capacity() * std::mem::size_of::<T>()).sum::<usize>()
            + self.free.capacity() * std::mem::size_of::<Vec<T>>()
    }
}

/// The scratch arena. See module docs for ownership rules.
#[derive(Debug)]
pub struct Workspace {
    f32s: [Vec<Vec<f32>>; CLASSES],
    usizes: [Vec<Vec<usize>>; CLASSES],
    pairs: Pool<(usize, f32)>,
    sparse: Vec<SparseVec>,
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace {
            f32s: std::array::from_fn(|_| Vec::new()),
            usizes: std::array::from_fn(|_| Vec::new()),
            pairs: Pool::new(),
            sparse: Vec::new(),
        }
    }

    // -- f32 buffers --------------------------------------------------------

    fn pop_f32(&mut self, len: usize) -> Vec<f32> {
        let c = class_of_len(len);
        self.f32s[c].pop().unwrap_or_else(|| Vec::with_capacity(1usize << c))
    }

    /// A zero-filled buffer of exactly `len` elements — drop-in for
    /// `vec![0.0; len]`.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.pop_f32(len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// A copy of `src` — drop-in for `src.to_vec()`.
    pub fn take_f32_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.pop_f32(src.len());
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// An empty buffer of capacity ≥ `cap_hint` (for push-style building).
    /// The hint must match the eventual fill size's class, or the buffer
    /// will migrate classes between take and recycle and miss the pool.
    pub fn take_f32_empty(&mut self, cap_hint: usize) -> Vec<f32> {
        let c = class_of_len(cap_hint);
        let mut v = self.f32s[c].pop().unwrap_or_else(|| Vec::with_capacity(1usize << c));
        v.clear();
        v
    }

    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let c = class_of_cap(v.capacity());
        self.f32s[c].push(v);
    }

    // -- usize buffers ------------------------------------------------------

    /// An empty index buffer of capacity ≥ `cap_hint`.
    pub fn take_usize(&mut self, cap_hint: usize) -> Vec<usize> {
        let c = class_of_len(cap_hint);
        let mut v = self.usizes[c].pop().unwrap_or_else(|| Vec::with_capacity(1usize << c));
        v.clear();
        v
    }

    pub fn recycle_usize(&mut self, v: Vec<usize>) {
        if v.capacity() == 0 {
            return;
        }
        let c = class_of_cap(v.capacity());
        self.usizes[c].push(v);
    }

    // -- (index, value) pair buffers (SparseVec assembly) -------------------

    pub fn take_pairs(&mut self) -> Vec<(usize, f32)> {
        self.pairs.take()
    }

    pub fn recycle_pairs(&mut self, v: Vec<(usize, f32)>) {
        self.pairs.recycle(v);
    }

    // -- sparse vectors -----------------------------------------------------

    /// An empty sparse vector (idx/val capacities retained from recycling).
    pub fn take_sparse(&mut self) -> SparseVec {
        let mut sv = self.sparse.pop().unwrap_or_default();
        sv.clear();
        sv
    }

    /// A copy of `src`.
    pub fn take_sparse_copy(&mut self, src: &SparseVec) -> SparseVec {
        let mut sv = self.take_sparse();
        sv.copy_from(src);
        sv
    }

    pub fn recycle_sparse(&mut self, mut sv: SparseVec) {
        // Capacity-less shells (e.g. `mem::take` leftovers of reset
        // recurrent state) are dropped, not pooled: pooling them would make
        // a later take grow a 0-capacity buffer — an allocation — while the
        // matching real buffer idles deeper in the stack.
        if sv.idx.capacity() == 0 && sv.val.capacity() == 0 {
            return;
        }
        sv.clear();
        self.sparse.push(sv);
    }

    // -- matrices -----------------------------------------------------------

    /// A zero-filled rows×cols matrix backed by the f32 pool.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_f32(rows * cols))
    }

    pub fn recycle_matrix(&mut self, m: Matrix) {
        self.recycle_f32(m.data);
    }

    // -- accounting ---------------------------------------------------------

    /// Bytes parked in the pools (scratch, not per-episode state).
    pub fn heap_bytes(&self) -> usize {
        let f: usize = self
            .f32s
            .iter()
            .map(|c| c.iter().map(|v| v.capacity() * 4).sum::<usize>())
            .sum();
        let u: usize = self
            .usizes
            .iter()
            .map(|c| c.iter().map(|v| v.capacity() * std::mem::size_of::<usize>()).sum::<usize>())
            .sum();
        let s: usize = self.sparse.iter().map(|v| v.heap_bytes()).sum();
        f + u + s + self.pairs.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_f32_is_zeroed_after_reuse() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f32(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        ws.recycle_f32(v);
        let v2 = ws.take_f32(8);
        assert_eq!(v2, vec![0.0; 8]);
    }

    #[test]
    fn classes_keep_small_requests_off_big_buffers() {
        let mut ws = Workspace::new();
        let big = ws.take_f32(1000);
        let big_ptr = big.as_ptr();
        ws.recycle_f32(big);
        // A small request must not consume the big buffer's class.
        let small = ws.take_f32(4);
        assert_ne!(small.as_ptr(), big_ptr);
        // The big request gets its buffer back.
        let big2 = ws.take_f32(900);
        assert_eq!(big2.as_ptr(), big_ptr);
    }

    #[test]
    fn steady_state_take_recycle_does_not_allocate() {
        let mut ws = Workspace::new();
        // Warm up.
        for _ in 0..3 {
            let a = ws.take_f32(100);
            let b = ws.take_f32_copy(&[1.0; 33]);
            let s = ws.take_sparse();
            ws.recycle_sparse(s);
            ws.recycle_f32(a);
            ws.recycle_f32(b);
        }
        let before = crate::util::alloc::thread_alloc_count();
        for _ in 0..10 {
            let a = ws.take_f32(100);
            let b = ws.take_f32_copy(&[1.0; 33]);
            let s = ws.take_sparse();
            ws.recycle_sparse(s);
            ws.recycle_f32(a);
            ws.recycle_f32(b);
        }
        assert_eq!(crate::util::alloc::thread_alloc_count() - before, 0);
    }

    #[test]
    fn matrix_roundtrip() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix(3, 4);
        assert_eq!(m.rows, 3);
        assert_eq!(m.cols, 4);
        assert!(m.data.iter().all(|&x| x == 0.0));
        ws.recycle_matrix(m);
        let m2 = ws.take_matrix(2, 5);
        assert_eq!(m2.data.len(), 10);
    }

    #[test]
    fn class_math() {
        assert_eq!(class_of_len(1), 0);
        assert_eq!(class_of_len(2), 1);
        assert_eq!(class_of_len(3), 2);
        assert_eq!(class_of_len(1024), 10);
        assert_eq!(class_of_cap(1024), 10);
        assert_eq!(class_of_cap(1500), 10);
        assert_eq!(class_of_cap(2048), 11);
        // A class-c buffer always satisfies a class-c request.
        for len in 1..200usize {
            let c = class_of_len(len);
            assert!((1usize << c) >= len);
        }
    }
}
