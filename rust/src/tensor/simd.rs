//! Runtime-dispatched SIMD micro-kernels (AVX2+FMA) for the crate's hot
//! float loops, plus the dispatch-once kernel-path selector.
//!
//! ## Dispatch contract
//!
//! The kernel path is chosen **once per process** ([`kernel_path`], backed
//! by a `OnceLock`): `SAM_FORCE_SCALAR=1` forces the scalar path, otherwise
//! x86-64 hosts with AVX2+FMA take the vectorized path and everything else
//! (including non-x86 targets) falls back to the scalar kernels that live
//! on in `tensor::matrix`. One process therefore never mixes paths — every
//! dot/gemv/gemm/scan in a run sums floats in the same order, which is what
//! keeps per-run determinism (fixtures, shard parity, rollback
//! bit-exactness) intact even though the *two paths disagree in the low
//! bits* (SIMD reorders float additions; this is exactly DESIGN.md's
//! re-bless case, and `rust/tests/engine_parity.rs` records the blessed
//! path in its fixture header so a fixture is only enforced on the path
//! that produced it).
//!
//! ## Summation-order contract (per kernel)
//!
//! * [`avx2::dot`] — one 8-lane FMA accumulator over 8-element chunks,
//!   lanes reduced serially in lane order 0..8, then a serial scalar
//!   remainder. This is the *same shape* as the scalar `matrix::dot`
//!   (8 independent lanes, serial lane sum, serial remainder); the only
//!   cross-path difference is FMA contraction (no intermediate rounding of
//!   the products).
//! * [`avx2::gemv_block4`] — each of the 4 rows runs exactly the
//!   [`avx2::dot`] op sequence (the x chunk is loaded once and shared),
//!   so blocked-gemv bits == dot bits on this path, mirroring the scalar
//!   guarantee `gemv_parity_odd_shapes` pins.
//! * [`avx2::microkernel_4x8`] — every C-tile element is a serial k-order
//!   FMA sum, same k order as the scalar micro-kernel, preserving the
//!   `GEMM_ROW_TILE` batch-size-independence contract within the path.
//! * [`avx2::dist_sq`] — 8-lane sub+FMA accumulator (the scalar `dist_sq`
//!   is a strictly serial sum, so the two paths reorder; ANN rank keys are
//!   only compared within one process, where the path is fixed).
//!
//! ## Compact-row kernels
//!
//! The bf16/int8 variants fuse the row decode into the scan loop — bf16
//! widens `u16 → f32` by a 16-bit shift in-register, int8 sign-extends and
//! converts with the per-row scale applied either per lane (`dist_sq_i8`,
//! where the subtraction needs decoded values) or hoisted out of the loop
//! entirely (`dot_normsq_i8` returns `scale·Σ q·r` / `scale²·Σ r·r`).
//! **Accumulation is always f32** regardless of the storage format; no
//! materialized f32 copy of a row is ever built.

use std::sync::OnceLock;

/// Which kernel implementation this process dispatches to (chosen once).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelPath {
    /// x86-64 AVX2+FMA intrinsics ([`avx2`]).
    Avx2Fma,
    /// The portable scalar kernels in `tensor::matrix` / `tensor::rowcodec`.
    Scalar,
}

impl KernelPath {
    /// Short stable name recorded in BENCH_*.json payloads and the parity
    /// fixture header ("avx2" | "scalar").
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Avx2Fma => "avx2",
            KernelPath::Scalar => "scalar",
        }
    }
}

static PATH: OnceLock<KernelPath> = OnceLock::new();

/// The dispatch decision as a pure function of its inputs, separated from
/// the process-global `OnceLock` so tests can exercise both branches in one
/// process (the lock fires once; CI's forced-scalar leg covers the env
/// override end-to-end).
#[inline]
pub fn detect_path(force_scalar: bool, has_avx2_fma: bool) -> KernelPath {
    if force_scalar || !has_avx2_fma {
        KernelPath::Scalar
    } else {
        KernelPath::Avx2Fma
    }
}

/// Runtime CPU probe: true iff this host can execute the AVX2+FMA kernels.
#[cfg(target_arch = "x86_64")]
pub fn host_has_avx2_fma() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Non-x86 targets never take the AVX2 path (NEON is covered by the scalar
/// kernels' auto-vectorization for now).
#[cfg(not(target_arch = "x86_64"))]
pub fn host_has_avx2_fma() -> bool {
    false
}

/// The process-wide kernel path. First call reads `SAM_FORCE_SCALAR` and
/// probes the CPU; every later call returns the cached decision.
#[inline]
pub fn kernel_path() -> KernelPath {
    *PATH.get_or_init(|| {
        let force = std::env::var("SAM_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false);
        detect_path(force, host_has_avx2_fma())
    })
}

/// `kernel_path().name()` — the string benches and fixtures record.
pub fn kernel_path_name() -> &'static str {
    kernel_path().name()
}

/// AVX2+FMA kernel bodies. Every function is `unsafe` with
/// `#[target_feature(enable = "avx2,fma")]`; callers must have checked
/// [`kernel_path`] == [`KernelPath::Avx2Fma`] (which implies the CPU probe
/// passed) before calling.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::*;

    /// Serial lane-order reduction of one 8-lane accumulator — the same
    /// order as the scalar kernels' `acc.iter().sum::<f32>()`.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(acc: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        lanes.iter().sum::<f32>()
    }

    /// Widen 8 bf16 values (stored as the high 16 bits of an f32) to f32.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn load_bf16_8(p: *const u16) -> __m256 {
        let half = _mm_loadu_si128(p as *const __m128i);
        let wide = _mm256_cvtepu16_epi32(half);
        _mm256_castsi256_ps(_mm256_slli_epi32(wide, 16))
    }

    /// Sign-extend 8 int8 codes and convert to f32 (scale not applied).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn load_i8_8(p: *const i8) -> __m256 {
        let bytes = _mm_loadl_epi64(p as *const __m128i);
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(bytes))
    }

    /// Dot product; see the module docs for the summation-order contract.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let main = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_fmadd_ps(av, bv, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        for j in main..n {
            s += a[j] * b[j];
        }
        s
    }

    /// Squared Euclidean distance (8-lane sub+FMA accumulator).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let main = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let d = _mm256_sub_ps(av, bv);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        for j in main..n {
            let d = a[j] - b[j];
            s += d * d;
        }
        s
    }

    /// Four gemv rows against one shared x: each row runs exactly the
    /// [`dot`] op sequence (x chunks loaded once), returning the four full
    /// row sums including the scalar remainder.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemv_block4(rows: [&[f32]; 4], x: &[f32]) -> [f32; 4] {
        let n = x.len();
        let main = n - n % 8;
        let mut acc = [_mm256_setzero_ps(); 4];
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            for r in 0..4 {
                let av = _mm256_loadu_ps(rows[r].as_ptr().add(i));
                acc[r] = _mm256_fmadd_ps(av, xv, acc[r]);
            }
            i += 8;
        }
        let mut out = [0.0f32; 4];
        for r in 0..4 {
            let mut s = hsum(acc[r]);
            for k in main..n {
                s += rows[r][k] * x[k];
            }
            out[r] = s;
        }
        out
    }

    /// The 4×8 GEMM micro-kernel: `tile[r][c] += Σ_kk ap[kk·4+r]·b(kk)[c]`
    /// with `b(kk) = bdata[bpos + kk·bstride ..][..8]`. Serial k-order per
    /// tile element, matching the scalar micro-kernel.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn microkernel_4x8(
        kr: usize,
        ap: &[f32],
        bdata: &[f32],
        bpos: usize,
        bstride: usize,
        tile: &mut [[f32; 8]; 4],
    ) {
        let mut c0 = _mm256_loadu_ps(tile[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(tile[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(tile[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(tile[3].as_ptr());
        let mut pos = bpos;
        for kk in 0..kr {
            debug_assert!(pos + 8 <= bdata.len() && kk * 4 + 4 <= ap.len());
            let b8 = _mm256_loadu_ps(bdata.as_ptr().add(pos));
            let a = ap.as_ptr().add(kk * 4);
            c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), b8, c0);
            c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), b8, c1);
            c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), b8, c2);
            c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), b8, c3);
            pos += bstride;
        }
        _mm256_storeu_ps(tile[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(tile[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(tile[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(tile[3].as_mut_ptr(), c3);
    }

    // -- compact-row (fused decode) kernels ---------------------------------

    /// Fused `(q·row, row·row)` over a bf16 row — one pass, two FMA
    /// accumulators, decode in-register, f32 accumulation.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_normsq_bf16(q: &[f32], row: &[u16]) -> (f32, f32) {
        debug_assert_eq!(q.len(), row.len());
        let n = q.len();
        let main = n - n % 8;
        let mut accq = _mm256_setzero_ps();
        let mut accn = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let rv = load_bf16_8(row.as_ptr().add(i));
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            accq = _mm256_fmadd_ps(qv, rv, accq);
            accn = _mm256_fmadd_ps(rv, rv, accn);
            i += 8;
        }
        let mut sq = hsum(accq);
        let mut sn = hsum(accn);
        for j in main..n {
            let r = f32::from_bits((row[j] as u32) << 16);
            sq += q[j] * r;
            sn += r * r;
        }
        (sq, sn)
    }

    /// Squared distance from `q` to a bf16 row, decode fused into the loop.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dist_sq_bf16(q: &[f32], row: &[u16]) -> f32 {
        debug_assert_eq!(q.len(), row.len());
        let n = q.len();
        let main = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let rv = load_bf16_8(row.as_ptr().add(i));
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            let d = _mm256_sub_ps(qv, rv);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        for j in main..n {
            let d = q[j] - f32::from_bits((row[j] as u32) << 16);
            s += d * d;
        }
        s
    }

    /// `out += coeff · decode(row)` over a bf16 row.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_bf16(out: &mut [f32], coeff: f32, row: &[u16]) {
        debug_assert_eq!(out.len(), row.len());
        let n = out.len();
        let main = n - n % 8;
        let cv = _mm256_set1_ps(coeff);
        let mut i = 0;
        while i < main {
            let rv = load_bf16_8(row.as_ptr().add(i));
            let ov = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(cv, rv, ov));
            i += 8;
        }
        for j in main..n {
            out[j] += coeff * f32::from_bits((row[j] as u32) << 16);
        }
    }

    /// Fused `(q·row, row·row)` over an int8 row: accumulates against the
    /// raw codes and applies `scale` / `scale²` once at the end.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_normsq_i8(q: &[f32], row: &[i8], scale: f32) -> (f32, f32) {
        debug_assert_eq!(q.len(), row.len());
        let n = q.len();
        let main = n - n % 8;
        let mut accq = _mm256_setzero_ps();
        let mut accn = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let rv = load_i8_8(row.as_ptr().add(i));
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            accq = _mm256_fmadd_ps(qv, rv, accq);
            accn = _mm256_fmadd_ps(rv, rv, accn);
            i += 8;
        }
        let mut sq = hsum(accq);
        let mut sn = hsum(accn);
        for j in main..n {
            let r = row[j] as f32;
            sq += q[j] * r;
            sn += r * r;
        }
        (scale * sq, scale * scale * sn)
    }

    /// Squared distance from `q` to an int8 row (scale applied per lane —
    /// the subtraction needs decoded values).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dist_sq_i8(q: &[f32], row: &[i8], scale: f32) -> f32 {
        debug_assert_eq!(q.len(), row.len());
        let n = q.len();
        let main = n - n % 8;
        let sv = _mm256_set1_ps(scale);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let rv = _mm256_mul_ps(load_i8_8(row.as_ptr().add(i)), sv);
            let qv = _mm256_loadu_ps(q.as_ptr().add(i));
            let d = _mm256_sub_ps(qv, rv);
            acc = _mm256_fmadd_ps(d, d, acc);
            i += 8;
        }
        let mut s = hsum(acc);
        for j in main..n {
            let d = q[j] - row[j] as f32 * scale;
            s += d * d;
        }
        s
    }

    /// `out += (coeff·scale) · codes` over an int8 row — the caller folds
    /// the row scale into the coefficient.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_i8(out: &mut [f32], coeff_times_scale: f32, row: &[i8]) {
        debug_assert_eq!(out.len(), row.len());
        let n = out.len();
        let main = n - n % 8;
        let cv = _mm256_set1_ps(coeff_times_scale);
        let mut i = 0;
        while i < main {
            let rv = load_i8_8(row.as_ptr().add(i));
            let ov = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(cv, rv, ov));
            i += 8;
        }
        for j in main..n {
            out[j] += coeff_times_scale * row[j] as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_path_truth_table() {
        assert_eq!(detect_path(false, true), KernelPath::Avx2Fma);
        assert_eq!(detect_path(true, true), KernelPath::Scalar);
        assert_eq!(detect_path(false, false), KernelPath::Scalar);
        assert_eq!(detect_path(true, false), KernelPath::Scalar);
    }

    #[test]
    fn kernel_path_is_stable_and_named() {
        let p = kernel_path();
        assert_eq!(p, kernel_path(), "dispatch must be chosen once");
        assert!(matches!(kernel_path_name(), "avx2" | "scalar"));
        // If the env override is set (CI's forced-scalar leg), the cached
        // decision must honor it.
        if std::env::var("SAM_FORCE_SCALAR").as_deref() == Ok("1") {
            assert_eq!(p, KernelPath::Scalar);
        }
    }
}
