//! Compact memory-row storage: f32 | bf16 | int8 (per-row-scaled) codecs
//! behind one [`RowStore`], with decode fused into every read kernel.
//!
//! ## Contract
//!
//! * **f32 accumulation everywhere.** Whatever the storage format, every
//!   kernel decodes lanes in-register and accumulates in f32 — compact rows
//!   change memory traffic, never the accumulator type, and no kernel ever
//!   materializes an f32 copy of a row to scan it.
//! * **bf16** stores the high 16 bits of the f32 pattern, encoded with
//!   round-to-nearest-even. `encode(decode(x))` is the identity (every bf16
//!   value is exactly representable in f32), which is what makes the
//!   journal/revert cycle bit-exact for bf16 rows.
//! * **int8** stores one signed byte per value plus one f32 scale per row
//!   (`scale = maxabs/127`, zero rows get scale 0): `decode = code·scale`.
//!   Re-encoding a decoded row *with its saved scale* recovers the original
//!   codes exactly (the decode error per value is ≪ half a quantization
//!   step), so revert restores identical storage bits; see
//!   [`RowStore::set_row_with_scale`].
//! * **Training is f32-only.** Compact formats are serve/eval-only: the
//!   backward paths borrow rows as `&[f32]` ([`RowStore::row`] panics on
//!   compact formats) and the CLI validates `--row-format` up front.
//!
//! The AVX2 fused-decode kernels live in [`crate::tensor::simd::avx2`];
//! this module holds the codec, the scalar fallbacks, and the per-call
//! dispatch on [`crate::tensor::simd::kernel_path`].

use crate::tensor::matrix::{axpy, dist_sq, dot};
use crate::tensor::simd::{kernel_path, KernelPath};

/// Storage format for memory rows (`--row-format f32|bf16|int8`).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum RowFormat {
    /// 4 bytes/value; the training format and the default everywhere.
    #[default]
    F32,
    /// 2 bytes/value, ~2× scan bandwidth, ≤2⁻⁸ relative rounding error.
    Bf16,
    /// 1 byte/value + one f32 scale per row, ~4× scan bandwidth,
    /// ≤ scale/2 absolute error per value.
    Int8,
}

impl RowFormat {
    /// Stable name recorded in BENCH_*.json payloads and `--row-format`.
    pub fn name(self) -> &'static str {
        match self {
            RowFormat::F32 => "f32",
            RowFormat::Bf16 => "bf16",
            RowFormat::Int8 => "int8",
        }
    }

    /// Whether the training path accepts this format (compact rows are
    /// serve/eval-only: the backward pass borrows rows as `&[f32]`).
    pub fn train_legal(self) -> bool {
        self == RowFormat::F32
    }
}

impl std::str::FromStr for RowFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<RowFormat, String> {
        match s {
            "f32" => Ok(RowFormat::F32),
            "bf16" => Ok(RowFormat::Bf16),
            "int8" => Ok(RowFormat::Int8),
            other => Err(format!("unknown row format '{other}' (expected f32|bf16|int8)")),
        }
    }
}

/// bf16 → f32: exact (bf16 is a prefix of the f32 bit pattern).
#[inline]
pub fn bf16_to_f32(u: u16) -> f32 {
    f32::from_bits((u as u32) << 16)
}

/// f32 → bf16 with round-to-nearest-even (NaN payloads kept non-signaling).
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Truncate but force a nonzero mantissa so the NaN survives.
        return ((bits >> 16) as u16) | 1;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// Largest int8 code magnitude (the per-row scale maps maxabs onto it).
pub const INT8_QMAX: f32 = 127.0;

/// `n × w` memory rows stored in one of the [`RowFormat`]s. All read
/// kernels decode on the fly; all mutation goes through whole-row encodes.
#[derive(Clone, Debug)]
pub struct RowStore {
    n: usize,
    w: usize,
    fmt: RowFormat,
    f32d: Vec<f32>,
    bf16d: Vec<u16>,
    i8d: Vec<i8>,
    /// Per-row dequant scale (Int8 only; empty otherwise).
    scales: Vec<f32>,
}

impl RowStore {
    pub fn zeros(n: usize, w: usize, fmt: RowFormat) -> RowStore {
        let (f32d, bf16d, i8d, scales) = match fmt {
            RowFormat::F32 => (vec![0.0; n * w], Vec::new(), Vec::new(), Vec::new()),
            RowFormat::Bf16 => (Vec::new(), vec![0u16; n * w], Vec::new(), Vec::new()),
            RowFormat::Int8 => (Vec::new(), Vec::new(), vec![0i8; n * w], vec![0.0; n]),
        };
        RowStore { n, w, fmt, f32d, bf16d, i8d, scales }
    }

    /// Extend to at least `n_new` rows, zero-filling the tail (no-op when
    /// already large enough). Lets growable consumers (the ANN linear
    /// index) take ids past their initial capacity.
    pub fn grow(&mut self, n_new: usize) {
        if n_new <= self.n {
            return;
        }
        match self.fmt {
            RowFormat::F32 => self.f32d.resize(n_new * self.w, 0.0),
            RowFormat::Bf16 => self.bf16d.resize(n_new * self.w, 0),
            RowFormat::Int8 => {
                self.i8d.resize(n_new * self.w, 0);
                self.scales.resize(n_new, 0.0);
            }
        }
        self.n = n_new;
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn w(&self) -> usize {
        self.w
    }

    #[inline]
    pub fn fmt(&self) -> RowFormat {
        self.fmt
    }

    /// Borrow row `i` as f32 — the training-path accessor; compact formats
    /// have no borrowable f32 row and panic (train is f32-only by CLI
    /// validation, so hitting this is a wiring bug, not a user error).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(
            self.fmt == RowFormat::F32,
            "row(): {} rows have no borrowable f32 slice (train/backward is f32-only)",
            self.fmt.name()
        );
        &self.f32d[i * self.w..(i + 1) * self.w]
    }

    /// Mutable f32 row (F32 format only, same contract as [`RowStore::row`]).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(
            self.fmt == RowFormat::F32,
            "row_mut(): {} rows are encode-only (use set_row)",
            self.fmt.name()
        );
        &mut self.f32d[i * self.w..(i + 1) * self.w]
    }

    /// Dequant scale of row `i` (Int8; other formats return 1.0).
    #[inline]
    pub fn row_scale(&self, i: usize) -> f32 {
        match self.fmt {
            RowFormat::Int8 => self.scales[i],
            _ => 1.0,
        }
    }

    /// Decode row `i` into `out` (length `w`). The only place a full f32
    /// copy of a compact row is built — used for ANN re-inserts and
    /// journaling, never for scans.
    pub fn decode_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.w);
        let lo = i * self.w;
        match self.fmt {
            RowFormat::F32 => out.copy_from_slice(&self.f32d[lo..lo + self.w]),
            RowFormat::Bf16 => {
                for (o, &u) in out.iter_mut().zip(&self.bf16d[lo..lo + self.w]) {
                    *o = bf16_to_f32(u);
                }
            }
            RowFormat::Int8 => {
                let s = self.scales[i];
                for (o, &q) in out.iter_mut().zip(&self.i8d[lo..lo + self.w]) {
                    *o = q as f32 * s;
                }
            }
        }
    }

    /// Encode `vals` into row `i` (quantize-on-write). Int8 recomputes the
    /// row scale from the new content.
    pub fn set_row(&mut self, i: usize, vals: &[f32]) {
        debug_assert_eq!(vals.len(), self.w);
        let lo = i * self.w;
        match self.fmt {
            RowFormat::F32 => self.f32d[lo..lo + self.w].copy_from_slice(vals),
            RowFormat::Bf16 => {
                for (u, &x) in self.bf16d[lo..lo + self.w].iter_mut().zip(vals) {
                    *u = f32_to_bf16(x);
                }
            }
            RowFormat::Int8 => {
                let maxabs = vals.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let scale = if maxabs > 0.0 { maxabs / INT8_QMAX } else { 0.0 };
                self.encode_i8_row(i, vals, scale);
            }
        }
    }

    /// Int8-only: encode `vals` against a caller-supplied scale — the
    /// revert path, which must reproduce the journaled row's storage bits
    /// (decoded values divided by their own scale round back to the
    /// original codes exactly).
    pub fn set_row_with_scale(&mut self, i: usize, vals: &[f32], scale: f32) {
        assert!(self.fmt == RowFormat::Int8, "set_row_with_scale is Int8-only");
        self.encode_i8_row(i, vals, scale);
    }

    fn encode_i8_row(&mut self, i: usize, vals: &[f32], scale: f32) {
        let lo = i * self.w;
        self.scales[i] = scale;
        if scale == 0.0 {
            self.i8d[lo..lo + self.w].iter_mut().for_each(|q| *q = 0);
            return;
        }
        let inv = 1.0 / scale;
        for (q, &x) in self.i8d[lo..lo + self.w].iter_mut().zip(vals) {
            *q = (x * inv).round().clamp(-INT8_QMAX, INT8_QMAX) as i8;
        }
    }

    /// Fused `(q·row, row·row)` — the content-addressing read (one pass
    /// over the row regardless of format, f32 accumulation).
    #[inline]
    pub fn dot_normsq(&self, i: usize, q: &[f32]) -> (f32, f32) {
        debug_assert_eq!(q.len(), self.w);
        let lo = i * self.w;
        match self.fmt {
            RowFormat::F32 => {
                let r = &self.f32d[lo..lo + self.w];
                (dot(q, r), dot(r, r))
            }
            RowFormat::Bf16 => {
                let r = &self.bf16d[lo..lo + self.w];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::dot_normsq_bf16(q, r) };
                }
                let (mut sq, mut sn) = (0.0f32, 0.0f32);
                for (&qq, &u) in q.iter().zip(r) {
                    let x = bf16_to_f32(u);
                    sq += qq * x;
                    sn += x * x;
                }
                (sq, sn)
            }
            RowFormat::Int8 => {
                let r = &self.i8d[lo..lo + self.w];
                let s = self.scales[i];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::dot_normsq_i8(q, r, s) };
                }
                let (mut sq, mut sn) = (0.0f32, 0.0f32);
                for (&qq, &c) in q.iter().zip(r) {
                    let x = c as f32;
                    sq += qq * x;
                    sn += x * x;
                }
                (s * sq, s * s * sn)
            }
        }
    }

    /// Squared distance from `q` to row `i` — the linear-ANN scan kernel.
    #[inline]
    pub fn dist_sq_to(&self, i: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.w);
        let lo = i * self.w;
        match self.fmt {
            RowFormat::F32 => dist_sq(q, &self.f32d[lo..lo + self.w]),
            RowFormat::Bf16 => {
                let r = &self.bf16d[lo..lo + self.w];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::dist_sq_bf16(q, r) };
                }
                let mut s = 0.0f32;
                for (&qq, &u) in q.iter().zip(r) {
                    let d = qq - bf16_to_f32(u);
                    s += d * d;
                }
                s
            }
            RowFormat::Int8 => {
                let r = &self.i8d[lo..lo + self.w];
                let sc = self.scales[i];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::dist_sq_i8(q, r, sc) };
                }
                let mut s = 0.0f32;
                for (&qq, &c) in q.iter().zip(r) {
                    let d = qq - c as f32 * sc;
                    s += d * d;
                }
                s
            }
        }
    }

    /// `out += coeff · decode(row i)` — the sparse-read mixture kernel.
    #[inline]
    pub fn axpy_into(&self, i: usize, coeff: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.w);
        let lo = i * self.w;
        match self.fmt {
            RowFormat::F32 => axpy(out, coeff, &self.f32d[lo..lo + self.w]),
            RowFormat::Bf16 => {
                let r = &self.bf16d[lo..lo + self.w];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::axpy_bf16(out, coeff, r) };
                }
                for (o, &u) in out.iter_mut().zip(r) {
                    *o += coeff * bf16_to_f32(u);
                }
            }
            RowFormat::Int8 => {
                // Fold the row scale into the coefficient: one multiply per
                // row instead of one per lane.
                let c = coeff * self.scales[i];
                let r = &self.i8d[lo..lo + self.w];
                #[cfg(target_arch = "x86_64")]
                if kernel_path() == KernelPath::Avx2Fma {
                    return unsafe { crate::tensor::simd::avx2::axpy_i8(out, c, r) };
                }
                for (o, &q) in out.iter_mut().zip(r) {
                    *o += c * q as f32;
                }
            }
        }
    }

    /// Fill every row with the constant `v` (the dense baselines' reset).
    /// Int8 encodes `v` at full code range (zero fills get the canonical
    /// zero scale); the decoded value matches `v` to within one rounding.
    pub fn fill(&mut self, v: f32) {
        match self.fmt {
            RowFormat::F32 => self.f32d.iter_mut().for_each(|x| *x = v),
            RowFormat::Bf16 => {
                let u = f32_to_bf16(v);
                self.bf16d.iter_mut().for_each(|x| *x = u);
            }
            RowFormat::Int8 => {
                let (scale, code) = if v == 0.0 {
                    (0.0, 0)
                } else {
                    (v.abs() / INT8_QMAX, if v > 0.0 { 127 } else { -127 })
                };
                self.i8d.iter_mut().for_each(|q| *q = code);
                self.scales.iter_mut().for_each(|s| *s = scale);
            }
        }
    }

    /// Exact heap bytes of the row storage (the Fig 1b accounting: bf16
    /// halves it, int8 quarters it plus one f32 scale per row).
    pub fn heap_bytes(&self) -> usize {
        self.f32d.capacity() * 4
            + self.bf16d.capacity() * 2
            + self.i8d.capacity()
            + self.scales.capacity() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pattern(w: usize, seed: f32) -> Vec<f32> {
        (0..w).map(|j| ((j as f32 + seed) * 0.731).sin() * (1.0 + seed)).collect()
    }

    #[test]
    fn bf16_decode_encode_is_identity() {
        for u in [0u16, 1, 0x3F80, 0x8000, 0xC2F0, 0x7F7F] {
            assert_eq!(f32_to_bf16(bf16_to_f32(u)), u);
        }
        // RNE: 1.0 + 2⁻⁹ is exactly halfway between bf16(1.0) and the next
        // value up; it must round to the even mantissa (1.0).
        assert_eq!(f32_to_bf16(1.0 + 0.001953125), 0x3F80);
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_relative_error_bound() {
        for w in [1, 7, 8, 16, 33] {
            let vals = pattern(w, 0.3);
            let mut st = RowStore::zeros(2, w, RowFormat::Bf16);
            st.set_row(1, &vals);
            let mut dec = vec![0.0; w];
            st.decode_into(1, &mut dec);
            for (x, d) in vals.iter().zip(&dec) {
                // bf16 has 8 mantissa bits; RNE error ≤ 2⁻⁸ relative.
                assert!((x - d).abs() <= x.abs() * (1.0 / 256.0) + 1e-30, "{x} vs {d}");
            }
        }
    }

    #[test]
    fn int8_error_bound_and_scale() {
        let w = 24;
        let vals = pattern(w, 1.7);
        let maxabs = vals.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let mut st = RowStore::zeros(1, w, RowFormat::Int8);
        st.set_row(0, &vals);
        let scale = st.row_scale(0);
        assert!((scale - maxabs / INT8_QMAX).abs() < 1e-12);
        let mut dec = vec![0.0; w];
        st.decode_into(0, &mut dec);
        for (x, d) in vals.iter().zip(&dec) {
            assert!((x - d).abs() <= scale * 0.5 + 1e-6, "{x} vs {d}");
        }
    }

    #[test]
    fn int8_zero_row_has_zero_scale() {
        let mut st = RowStore::zeros(1, 8, RowFormat::Int8);
        st.set_row(0, &[0.0; 8]);
        assert_eq!(st.row_scale(0), 0.0);
        let mut dec = vec![1.0; 8];
        st.decode_into(0, &mut dec);
        assert!(dec.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn int8_reencode_with_saved_scale_is_bit_exact() {
        // The journal/revert contract: decode a row, re-encode it with the
        // saved scale, and the storage bits must be identical.
        let w = 19;
        let vals = pattern(w, 0.9);
        let mut st = RowStore::zeros(1, w, RowFormat::Int8);
        st.set_row(0, &vals);
        let codes_before = st.i8d.clone();
        let scale = st.row_scale(0);
        let mut dec = vec![0.0; w];
        st.decode_into(0, &mut dec);
        st.set_row(0, &pattern(w, 4.2)); // clobber
        st.set_row_with_scale(0, &dec, scale);
        assert_eq!(st.i8d, codes_before);
        assert_eq!(st.row_scale(0), scale);
    }

    #[test]
    fn fused_kernels_match_decode_then_scalar() {
        // Whatever path dispatch picked, the fused kernels must agree with
        // decode-then-f32-math to ~1e-5 relative on every residue class.
        for fmt in [RowFormat::F32, RowFormat::Bf16, RowFormat::Int8] {
            for w in [1, 4, 7, 8, 9, 16, 17, 64] {
                let vals = pattern(w, 0.5);
                let q = pattern(w, 2.1);
                let mut st = RowStore::zeros(3, w, fmt);
                st.set_row(2, &vals);
                let mut dec = vec![0.0; w];
                st.decode_into(2, &mut dec);

                let (dq, nsq) = st.dot_normsq(2, &q);
                let (edq, ensq) = (
                    q.iter().zip(&dec).map(|(a, b)| a * b).sum::<f32>(),
                    dec.iter().map(|x| x * x).sum::<f32>(),
                );
                let tol = |e: f32| e.abs() * 2e-5 + 2e-5;
                assert!((dq - edq).abs() <= tol(edq), "{fmt:?} w={w} dot {dq} vs {edq}");
                assert!((nsq - ensq).abs() <= tol(ensq), "{fmt:?} w={w} normsq {nsq} vs {ensq}");

                let d2 = st.dist_sq_to(2, &q);
                let ed2 = q.iter().zip(&dec).map(|(a, b)| (a - b) * (a - b)).sum::<f32>();
                assert!((d2 - ed2).abs() <= tol(ed2), "{fmt:?} w={w} d2 {d2} vs {ed2}");

                let mut out = pattern(w, 3.3);
                let mut expect = out.clone();
                st.axpy_into(2, 0.37, &mut out);
                for (e, d) in expect.iter_mut().zip(&dec) {
                    *e += 0.37 * d;
                }
                for (o, e) in out.iter().zip(&expect) {
                    assert!((o - e).abs() <= tol(*e), "{fmt:?} w={w} axpy {o} vs {e}");
                }
            }
        }
    }

    #[test]
    fn heap_bytes_is_exact_per_format() {
        let (n, w) = (10, 16);
        assert_eq!(RowStore::zeros(n, w, RowFormat::F32).heap_bytes(), n * w * 4);
        assert_eq!(RowStore::zeros(n, w, RowFormat::Bf16).heap_bytes(), n * w * 2);
        assert_eq!(RowStore::zeros(n, w, RowFormat::Int8).heap_bytes(), n * w + n * 4);
    }
}
