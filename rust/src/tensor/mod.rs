//! Dense and sparse tensor kernels (the role Eigen played in the paper's
//! Torch implementation).
pub mod csr;
pub mod matrix;
