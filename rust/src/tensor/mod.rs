//! Dense and sparse tensor kernels (the role Eigen played in the paper's
//! Torch implementation), plus the reusable scratch arena the step hot
//! path draws its buffers from.
pub mod csr;
pub mod matrix;
pub mod rowcodec;
pub mod simd;
pub mod workspace;
