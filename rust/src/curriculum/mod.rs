//! Exponential curriculum (paper §4.3): sample the difficulty level of each
//! episode uniformly from U(base, h); double the ceiling h whenever the
//! average training loss drops below a threshold for a window of episodes.
//! Doubling (rather than incrementing) keeps total training cost O(T) in
//! the final sequence length instead of O(T²).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Curriculum {
    /// Minimum level (task base difficulty).
    pub base: usize,
    /// Current ceiling h.
    pub h: usize,
    /// Hard cap on h.
    pub max_h: usize,
    /// Average per-step loss below which the level advances.
    pub loss_threshold: f64,
    /// Number of consecutive qualifying episodes required.
    pub patience: usize,
    streak: usize,
    /// Number of times h was doubled (diagnostics).
    pub advances: usize,
}

impl Curriculum {
    /// The paper's scheme: start at the task's base difficulty and double.
    pub fn exponential(base: usize, max_h: usize, loss_threshold: f64) -> Curriculum {
        Curriculum {
            base,
            h: base,
            max_h,
            loss_threshold,
            patience: 20,
            streak: 0,
            advances: 0,
        }
    }

    /// Fixed difficulty (no curriculum).
    pub fn fixed(level: usize) -> Curriculum {
        Curriculum {
            base: level,
            h: level,
            max_h: level,
            loss_threshold: 0.0,
            patience: usize::MAX,
            streak: 0,
            advances: 0,
        }
    }

    /// Sample a level for the next episode: U(base, h) inclusive.
    pub fn sample_level(&self, rng: &mut Rng) -> usize {
        if self.h <= self.base {
            self.base
        } else {
            rng.int_in(self.base, self.h)
        }
    }

    /// Report an episode's average per-scored-step loss; possibly advance.
    /// Returns true when h was doubled.
    pub fn report(&mut self, avg_loss: f64) -> bool {
        if self.h >= self.max_h {
            return false;
        }
        if avg_loss < self.loss_threshold {
            self.streak += 1;
            if self.streak >= self.patience {
                self.h = (self.h * 2).min(self.max_h);
                self.streak = 0;
                self.advances += 1;
                return true;
            }
        } else {
            self.streak = 0;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_after_patience() {
        let mut c = Curriculum::exponential(4, 64, 0.1);
        c.patience = 3;
        assert!(!c.report(0.05));
        assert!(!c.report(0.05));
        assert!(c.report(0.05));
        assert_eq!(c.h, 8);
        // streak resets on a bad episode
        assert!(!c.report(0.05));
        assert!(!c.report(0.5));
        assert!(!c.report(0.05));
        assert!(!c.report(0.05));
        assert!(c.report(0.05));
        assert_eq!(c.h, 16);
    }

    #[test]
    fn respects_max() {
        let mut c = Curriculum::exponential(4, 10, 1.0);
        c.patience = 1;
        c.report(0.0);
        assert_eq!(c.h, 8);
        c.report(0.0);
        assert_eq!(c.h, 10);
        assert!(!c.report(0.0));
        assert_eq!(c.h, 10);
    }

    #[test]
    fn sample_within_bounds() {
        let c = Curriculum { h: 16, ..Curriculum::exponential(4, 64, 0.1) };
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let l = c.sample_level(&mut rng);
            assert!((4..=16).contains(&l));
        }
    }

    #[test]
    fn fixed_never_moves() {
        let mut c = Curriculum::fixed(7);
        for _ in 0..100 {
            c.report(0.0);
        }
        assert_eq!(c.h, 7);
        let mut rng = Rng::new(2);
        assert_eq!(c.sample_level(&mut rng), 7);
    }
}
