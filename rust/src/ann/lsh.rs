//! Hyperplane locality-sensitive hashing (Charikar sim-hash), the index the
//! paper uses for large word sizes (§3.5): random hyperplanes map points to
//! buckets with cosine-distance-preserving collision probability
//! P[h(a)=h(b)] = 1 - θ(a,b)/π per bit.
//!
//! `tables` independent hash tables of `bits` hyperplanes each; a query
//! probes its exact bucket in every table plus all 1-bit-flip neighbour
//! buckets (multiprobe) until enough candidates are gathered, then ranks
//! candidates by exact cosine. Insert/remove are O(tables · bits · dim).

use super::{normalized, unit_dist_sq_to_cosine, AnnIndex};
use crate::tensor::matrix::{dist_sq, dot};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Multi-table hyperplane LSH index over normalized memory rows.
pub struct LshIndex {
    dim: usize,
    bits: usize,
    /// Hyperplane normals: tables × bits × dim, flattened.
    planes: Vec<f32>,
    tables: Vec<HashMap<u64, Vec<usize>>>,
    /// Flat normalized row storage + presence.
    data: Vec<f32>,
    present: Vec<bool>,
    /// Cached bucket key per (table, id) so remove() doesn't rehash.
    keys: Vec<u64>,
    count: usize,
    /// Minimum candidate pool before ranking (multiprobe widens until this).
    pub min_candidates: usize,
    stamp: Vec<u32>,
    stamp_now: u32,
    /// Inserts *and removes* since the last bucket compaction. Bucket
    /// vectors only grow (remove() retains capacity), so long update or
    /// remove-heavy streams slowly bloat the tables; every `compact_every`
    /// ops we rehash once, amortizing the O(N) compaction over O(N)
    /// incremental updates.
    ops_since_compact: usize,
    compact_every: usize,
    rebuilds: usize,
    /// Reused query scratch: flat normalized queries (one dim-sized segment
    /// per query), per-(query, table) bucket keys, and the candidate pool —
    /// so the query hot path allocates nothing beyond its result.
    qn_scratch: Vec<f32>,
    qkeys: Vec<u64>,
    cand: Vec<usize>,
}

impl LshIndex {
    /// Defaults tuned for memory-word data: 8 tables × 12 bits.
    pub fn with_defaults(n: usize, dim: usize, seed: u64) -> LshIndex {
        LshIndex::new(n, dim, 8, 12, 64, seed)
    }

    pub fn new(
        n: usize,
        dim: usize,
        n_tables: usize,
        bits: usize,
        min_candidates: usize,
        seed: u64,
    ) -> LshIndex {
        assert!(bits <= 63);
        let mut rng = Rng::new(seed);
        let mut planes = vec![0.0f32; n_tables * bits * dim];
        rng.fill_normal(&mut planes, 1.0);
        LshIndex {
            dim,
            bits,
            planes,
            tables: vec![HashMap::new(); n_tables],
            data: vec![0.0; n * dim],
            present: vec![false; n],
            keys: vec![0; n * n_tables],
            count: 0,
            min_candidates,
            stamp: vec![0; n],
            stamp_now: 0,
            ops_since_compact: 0,
            compact_every: 8 * n.max(64),
            rebuilds: 0,
            qn_scratch: Vec::new(),
            qkeys: Vec::new(),
            cand: Vec::new(),
        }
    }

    #[inline]
    fn point(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// Bucket key of `v` in table `t`.
    fn hash(&self, t: usize, v: &[f32]) -> u64 {
        let mut key = 0u64;
        let base = t * self.bits * self.dim;
        for b in 0..self.bits {
            let plane = &self.planes[base + b * self.dim..base + (b + 1) * self.dim];
            if dot(plane, v) >= 0.0 {
                key |= 1 << b;
            }
        }
        key
    }

    fn next_stamp(&mut self) -> u32 {
        self.stamp_now = self.stamp_now.wrapping_add(1);
        if self.stamp_now == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp_now = 1;
        }
        self.stamp_now
    }
}

/// Append the L2-normalized copy of `v` to `buf` (zero vectors stay zero).
fn push_normalized(buf: &mut Vec<f32>, v: &[f32]) {
    let n = dot(v, v).sqrt();
    let start = buf.len();
    buf.extend_from_slice(v);
    if n >= 1e-12 {
        let inv = 1.0 / n;
        buf[start..].iter_mut().for_each(|x| *x *= inv);
    }
}

/// Candidate gathering for one query whose per-table bucket keys are `keys`:
/// exact buckets first, then multiprobe 1-bit flips until the pool reaches
/// `want` (stopping at 2·want). Free function over split borrows so `query`
/// and `query_many_into` share it — which is what keeps them value-identical.
fn gather_candidates(
    tables: &[HashMap<u64, Vec<usize>>],
    bits: usize,
    keys: &[u64],
    want: usize,
    stamp: &mut [u32],
    stamp_val: u32,
    out: &mut Vec<usize>,
) {
    out.clear();
    for (t, &key) in keys.iter().enumerate() {
        if let Some(bucket) = tables[t].get(&key) {
            for &id in bucket {
                if stamp[id] != stamp_val {
                    stamp[id] = stamp_val;
                    out.push(id);
                }
            }
        }
    }
    if out.len() < want {
        'probe: for b in 0..bits {
            for (t, &key) in keys.iter().enumerate() {
                if let Some(bucket) = tables[t].get(&(key ^ (1 << b))) {
                    for &id in bucket {
                        if stamp[id] != stamp_val {
                            stamp[id] = stamp_val;
                            out.push(id);
                        }
                    }
                }
                if out.len() >= want * 2 {
                    break 'probe;
                }
            }
        }
    }
}

impl AnnIndex for LshIndex {
    fn len(&self) -> usize {
        self.count
    }

    fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        if id >= self.present.len() {
            self.present.resize(id + 1, false);
            self.data.resize((id + 1) * self.dim, 0.0);
            self.stamp.resize(id + 1, 0);
            self.keys.resize((id + 1) * self.tables.len(), 0);
        }
        if self.present[id] {
            self.remove(id);
        }
        let nv = normalized(v);
        self.data[id * self.dim..(id + 1) * self.dim].copy_from_slice(&nv);
        for t in 0..self.tables.len() {
            let key = self.hash(t, &nv);
            self.keys[id * self.tables.len() + t] = key;
            self.tables[t].entry(key).or_default().push(id);
        }
        self.present[id] = true;
        self.count += 1;
        self.ops_since_compact += 1;
        if self.ops_since_compact >= self.compact_every {
            self.rebuild();
        }
    }

    fn remove(&mut self, id: usize) {
        if id >= self.present.len() || !self.present[id] {
            return;
        }
        for t in 0..self.tables.len() {
            let key = self.keys[id * self.tables.len() + t];
            if let Some(bucket) = self.tables[t].get_mut(&key) {
                bucket.retain(|&x| x != id);
                if bucket.is_empty() {
                    self.tables[t].remove(&key);
                }
            }
        }
        self.present[id] = false;
        self.count -= 1;
        // Removes bloat the tables exactly like inserts do (retained bucket
        // capacity), so they count toward the compaction budget too — a
        // remove-heavy stream must still trigger the promised compaction.
        self.ops_since_compact += 1;
        if self.ops_since_compact >= self.compact_every {
            self.rebuild();
        }
    }

    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        assert_eq!(q.len(), self.dim);
        self.qn_scratch.clear();
        push_normalized(&mut self.qn_scratch, q);
        self.qkeys.clear();
        for t in 0..self.tables.len() {
            let key = self.hash(t, &self.qn_scratch);
            self.qkeys.push(key);
        }
        let stamp = self.next_stamp();
        let want = self.min_candidates.max(k);
        gather_candidates(
            &self.tables,
            self.bits,
            &self.qkeys,
            want,
            &mut self.stamp,
            stamp,
            &mut self.cand,
        );
        crate::util::metrics::ANN_QUERIES.inc();
        crate::util::metrics::ANN_CANDIDATES.add(self.cand.len() as u64);
        let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        for &id in &self.cand {
            let d2 = dist_sq(&self.qn_scratch, self.point(id));
            if best.len() < k || d2 < best.last().unwrap().1 {
                let pos = best.partition_point(|&(_, bd)| bd <= d2);
                best.insert(pos, (id, d2));
                if best.len() > k {
                    best.pop();
                }
            }
        }
        best.into_iter()
            .map(|(id, d2)| (id, unit_dist_sq_to_cosine(d2)))
            .collect()
    }

    /// Batched probe: hash all H queries against each table's hyperplanes up
    /// front (one pass per table serves every query while its planes are hot
    /// in cache), then probe and rank per query. Value-identical to issuing
    /// `query` per element — both paths share `gather_candidates` and the
    /// same ranking loop over identically normalized queries.
    fn query_many_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        let dim = self.dim;
        let nt = self.tables.len();
        self.qn_scratch.clear();
        for q in queries {
            assert_eq!(q.len(), dim);
            push_normalized(&mut self.qn_scratch, q);
        }
        self.qkeys.clear();
        self.qkeys.resize(queries.len() * nt, 0);
        for t in 0..nt {
            for qi in 0..queries.len() {
                let key = self.hash(t, &self.qn_scratch[qi * dim..(qi + 1) * dim]);
                self.qkeys[qi * nt + t] = key;
            }
        }
        while out.len() < queries.len() {
            out.push(Vec::new());
        }
        out.truncate(queries.len());
        for (qi, slot) in out.iter_mut().enumerate() {
            let stamp = self.next_stamp();
            let want = self.min_candidates.max(k);
            gather_candidates(
                &self.tables,
                self.bits,
                &self.qkeys[qi * nt..(qi + 1) * nt],
                want,
                &mut self.stamp,
                stamp,
                &mut self.cand,
            );
            crate::util::metrics::ANN_QUERIES.inc();
            crate::util::metrics::ANN_CANDIDATES.add(self.cand.len() as u64);
            slot.clear();
            slot.reserve(k + 1);
            for &id in &self.cand {
                let d2 = dist_sq(&self.qn_scratch[qi * dim..(qi + 1) * dim], self.point(id));
                if slot.len() < k || d2 < slot.last().unwrap().1 {
                    let pos = slot.partition_point(|&(_, bd)| bd <= d2);
                    slot.insert(pos, (id, d2));
                    if slot.len() > k {
                        slot.pop();
                    }
                }
            }
            for e in slot.iter_mut() {
                e.1 = unit_dist_sq_to_cosine(e.1);
            }
        }
    }

    fn rebuild(&mut self) {
        // Rehash everything (hyperplanes are static; this compacts buckets).
        for t in &mut self.tables {
            t.clear();
        }
        let nt = self.tables.len();
        for id in 0..self.present.len() {
            if !self.present[id] {
                continue;
            }
            for t in 0..nt {
                // Hash the row slice in place — no per-(row, table) copy.
                let key = self.hash(t, &self.data[id * self.dim..(id + 1) * self.dim]);
                self.keys[id * nt + t] = key;
                self.tables[t].entry(key).or_default().push(id);
            }
        }
        self.ops_since_compact = 0;
        self.rebuilds += 1;
        crate::util::metrics::ANN_FULL_REBUILDS.inc();
    }

    fn full_rebuilds(&self) -> usize {
        self.rebuilds
    }

    fn heap_bytes(&self) -> usize {
        let bucket_bytes: usize = self
            .tables
            .iter()
            .map(|t| t.values().map(|b| 48 + b.capacity() * 8).sum::<usize>())
            .sum();
        self.planes.capacity() * 4
            + self.data.capacity() * 4
            + self.present.capacity()
            + self.keys.capacity() * 8
            + self.stamp.capacity() * 4
            + self.qn_scratch.capacity() * 4
            + self.qkeys.capacity() * 8
            + self.cand.capacity() * 8
            + bucket_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::LinearIndex;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn exact_self_query() {
        let dim = 32;
        let pts = random_points(256, dim, 21);
        let mut lsh = LshIndex::with_defaults(256, dim, 1);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        for i in (0..256).step_by(31) {
            let r = lsh.query(&pts[i], 1);
            assert_eq!(r[0].0, i);
            assert!((r[0].1 - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn recall_against_exact() {
        let dim = 32;
        let n = 512;
        let pts = random_points(n, dim, 22);
        let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 2);
        let mut exact = LinearIndex::new(n, dim);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
            exact.insert(i, p);
        }
        // Queries near existing points (the SAM regime: queries are learned
        // to point at stored memories).
        let mut rng = Rng::new(77);
        let mut hit = 0;
        let mut total = 0;
        for qi in 0..64 {
            let base = &pts[(qi * 7) % n];
            let q: Vec<f32> = base.iter().map(|x| x + 0.1 * rng.normal()).collect();
            let approx: std::collections::HashSet<usize> =
                lsh.query(&q, 4).into_iter().map(|(i, _)| i).collect();
            for (i, _) in exact.query(&q, 4) {
                total += 1;
                if approx.contains(&i) {
                    hit += 1;
                }
            }
        }
        let recall = hit as f64 / total as f64;
        assert!(recall > 0.7, "recall@4 = {recall}");
    }

    #[test]
    fn update_and_remove() {
        let dim = 16;
        let pts = random_points(32, dim, 23);
        let mut lsh = LshIndex::with_defaults(32, dim, 3);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        let target = vec![1.0; 16];
        lsh.update(5, &target);
        let r = lsh.query(&target, 1);
        assert_eq!(r[0].0, 5);
        lsh.remove(5);
        let r = lsh.query(&target, 1);
        assert_ne!(r[0].0, 5);
        assert_eq!(lsh.len(), 31);
    }

    #[test]
    fn query_many_into_matches_sequential_query() {
        let dim = 32;
        let n = 256;
        let pts = random_points(n, dim, 25);
        let mut lsh = LshIndex::with_defaults(n, dim, 5);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        let mut out = Vec::new();
        let mut rng = Rng::new(6);
        for round in 0..3 {
            let queries: Vec<Vec<f32>> = (0..4)
                .map(|qi| {
                    pts[(round * 31 + qi * 7) % n]
                        .iter()
                        .map(|x| x + 0.1 * rng.normal())
                        .collect()
                })
                .collect();
            lsh.query_many_into(&queries, 4, &mut out);
            for (q, got) in queries.iter().zip(&out) {
                assert_eq!(lsh.query(q, 4), *got, "round {round} (batched != sequential)");
            }
        }
    }

    #[test]
    fn remove_heavy_stream_triggers_compaction() {
        // Regression: remove() never advanced ops_since_compact, so a
        // remove-heavy stream kept every bucket's stale capacity forever.
        let dim = 16;
        let n = 64;
        let pts = random_points(n, dim, 26);
        let mut lsh = LshIndex::with_defaults(n, dim, 7);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        // Bloat the buckets with update churn (remove+insert retains bucket
        // capacity), then drain with a pure-remove stream.
        let mut rng = Rng::new(8);
        for step in 0..4 * n {
            let v: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            lsh.update(step % n, &v);
        }
        let bloated = lsh.heap_bytes();
        let rebuilds_before = lsh.full_rebuilds();
        lsh.ops_since_compact = 0;
        lsh.compact_every = n / 2;
        for id in 0..n / 2 {
            lsh.remove(id);
        }
        assert!(
            lsh.full_rebuilds() > rebuilds_before,
            "pure-remove stream never compacted"
        );
        assert!(
            lsh.heap_bytes() < bloated,
            "compaction must shrink the tables: {} vs {bloated}",
            lsh.heap_bytes()
        );
        assert_eq!(lsh.len(), n / 2);
        // Post-compaction correctness: surviving rows are still findable.
        for id in (n / 2..n).step_by(5) {
            let p = lsh.point(id).to_vec();
            let r = lsh.query(&p, 1);
            assert_eq!(r[0].0, id);
        }
    }

    #[test]
    fn rebuild_allocates_per_bucket_not_per_row() {
        // Regression: rebuild used to copy every row once per table just to
        // hash it. Allocation events in a warm rebuild must now be bounded
        // by bucket growth — strictly below one per (row, table) pair.
        let dim = 16;
        let n = 64;
        let n_tables = 4;
        let pts = random_points(n, dim, 27);
        let mut lsh = LshIndex::new(n, dim, n_tables, 3, 16, 9);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        lsh.rebuild(); // warm the table capacities
        let before = crate::util::alloc::thread_alloc_count();
        lsh.rebuild();
        let allocs = crate::util::alloc::thread_alloc_count() - before;
        assert!(
            allocs < n * n_tables,
            "rebuild allocated {allocs} times for {} bucket entries",
            n * n_tables
        );
    }

    #[test]
    fn warm_query_allocates_only_its_result() {
        // Regression: query used to allocate its per-table key Vec (and a
        // normalized copy, and the candidate pool) on every call.
        let dim = 32;
        let n = 256;
        let pts = random_points(n, dim, 28);
        let mut lsh = LshIndex::with_defaults(n, dim, 10);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        let q = pts[17].clone();
        // Warm the scratch capacities.
        lsh.query(&q, 4);
        lsh.query(&q, 4);
        let before = crate::util::alloc::thread_alloc_count();
        let r = lsh.query(&q, 4);
        let allocs = crate::util::alloc::thread_alloc_count() - before;
        assert_eq!(r[0].0, 17);
        assert!(allocs <= 3, "query hot path allocated {allocs} times");
    }

    #[test]
    fn rebuild_is_lossless() {
        let dim = 16;
        let pts = random_points(64, dim, 24);
        let mut lsh = LshIndex::with_defaults(64, dim, 4);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        lsh.rebuild();
        assert_eq!(lsh.len(), 64);
        let r = lsh.query(&pts[10], 1);
        assert_eq!(r[0].0, 10);
    }
}
