//! Hyperplane locality-sensitive hashing (Charikar sim-hash), the index the
//! paper uses for large word sizes (§3.5): random hyperplanes map points to
//! buckets with cosine-distance-preserving collision probability
//! P[h(a)=h(b)] = 1 - θ(a,b)/π per bit.
//!
//! `tables` independent hash tables of `bits` hyperplanes each; a query
//! probes its exact bucket in every table plus all 1-bit-flip neighbour
//! buckets (multiprobe) until enough candidates are gathered, then ranks
//! candidates by exact cosine. Insert/remove are O(tables · bits · dim).

use super::{normalized, unit_dist_sq_to_cosine, AnnIndex};
use crate::tensor::matrix::{dist_sq, dot};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Multi-table hyperplane LSH index over normalized memory rows.
pub struct LshIndex {
    dim: usize,
    bits: usize,
    /// Hyperplane normals: tables × bits × dim, flattened.
    planes: Vec<f32>,
    tables: Vec<HashMap<u64, Vec<usize>>>,
    /// Flat normalized row storage + presence.
    data: Vec<f32>,
    present: Vec<bool>,
    /// Cached bucket key per (table, id) so remove() doesn't rehash.
    keys: Vec<u64>,
    count: usize,
    /// Minimum candidate pool before ranking (multiprobe widens until this).
    pub min_candidates: usize,
    stamp: Vec<u32>,
    stamp_now: u32,
    /// Inserts since the last bucket compaction. Bucket vectors only grow
    /// (remove() retains capacity), so a long update stream slowly bloats
    /// the tables; every `compact_every` inserts we rehash once, amortizing
    /// the O(N) compaction over O(N) incremental updates.
    ops_since_compact: usize,
    compact_every: usize,
    rebuilds: usize,
}

impl LshIndex {
    /// Defaults tuned for memory-word data: 8 tables × 12 bits.
    pub fn with_defaults(n: usize, dim: usize, seed: u64) -> LshIndex {
        LshIndex::new(n, dim, 8, 12, 64, seed)
    }

    pub fn new(
        n: usize,
        dim: usize,
        n_tables: usize,
        bits: usize,
        min_candidates: usize,
        seed: u64,
    ) -> LshIndex {
        assert!(bits <= 63);
        let mut rng = Rng::new(seed);
        let mut planes = vec![0.0f32; n_tables * bits * dim];
        rng.fill_normal(&mut planes, 1.0);
        LshIndex {
            dim,
            bits,
            planes,
            tables: vec![HashMap::new(); n_tables],
            data: vec![0.0; n * dim],
            present: vec![false; n],
            keys: vec![0; n * n_tables],
            count: 0,
            min_candidates,
            stamp: vec![0; n],
            stamp_now: 0,
            ops_since_compact: 0,
            compact_every: 8 * n.max(64),
            rebuilds: 0,
        }
    }

    #[inline]
    fn point(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// Bucket key of `v` in table `t`.
    fn hash(&self, t: usize, v: &[f32]) -> u64 {
        let mut key = 0u64;
        let base = t * self.bits * self.dim;
        for b in 0..self.bits {
            let plane = &self.planes[base + b * self.dim..base + (b + 1) * self.dim];
            if dot(plane, v) >= 0.0 {
                key |= 1 << b;
            }
        }
        key
    }

    fn next_stamp(&mut self) -> u32 {
        self.stamp_now = self.stamp_now.wrapping_add(1);
        if self.stamp_now == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp_now = 1;
        }
        self.stamp_now
    }
}

impl AnnIndex for LshIndex {
    fn len(&self) -> usize {
        self.count
    }

    fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        if id >= self.present.len() {
            self.present.resize(id + 1, false);
            self.data.resize((id + 1) * self.dim, 0.0);
            self.stamp.resize(id + 1, 0);
            self.keys.resize((id + 1) * self.tables.len(), 0);
        }
        if self.present[id] {
            self.remove(id);
        }
        let nv = normalized(v);
        self.data[id * self.dim..(id + 1) * self.dim].copy_from_slice(&nv);
        for t in 0..self.tables.len() {
            let key = self.hash(t, &nv);
            self.keys[id * self.tables.len() + t] = key;
            self.tables[t].entry(key).or_default().push(id);
        }
        self.present[id] = true;
        self.count += 1;
        self.ops_since_compact += 1;
        if self.ops_since_compact >= self.compact_every {
            self.rebuild();
        }
    }

    fn remove(&mut self, id: usize) {
        if id >= self.present.len() || !self.present[id] {
            return;
        }
        for t in 0..self.tables.len() {
            let key = self.keys[id * self.tables.len() + t];
            if let Some(bucket) = self.tables[t].get_mut(&key) {
                bucket.retain(|&x| x != id);
                if bucket.is_empty() {
                    self.tables[t].remove(&key);
                }
            }
        }
        self.present[id] = false;
        self.count -= 1;
    }

    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        let qn = normalized(q);
        let stamp = self.next_stamp();
        let mut candidates: Vec<usize> = Vec::with_capacity(self.min_candidates * 2);

        // Exact buckets first.
        let keys: Vec<u64> = (0..self.tables.len()).map(|t| self.hash(t, &qn)).collect();
        for (t, &key) in keys.iter().enumerate() {
            if let Some(bucket) = self.tables[t].get(&key) {
                for &id in bucket {
                    if self.stamp[id] != stamp {
                        self.stamp[id] = stamp;
                        candidates.push(id);
                    }
                }
            }
        }
        // Multiprobe: 1-bit flips until the candidate pool is large enough.
        if candidates.len() < self.min_candidates.max(k) {
            'probe: for b in 0..self.bits {
                for (t, &key) in keys.iter().enumerate() {
                    if let Some(bucket) = self.tables[t].get(&(key ^ (1 << b))) {
                        for &id in bucket {
                            if self.stamp[id] != stamp {
                                self.stamp[id] = stamp;
                                candidates.push(id);
                            }
                        }
                    }
                    if candidates.len() >= self.min_candidates.max(k) * 2 {
                        break 'probe;
                    }
                }
            }
        }

        let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        for id in candidates {
            let d2 = dist_sq(&qn, self.point(id));
            if best.len() < k || d2 < best.last().unwrap().1 {
                let pos = best.partition_point(|&(_, bd)| bd <= d2);
                best.insert(pos, (id, d2));
                if best.len() > k {
                    best.pop();
                }
            }
        }
        best.into_iter()
            .map(|(id, d2)| (id, unit_dist_sq_to_cosine(d2)))
            .collect()
    }

    fn rebuild(&mut self) {
        // Rehash everything (hyperplanes are static; this compacts buckets).
        let ids: Vec<usize> =
            (0..self.present.len()).filter(|&i| self.present[i]).collect();
        for t in &mut self.tables {
            t.clear();
        }
        for id in ids {
            for t in 0..self.tables.len() {
                let key = self.hash(t, &self.point(id).to_vec());
                self.keys[id * self.tables.len() + t] = key;
                self.tables[t].entry(key).or_default().push(id);
            }
        }
        self.ops_since_compact = 0;
        self.rebuilds += 1;
    }

    fn full_rebuilds(&self) -> usize {
        self.rebuilds
    }

    fn heap_bytes(&self) -> usize {
        let bucket_bytes: usize = self
            .tables
            .iter()
            .map(|t| t.values().map(|b| 48 + b.capacity() * 8).sum::<usize>())
            .sum();
        self.planes.capacity() * 4
            + self.data.capacity() * 4
            + self.present.capacity()
            + self.keys.capacity() * 8
            + self.stamp.capacity() * 4
            + bucket_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::LinearIndex;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn exact_self_query() {
        let dim = 32;
        let pts = random_points(256, dim, 21);
        let mut lsh = LshIndex::with_defaults(256, dim, 1);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        for i in (0..256).step_by(31) {
            let r = lsh.query(&pts[i], 1);
            assert_eq!(r[0].0, i);
            assert!((r[0].1 - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn recall_against_exact() {
        let dim = 32;
        let n = 512;
        let pts = random_points(n, dim, 22);
        let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 2);
        let mut exact = LinearIndex::new(n, dim);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
            exact.insert(i, p);
        }
        // Queries near existing points (the SAM regime: queries are learned
        // to point at stored memories).
        let mut rng = Rng::new(77);
        let mut hit = 0;
        let mut total = 0;
        for qi in 0..64 {
            let base = &pts[(qi * 7) % n];
            let q: Vec<f32> = base.iter().map(|x| x + 0.1 * rng.normal()).collect();
            let approx: std::collections::HashSet<usize> =
                lsh.query(&q, 4).into_iter().map(|(i, _)| i).collect();
            for (i, _) in exact.query(&q, 4) {
                total += 1;
                if approx.contains(&i) {
                    hit += 1;
                }
            }
        }
        let recall = hit as f64 / total as f64;
        assert!(recall > 0.7, "recall@4 = {recall}");
    }

    #[test]
    fn update_and_remove() {
        let dim = 16;
        let pts = random_points(32, dim, 23);
        let mut lsh = LshIndex::with_defaults(32, dim, 3);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        let target = vec![1.0; 16];
        lsh.update(5, &target);
        let r = lsh.query(&target, 1);
        assert_eq!(r[0].0, 5);
        lsh.remove(5);
        let r = lsh.query(&target, 1);
        assert_ne!(r[0].0, 5);
        assert_eq!(lsh.len(), 31);
    }

    #[test]
    fn rebuild_is_lossless() {
        let dim = 16;
        let pts = random_points(64, dim, 24);
        let mut lsh = LshIndex::with_defaults(64, dim, 4);
        for (i, p) in pts.iter().enumerate() {
            lsh.insert(i, p);
        }
        lsh.rebuild();
        assert_eq!(lsh.len(), 64);
        let r = lsh.query(&pts[10], 1);
        assert_eq!(r[0].0, 10);
    }
}
