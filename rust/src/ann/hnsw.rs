//! HNSW-style navigable-small-world graph index — the O(log N) backend the
//! ROADMAP names as the scaling unlock past the paper's 2016-era kd/LSH
//! approximations (Malkov & Yashunin, arXiv 1603.09320; Hierarchical
//! Attentive Memory, arXiv 1602.03218, argues the O(log n) asymptotic).
//!
//! Layout: every node gets a geometric random level; each layer is a
//! proximity graph with degree capped at M (2·M on layer 0). A query greedily
//! descends from the entry point through the upper layers, then runs an
//! ef-bounded best-first search on layer 0. Per-query cost is
//! O(ef · M · dim · log N) — flat-ish in N — versus the linear scan's
//! O(N · dim).
//!
//! Determinism contract (same as kd/LSH): **per-run deterministic at a fixed
//! seed and operation order.** Stronger than the other backends in one
//! respect: a node's level is a pure hash of `(seed, id)`, not a draw from a
//! mutable RNG stream, so remove/re-insert churn from the engine's
//! write-revert cycles cannot shift any node's level. All heap and
//! neighbor-selection tie-breaks are `(f32::total_cmp, id)`-lexicographic, so
//! there is no residual ordering freedom.
//!
//! Incremental maintenance: `update_row` unlinks the node and re-links it in
//! place (its level is stable, so the layer structure is untouched);
//! `remove_row` unlinks with neighbor repair — former neighbors with spare
//! degree are reconnected pairwise so the graph does not fragment under the
//! engine's remove-heavy revert streams. Neither path ever triggers a full
//! rebuild: `full_rebuilds()` stays 0 unless `rebuild()` is called
//! explicitly.

use super::{unit_dist_sq_to_cosine, AnnIndex};
use crate::tensor::matrix::{dist_sq, dot};
use std::collections::BinaryHeap;

/// Hard cap on node levels (fits u8; log_M(N) for any realistic N is far
/// smaller).
const MAX_LEVEL: usize = 15;

/// Heap entry popping **nearest first** (BinaryHeap is a max-heap, so the
/// ordering is reversed). Ties break by ascending id for determinism.
#[derive(Clone, Copy, PartialEq)]
struct Near(f32, u32);

impl Eq for Near {}

impl Ord for Near {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        o.0.total_cmp(&self.0).then(o.1.cmp(&self.1))
    }
}

impl PartialOrd for Near {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

/// Heap entry popping **farthest first** — the ef-bounded result set.
#[derive(Clone, Copy, PartialEq)]
struct Far(f32, u32);

impl Eq for Far {}

impl Ord for Far {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&o.0).then(self.1.cmp(&o.1))
    }
}

impl PartialOrd for Far {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

/// Reused per-search buffers: the step hot path allocates nothing once these
/// are warm.
struct SearchScratch {
    /// Visited markers (stamp pattern — no per-query clearing).
    stamp: Vec<u32>,
    stamp_now: u32,
    /// Frontier (nearest-first) and result set (farthest-first).
    cand: BinaryHeap<Near>,
    best: BinaryHeap<Far>,
    /// Result staging, ascending `(d², id)`.
    sorted: Vec<(f32, u32)>,
    /// Neighbor-selection output.
    selected: Vec<u32>,
    /// Degree-overflow pruning staging.
    prune: Vec<(f32, u32)>,
    /// Distance evaluations of the last `search_layer` call (the metrics
    /// candidate count; also written by insert-side searches, read only by
    /// queries).
    visited: usize,
}

impl SearchScratch {
    fn next_stamp(&mut self) -> u32 {
        self.stamp_now = self.stamp_now.wrapping_add(1);
        if self.stamp_now == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp_now = 1;
        }
        self.stamp_now
    }

    fn heap_bytes(&self) -> usize {
        self.stamp.capacity() * 4
            + self.cand.capacity() * std::mem::size_of::<Near>()
            + self.best.capacity() * std::mem::size_of::<Far>()
            + self.sorted.capacity() * std::mem::size_of::<(f32, u32)>()
            + self.selected.capacity() * 4
            + self.prune.capacity() * std::mem::size_of::<(f32, u32)>()
    }
}

#[inline]
fn rowslice(data: &[f32], dim: usize, id: u32) -> &[f32] {
    let i = id as usize;
    &data[i * dim..(i + 1) * dim]
}

/// Greedy descent on one layer: strict lexicographic `(d, id)` improvement,
/// so the walk terminates and is deterministic.
fn greedy_descend(
    data: &[f32],
    dim: usize,
    links: &[Vec<Vec<u32>>],
    layer: usize,
    qn: &[f32],
    mut cur: u32,
    mut curd: f32,
) -> (u32, f32) {
    loop {
        let mut improved = false;
        for &v in &links[cur as usize][layer] {
            let d = dist_sq(qn, rowslice(data, dim, v));
            if d.total_cmp(&curd).then(v.cmp(&cur)) == std::cmp::Ordering::Less {
                cur = v;
                curd = d;
                improved = true;
            }
        }
        if !improved {
            return (cur, curd);
        }
    }
}

/// ef-bounded best-first search on one layer starting from `entry`. Leaves
/// the result set in `sc.best` (farthest-first heap) for the caller to drain.
fn search_layer(
    data: &[f32],
    dim: usize,
    links: &[Vec<Vec<u32>>],
    layer: usize,
    qn: &[f32],
    ef: usize,
    entry: u32,
    sc: &mut SearchScratch,
) {
    let stamp = sc.next_stamp();
    sc.cand.clear();
    sc.best.clear();
    let d0 = dist_sq(qn, rowslice(data, dim, entry));
    sc.visited = 1;
    sc.stamp[entry as usize] = stamp;
    sc.cand.push(Near(d0, entry));
    sc.best.push(Far(d0, entry));
    while let Some(Near(d, u)) = sc.cand.pop() {
        if sc.best.len() >= ef && d > sc.best.peek().map_or(f32::INFINITY, |f| f.0) {
            break;
        }
        for &v in &links[u as usize][layer] {
            if sc.stamp[v as usize] == stamp {
                continue;
            }
            sc.stamp[v as usize] = stamp;
            let dv = dist_sq(qn, rowslice(data, dim, v));
            sc.visited += 1;
            if sc.best.len() < ef || dv < sc.best.peek().map_or(f32::INFINITY, |f| f.0) {
                sc.cand.push(Near(dv, v));
                sc.best.push(Far(dv, v));
                if sc.best.len() > ef {
                    sc.best.pop();
                }
            }
        }
    }
}

/// The paper's neighbor-selection heuristic (Alg. 4): from candidates sorted
/// ascending by `(d, id)`, keep `c` only if it is closer to the query than to
/// every already-selected neighbor — this spreads links across directions.
/// Closest-first fill if the heuristic under-selects.
fn select_neighbors(
    data: &[f32],
    dim: usize,
    m: usize,
    sorted: &[(f32, u32)],
    selected: &mut Vec<u32>,
) {
    selected.clear();
    for &(d, c) in sorted {
        if selected.len() >= m {
            break;
        }
        let rc = rowslice(data, dim, c);
        let spread = selected
            .iter()
            .all(|&s| dist_sq(rc, rowslice(data, dim, s)) > d);
        if spread {
            selected.push(c);
        }
    }
    if selected.len() < m {
        for &(_, c) in sorted {
            if selected.len() >= m {
                break;
            }
            if !selected.contains(&c) {
                selected.push(c);
            }
        }
    }
}

/// Re-rank `u`'s neighbor list on `layer`, keep the closest `max_links`, and
/// drop the reverse edges of the cut ones — edges stay strictly symmetric,
/// which is what makes `unlink` total.
fn prune_node(
    links: &mut [Vec<Vec<u32>>],
    data: &[f32],
    dim: usize,
    layer: usize,
    u: u32,
    max_links: usize,
    prune: &mut Vec<(f32, u32)>,
) {
    let uu = u as usize;
    prune.clear();
    {
        let ru = rowslice(data, dim, u);
        for &x in &links[uu][layer] {
            prune.push((dist_sq(ru, rowslice(data, dim, x)), x));
        }
    }
    prune.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let lu = &mut links[uu][layer];
    lu.clear();
    lu.extend(prune.iter().take(max_links).map(|&(_, x)| x));
    for &(_, x) in prune.iter().skip(max_links) {
        let lx = &mut links[x as usize][layer];
        if let Some(p) = lx.iter().position(|&y| y == u) {
            lx.swap_remove(p);
        }
    }
}

/// Add the symmetric edge (a, b) on `layer`, pruning either endpoint that
/// overflows `max_links`. No-op if the edge exists.
fn add_edge(
    links: &mut [Vec<Vec<u32>>],
    data: &[f32],
    dim: usize,
    layer: usize,
    a: u32,
    b: u32,
    max_links: usize,
    prune: &mut Vec<(f32, u32)>,
) {
    if a == b || links[a as usize][layer].contains(&b) {
        return;
    }
    links[a as usize][layer].push(b);
    links[b as usize][layer].push(a);
    if links[b as usize][layer].len() > max_links {
        prune_node(links, data, dim, layer, b, max_links, prune);
    }
    if links[a as usize][layer].len() > max_links {
        prune_node(links, data, dim, layer, a, max_links, prune);
    }
}

/// Link freshly-searched node `id` to `selected` on `layer`, pruning any
/// neighbor whose degree overflows.
fn link_node(
    links: &mut [Vec<Vec<u32>>],
    data: &[f32],
    dim: usize,
    layer: usize,
    id: u32,
    selected: &[u32],
    max_links: usize,
    prune: &mut Vec<(f32, u32)>,
) {
    for &u in selected {
        links[id as usize][layer].push(u);
        links[u as usize][layer].push(id);
        if links[u as usize][layer].len() > max_links {
            prune_node(links, data, dim, layer, u, max_links, prune);
        }
    }
}

/// Seeded, deterministic HNSW graph over normalized memory rows.
pub struct HnswIndex {
    dim: usize,
    /// Degree cap on layers ≥ 1 (the paper's M).
    m: usize,
    /// Degree cap on layer 0 (2·M, as in the reference implementation).
    m0: usize,
    /// Candidate-list width while (re-)linking a node.
    pub ef_construction: usize,
    /// Candidate-list width while answering queries. Raise for recall,
    /// lower for speed; `query` internally uses `ef_search.max(k)`.
    pub ef_search: usize,
    /// 1/ln(M) — geometric level-distribution multiplier.
    level_mult: f64,
    seed: u64,
    /// Flat normalized row storage; row i at [i·dim, (i+1)·dim).
    data: Vec<f32>,
    present: Vec<bool>,
    /// Pure-hash level per id (stable across remove/re-insert).
    levels: Vec<u8>,
    /// links[id][layer] = neighbor ids; lists are kept strictly symmetric.
    links: Vec<Vec<Vec<u32>>>,
    /// Highest-level node, search start point.
    entry: Option<u32>,
    count: usize,
    /// Normalized-query scratch (kept outside SearchScratch so a query can
    /// borrow it immutably while the search mutates the scratch).
    qn: Vec<f32>,
    scratch: SearchScratch,
    rebuilds: usize,
}

impl HnswIndex {
    /// Defaults tuned for memory-word widths W ∈ {32..128}: M=16 keeps the
    /// graph walk cache-friendly at those dims, efConstruction=80 holds
    /// recall@16 ≥ 0.95 at N=100k, efSearch=64 keeps per-query µs flat in N.
    pub fn with_defaults(n: usize, dim: usize, seed: u64) -> HnswIndex {
        HnswIndex::new(n, dim, 16, 80, 64, seed)
    }

    pub fn new(
        n: usize,
        dim: usize,
        m: usize,
        ef_construction: usize,
        ef_search: usize,
        seed: u64,
    ) -> HnswIndex {
        assert!(m >= 2, "HNSW needs a degree cap of at least 2");
        HnswIndex {
            dim,
            m,
            m0: 2 * m,
            ef_construction,
            ef_search,
            level_mult: 1.0 / (m as f64).ln(),
            seed,
            data: vec![0.0; n * dim],
            present: vec![false; n],
            levels: vec![0; n],
            links: (0..n).map(|_| Vec::new()).collect(),
            entry: None,
            count: 0,
            qn: Vec::new(),
            scratch: SearchScratch {
                stamp: vec![0; n],
                stamp_now: 0,
                cand: BinaryHeap::new(),
                best: BinaryHeap::new(),
                sorted: Vec::new(),
                selected: Vec::new(),
                prune: Vec::new(),
                visited: 0,
            },
            rebuilds: 0,
        }
    }

    /// Level of `id`: SplitMix64 of (seed, id) mapped through the geometric
    /// distribution. Pure, so the layer structure survives engine churn.
    fn level_for(&self, id: usize) -> usize {
        let mut z = self.seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        // Uniform in (0, 1]; -ln(u)·mult is the standard geometric draw.
        let u = ((z >> 11) as f64 + 1.0) / (1u64 << 53) as f64;
        ((-u.ln() * self.level_mult) as usize).min(MAX_LEVEL)
    }

    fn ensure_capacity(&mut self, id: usize) {
        if id >= self.present.len() {
            self.present.resize(id + 1, false);
            self.data.resize((id + 1) * self.dim, 0.0);
            self.levels.resize(id + 1, 0);
            self.links.resize_with(id + 1, Vec::new);
            self.scratch.stamp.resize(id + 1, 0);
        }
    }

    /// Highest-level present node other than `exclude` (ties to the smallest
    /// id). O(N) scan, but only reached when the entry node itself is
    /// removed or rewritten — ~K/N of engine writes.
    fn pick_entry_excluding(&self, exclude: usize) -> Option<u32> {
        let mut best: Option<(u8, u32)> = None;
        for i in 0..self.present.len() {
            if i == exclude || !self.present[i] {
                continue;
            }
            let l = self.levels[i];
            if best.is_none() || l > best.unwrap().0 {
                best = Some((l, i as u32));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Detach `id` from the graph. With `repair`, former neighbors with
    /// spare degree are reconnected to their closest former co-neighbor so
    /// remove-heavy streams don't fragment the layer graphs.
    fn unlink(&mut self, id: usize, repair: bool) {
        for layer in 0..self.links[id].len() {
            let mut nbrs = std::mem::take(&mut self.links[id][layer]);
            for &u in &nbrs {
                let lu = &mut self.links[u as usize][layer];
                if let Some(p) = lu.iter().position(|&x| x == id as u32) {
                    lu.swap_remove(p);
                }
            }
            if repair && nbrs.len() >= 2 {
                let max_links = if layer == 0 { self.m0 } else { self.m };
                for i in 0..nbrs.len() {
                    let u = nbrs[i];
                    if self.links[u as usize][layer].len() >= max_links {
                        continue;
                    }
                    let mut bestw: Option<(f32, u32)> = None;
                    for &w in &nbrs {
                        if w == u || self.links[u as usize][layer].contains(&w) {
                            continue;
                        }
                        let d = dist_sq(
                            rowslice(&self.data, self.dim, u),
                            rowslice(&self.data, self.dim, w),
                        );
                        let better = match bestw {
                            None => true,
                            Some((bd, bw)) => {
                                d.total_cmp(&bd).then(w.cmp(&bw)) == std::cmp::Ordering::Less
                            }
                        };
                        if better {
                            bestw = Some((d, w));
                        }
                    }
                    if let Some((_, w)) = bestw {
                        add_edge(
                            &mut self.links,
                            &self.data,
                            self.dim,
                            layer,
                            u,
                            w,
                            max_links,
                            &mut self.scratch.prune,
                        );
                    }
                }
            }
            nbrs.clear();
            self.links[id][layer] = nbrs;
        }
    }

    /// Search-and-link a node whose data/level/present are already set and
    /// whose link lists are empty. Shared by insert, update_row and rebuild.
    fn connect(&mut self, id: usize) {
        let lvl = self.levels[id] as usize;
        let Some(ep) = self.entry else {
            self.entry = Some(id as u32);
            return;
        };
        if ep as usize == id {
            // Sole present node: nothing to link to.
            return;
        }
        let l_ep = self.levels[ep as usize] as usize;
        let qrow = rowslice(&self.data, self.dim, id as u32);
        let mut cur = ep;
        let mut curd = dist_sq(qrow, rowslice(&self.data, self.dim, cur));
        for layer in (lvl + 1..=l_ep).rev() {
            (cur, curd) =
                greedy_descend(&self.data, self.dim, &self.links, layer, qrow, cur, curd);
        }
        let _ = curd;
        for layer in (0..=lvl.min(l_ep)).rev() {
            search_layer(
                &self.data,
                self.dim,
                &self.links,
                layer,
                qrow,
                self.ef_construction.max(1),
                cur,
                &mut self.scratch,
            );
            self.scratch.sorted.clear();
            while let Some(Far(d, u)) = self.scratch.best.pop() {
                self.scratch.sorted.push((d, u));
            }
            self.scratch.sorted.reverse();
            let max_links = if layer == 0 { self.m0 } else { self.m };
            select_neighbors(
                &self.data,
                self.dim,
                self.m,
                &self.scratch.sorted,
                &mut self.scratch.selected,
            );
            link_node(
                &mut self.links,
                &self.data,
                self.dim,
                layer,
                id as u32,
                &self.scratch.selected,
                max_links,
                &mut self.scratch.prune,
            );
            cur = self.scratch.sorted[0].1;
        }
        if lvl > l_ep {
            self.entry = Some(id as u32);
        }
    }

    /// Top-k by ascending squared unit-L2 distance, left in
    /// `self.scratch.sorted` as `(d², id)` — ties broken by ascending id,
    /// the ordering the sharded merge depends on.
    fn search_topk(&mut self, q: &[f32], k: usize) {
        assert_eq!(q.len(), self.dim);
        self.qn.clear();
        self.qn.extend_from_slice(q);
        let n = dot(q, q).sqrt();
        if n >= 1e-12 {
            let inv = 1.0 / n;
            self.qn.iter_mut().for_each(|x| *x *= inv);
        }
        self.scratch.sorted.clear();
        self.scratch.visited = 0;
        let Some(ep) = self.entry else {
            return;
        };
        let mut cur = ep;
        let mut curd = dist_sq(&self.qn, rowslice(&self.data, self.dim, cur));
        for layer in (1..=self.levels[ep as usize] as usize).rev() {
            (cur, curd) =
                greedy_descend(&self.data, self.dim, &self.links, layer, &self.qn, cur, curd);
        }
        let _ = curd;
        search_layer(
            &self.data,
            self.dim,
            &self.links,
            0,
            &self.qn,
            self.ef_search.max(k),
            cur,
            &mut self.scratch,
        );
        self.scratch.sorted.clear();
        while let Some(Far(d, u)) = self.scratch.best.pop() {
            self.scratch.sorted.push((d, u));
        }
        self.scratch.sorted.reverse();
        self.scratch.sorted.truncate(k);
    }
}

impl AnnIndex for HnswIndex {
    fn len(&self) -> usize {
        self.count
    }

    fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        self.ensure_capacity(id);
        if self.present[id] {
            self.unlink(id, false);
            if self.entry == Some(id as u32) && self.count > 1 {
                self.entry = self.pick_entry_excluding(id);
            }
        } else {
            self.present[id] = true;
            self.count += 1;
        }
        self.levels[id] = self.level_for(id) as u8;
        // Normalize in place in the slot: insert is the per-write ANN sync,
        // so no temporary like `normalized` would allocate.
        let n = dot(v, v).sqrt();
        let slot = &mut self.data[id * self.dim..(id + 1) * self.dim];
        slot.copy_from_slice(v);
        if n >= 1e-12 {
            let inv = 1.0 / n;
            slot.iter_mut().for_each(|x| *x *= inv);
        }
        let lvl = self.levels[id] as usize;
        let lid = &mut self.links[id];
        for l in lid.iter_mut() {
            l.clear();
        }
        lid.resize_with(lvl + 1, Vec::new);
        if self.entry.is_none() {
            self.entry = Some(id as u32);
            return;
        }
        self.connect(id);
    }

    fn remove(&mut self, id: usize) {
        if id >= self.present.len() || !self.present[id] {
            return;
        }
        self.unlink(id, true);
        self.present[id] = false;
        self.count -= 1;
        if self.entry == Some(id as u32) {
            self.entry = self.pick_entry_excluding(id);
        }
    }

    /// In-place relink: the node's level is a pure function of its id, so an
    /// update never reshapes the layer structure and never rebuilds.
    fn update(&mut self, id: usize, v: &[f32]) {
        self.insert(id, v);
    }

    fn update_row(&mut self, id: usize, v: &[f32]) {
        self.insert(id, v);
    }

    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        self.search_topk(q, k);
        crate::util::metrics::ANN_QUERIES.inc();
        crate::util::metrics::ANN_CANDIDATES.add(self.scratch.visited as u64);
        self.scratch
            .sorted
            .iter()
            .map(|&(d, u)| (u as usize, unit_dist_sq_to_cosine(d)))
            .collect()
    }

    fn query_many_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        self.query_many_rank_into(queries, k, out);
        for res in out.iter_mut() {
            for e in res.iter_mut() {
                e.1 = unit_dist_sq_to_cosine(e.1);
            }
        }
    }

    /// Raw rank key = squared unit L2 distance, ascending with ties by
    /// ascending id — the same key space as [`super::LinearIndex`], so the
    /// sharded merge stays well-ordered across HNSW shards.
    fn query_many_rank_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        while out.len() < queries.len() {
            out.push(Vec::new());
        }
        out.truncate(queries.len());
        for (q, slot) in queries.iter().zip(out.iter_mut()) {
            self.search_topk(q, k);
            crate::util::metrics::ANN_QUERIES.inc();
            crate::util::metrics::ANN_CANDIDATES.add(self.scratch.visited as u64);
            slot.clear();
            slot.extend(
                self.scratch
                    .sorted
                    .iter()
                    .map(|&(d, u)| (u as usize, d)),
            );
        }
    }

    fn rebuild(&mut self) {
        for per in self.links.iter_mut() {
            for l in per.iter_mut() {
                l.clear();
            }
        }
        self.entry = None;
        self.rebuilds += 1;
        crate::util::metrics::ANN_FULL_REBUILDS.inc();
        for id in 0..self.present.len() {
            if self.present[id] {
                self.connect(id);
            }
        }
    }

    fn full_rebuilds(&self) -> usize {
        self.rebuilds
    }

    fn heap_bytes(&self) -> usize {
        let links_bytes: usize = self.links.capacity()
            * std::mem::size_of::<Vec<Vec<u32>>>()
            + self
                .links
                .iter()
                .map(|per| {
                    per.capacity() * std::mem::size_of::<Vec<u32>>()
                        + per.iter().map(|l| l.capacity() * 4).sum::<usize>()
                })
                .sum::<usize>();
        self.data.capacity() * 4
            + self.present.capacity()
            + self.levels.capacity()
            + self.qn.capacity() * 4
            + self.scratch.heap_bytes()
            + links_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::LinearIndex;
    use crate::util::rng::Rng;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect()
    }

    #[test]
    fn exact_self_query() {
        let dim = 32;
        let pts = random_points(256, dim, 41);
        let mut h = HnswIndex::with_defaults(256, dim, 1);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        for i in (0..256).step_by(17) {
            let r = h.query(&pts[i], 1);
            assert_eq!(r[0].0, i);
            assert!((r[0].1 - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn recall_against_exact() {
        let dim = 32;
        let n = 512;
        let pts = random_points(n, dim, 42);
        let mut h = HnswIndex::with_defaults(n, dim, 2);
        let mut exact = LinearIndex::new(n, dim);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
            exact.insert(i, p);
        }
        let mut rng = Rng::new(77);
        let (mut hit, mut total) = (0, 0);
        for qi in 0..64 {
            let base = &pts[(qi * 7) % n];
            let q: Vec<f32> = base.iter().map(|x| x + 0.1 * rng.normal()).collect();
            let approx: std::collections::HashSet<usize> =
                h.query(&q, 4).into_iter().map(|(i, _)| i).collect();
            for (i, _) in exact.query(&q, 4) {
                total += 1;
                if approx.contains(&i) {
                    hit += 1;
                }
            }
        }
        let recall = hit as f64 / total as f64;
        assert!(recall > 0.9, "recall@4 = {recall}");
    }

    #[test]
    fn update_and_remove() {
        let dim = 16;
        let pts = random_points(32, dim, 43);
        let mut h = HnswIndex::with_defaults(32, dim, 3);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let target = vec![1.0; 16];
        h.update(5, &target);
        let r = h.query(&target, 1);
        assert_eq!(r[0].0, 5);
        h.remove(5);
        let r = h.query(&target, 1);
        assert_ne!(r[0].0, 5);
        assert_eq!(h.len(), 31);
    }

    #[test]
    fn incremental_churn_never_rebuilds() {
        let dim = 16;
        let n = 128;
        let pts = random_points(n, dim, 44);
        let mut h = HnswIndex::with_defaults(n, dim, 4);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let mut rng = Rng::new(9);
        for step in 0..512 {
            let id = step % n;
            let v: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            h.update_row(id, &v);
            if step % 5 == 0 {
                h.remove_row((step * 3) % n);
            }
        }
        assert_eq!(h.full_rebuilds(), 0);
        // The graph still answers: every present row finds itself.
        for id in 0..n {
            if h.present[id] {
                let p: Vec<f32> = rowslice(&h.data, dim, id as u32).to_vec();
                let r = h.query(&p, 1);
                assert_eq!(r[0].0, id, "self-query failed after churn");
            }
        }
    }

    #[test]
    fn entry_node_can_be_removed_and_updated() {
        let dim = 8;
        let pts = random_points(64, dim, 45);
        let mut h = HnswIndex::with_defaults(64, dim, 5);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let ep = h.entry.unwrap() as usize;
        // Rewriting the entry in place keeps it findable.
        let target = vec![1.0; dim];
        h.update_row(ep, &target);
        assert_eq!(h.query(&target, 1)[0].0, ep);
        // Removing it promotes another entry and queries keep working.
        h.remove_row(ep);
        assert!(h.entry.is_some());
        assert_ne!(h.entry.unwrap() as usize, ep);
        let r = h.query(&pts[(ep + 1) % 64], 1);
        assert_ne!(r[0].0, ep);
        assert_eq!(h.len(), 63);
        // Removing everything empties the index; queries return nothing.
        for i in 0..64 {
            h.remove_row(i);
        }
        assert_eq!(h.len(), 0);
        assert!(h.entry.is_none());
        assert!(h.query(&target, 4).is_empty());
        // And it comes back up from empty.
        h.insert(3, &pts[3]);
        assert_eq!(h.query(&pts[3], 1)[0].0, 3);
    }

    #[test]
    fn rank_keys_are_raw_distances() {
        let dim = 16;
        let n = 128;
        let pts = random_points(n, dim, 46);
        let mut h = HnswIndex::with_defaults(n, dim, 6);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let queries: Vec<Vec<f32>> = random_points(4, dim, 47);
        let mut cos = Vec::new();
        let mut rank = Vec::new();
        h.query_many_into(&queries, 8, &mut cos);
        h.query_many_rank_into(&queries, 8, &mut rank);
        for (c, r) in cos.iter().zip(&rank) {
            let c_ids: Vec<usize> = c.iter().map(|&(i, _)| i).collect();
            let r_ids: Vec<usize> = r.iter().map(|&(i, _)| i).collect();
            assert_eq!(c_ids, r_ids);
            for (&(_, cv), &(_, rv)) in c.iter().zip(r) {
                assert!(rv >= 0.0, "rank key must be a distance");
                assert_eq!(cv.to_bits(), unit_dist_sq_to_cosine(rv).to_bits());
            }
            // Keys ascend (best first), ties broken by ascending id — the
            // sharded-merge precondition.
            for w in r.windows(2) {
                assert!(
                    w[0].1 < w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                    "rank order violated: {w:?}"
                );
            }
        }
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let dim = 16;
        let n = 96;
        let pts = random_points(n, dim, 48);
        let queries: Vec<Vec<f32>> = random_points(5, dim, 49);
        let run = || {
            let mut h = HnswIndex::with_defaults(n, dim, 7);
            for (i, p) in pts.iter().enumerate() {
                h.insert(i, p);
            }
            for i in (0..n).step_by(3) {
                h.update_row(i, &pts[(i + 1) % n]);
            }
            for i in (0..n).step_by(7) {
                h.remove_row(i);
            }
            let mut out = Vec::new();
            h.query_many_rank_into(&queries, 6, &mut out);
            out
        };
        let a = run();
        let b = run();
        // Bit-identical results, not just same ids.
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.len(), rb.len());
            for (&(ia, da), &(ib, db)) in ra.iter().zip(rb) {
                assert_eq!(ia, ib);
                assert_eq!(da.to_bits(), db.to_bits());
            }
        }
    }

    #[test]
    fn levels_are_stable_across_reinserts() {
        let dim = 8;
        let pts = random_points(32, dim, 50);
        let mut h = HnswIndex::with_defaults(32, dim, 8);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let before = h.levels.clone();
        for (i, p) in pts.iter().enumerate().rev() {
            h.remove_row(i);
            h.insert(i, p);
        }
        assert_eq!(before, h.levels);
    }

    #[test]
    fn heap_bytes_counts_scratch_and_grows_after_warm_query() {
        let dim = 16;
        let pts = random_points(64, dim, 51);
        let mut h = HnswIndex::with_defaults(64, dim, 9);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        let before = h.heap_bytes();
        let queries: Vec<Vec<f32>> = random_points(3, dim, 52);
        let mut out = Vec::new();
        h.query_many_rank_into(&queries, 4, &mut out);
        assert!(
            h.heap_bytes() > before,
            "warm query scratch must show up in heap_bytes"
        );
        // Sanity floor: the row storage alone.
        assert!(h.heap_bytes() >= 64 * dim * 4);
    }

    #[test]
    fn explicit_rebuild_is_lossless_and_counted() {
        let dim = 16;
        let pts = random_points(64, dim, 53);
        let mut h = HnswIndex::with_defaults(64, dim, 10);
        for (i, p) in pts.iter().enumerate() {
            h.insert(i, p);
        }
        assert_eq!(h.full_rebuilds(), 0);
        h.rebuild();
        assert_eq!(h.full_rebuilds(), 1);
        assert_eq!(h.len(), 64);
        let r = h.query(&pts[10], 1);
        assert_eq!(r[0].0, 10);
    }
}
