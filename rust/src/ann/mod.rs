//! Approximate nearest neighbour indexes (paper §3.5, Supp A.4).
//!
//! The ANN is a *structured view* of the external memory: the memory stays a
//! dense tensor the network operates on, while the index is carried through
//! the network as non-differentiable state, kept in sync on every write, and
//! queried for the K nearest words under cosine similarity.
//!
//! We follow the paper: a FLANN-style randomized k-d-tree ensemble
//! ([`KdForest`]) for small word sizes, hyperplane LSH ([`LshIndex`]) for
//! large ones, and an exact [`LinearIndex`] baseline ("SAM linear"). Beyond
//! the paper's 2016-era choices, [`HnswIndex`] adds a navigable-small-world
//! graph with O(log N) queries — the backend for million-to-ten-million-slot
//! configs. All indexes store L2-normalized copies of the rows so that
//! nearest-in-L2 equals highest-cosine, which is the similarity used by
//! content-based addressing (eq. 2).

pub mod hnsw;
pub mod kdtree;
pub mod lsh;

pub use hnsw::HnswIndex;
pub use kdtree::KdForest;
pub use lsh::LshIndex;

use crate::tensor::matrix::dot;
use crate::tensor::rowcodec::{RowFormat, RowStore};
use crate::util::metrics;

/// Which ANN backs a SAM memory (CLI / config selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnnKind {
    /// Exact linear scan — the paper's "SAM linear".
    Linear,
    /// Randomized k-d-tree ensemble — the paper's "SAM ANN (k-d tree)".
    KdForest,
    /// Hyperplane locality-sensitive hashing — "SAM ANN (LSH)".
    Lsh,
    /// HNSW-style small-world graph — O(log N) queries, the post-paper
    /// backend for very large memories.
    Hnsw,
}

impl std::str::FromStr for AnnKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "linear" => Ok(AnnKind::Linear),
            "kdtree" | "kd" | "kdforest" => Ok(AnnKind::KdForest),
            "lsh" => Ok(AnnKind::Lsh),
            "hnsw" => Ok(AnnKind::Hnsw),
            other => Err(format!("unknown ann kind {other:?} (linear|kdtree|lsh|hnsw)")),
        }
    }
}

/// A point index over the memory rows, queried for K nearest by cosine.
/// `Send + Sync` so a core holding one can be shared read-only behind an
/// `Arc` by the serving runtime (all implementations are plain owned data
/// with no interior mutability; queries take `&mut self` only for their
/// scratch buffers, and serving sessions each own a private index).
pub trait AnnIndex: Send + Sync {
    /// Number of indexed rows.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert (or re-insert) row `id` with contents `v`. Implementations
    /// normalize internally; `v` is the raw memory row.
    fn insert(&mut self, id: usize, v: &[f32]);

    /// Remove row `id` (no-op if absent).
    fn remove(&mut self, id: usize);

    /// Replace row `id`'s vector: the per-write sync operation (§3.5).
    fn update(&mut self, id: usize, v: &[f32]) {
        self.remove(id);
        self.insert(id, v);
    }

    /// Incremental per-row replacement — the write-time sync path used by
    /// [`crate::memory::engine::SparseMemoryEngine`]. Semantically identical
    /// to [`AnnIndex::update`], but implementations treat it as the hot path:
    /// service it in place (no full resync) and amortize any structural
    /// maintenance through their internal rebuild counters.
    fn update_row(&mut self, id: usize, v: &[f32]) {
        self.update(id, v);
    }

    /// Incremental removal twin of [`AnnIndex::update_row`].
    fn remove_row(&mut self, id: usize) {
        self.remove(id);
    }

    /// Return up to `k` (id, cosine-similarity) pairs, best first.
    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)>;

    /// Batched K-nearest lookup: answer every query in one call so a
    /// multi-head read step costs one index traversal, not one per head.
    /// Takes borrowed slices so the hot path never clones query vectors.
    /// Results are identical to issuing `query` per element in order; the
    /// default does exactly that, and backends override where a genuinely
    /// shared traversal exists (see [`LinearIndex`]).
    fn query_many(&mut self, queries: &[&[f32]], k: usize) -> Vec<Vec<(usize, f32)>> {
        queries.iter().map(|q| self.query(q, k)).collect()
    }

    /// `query_many` into reused result buffers — the step hot path. `out`
    /// is resized to one entry per query with inner capacities retained, so
    /// backends that also avoid internal scratch allocations (the
    /// [`LinearIndex`] override) answer a steady-state step with zero heap
    /// allocations. The default delegates to [`AnnIndex::query`] and is
    /// correct but allocating.
    fn query_many_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        while out.len() < queries.len() {
            out.push(Vec::new());
        }
        out.truncate(queries.len());
        for (q, slot) in queries.iter().zip(out.iter_mut()) {
            *slot = self.query(q, k);
        }
    }

    /// `query_many_into` where each result carries the backend's **raw
    /// ranking key** (ascending = more similar) instead of the cosine — the
    /// merge key for [`crate::memory::sharded::ShardedMemoryEngine`]'s
    /// sharded fan-out. Per-shard top-K lists merged by `(key, global id)`
    /// must reproduce a single index's candidate *order* exactly, and the
    /// cosine↔key conversion is not injective in f32 (two distinct d² can
    /// round to one cosine), so the merge has to happen in key space.
    ///
    /// * [`LinearIndex`] overrides this with the squared L2 distance
    ///   between unit vectors — the quantity its scan actually compares —
    ///   which is what makes the merged sharded result bit-identical to
    ///   the unsharded scan (see `linear_rank_keys_are_raw_distances`).
    /// * Approximate backends keep the default (negated cosine): any
    ///   per-run-deterministic key consistent with their own ranking is
    ///   enough, since kd/LSH results are approximate to begin with.
    fn query_many_rank_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        self.query_many_into(queries, k, out);
        for res in out.iter_mut() {
            for e in res.iter_mut() {
                e.1 = -e.1;
            }
        }
    }

    /// Rebuild internal structure from scratch (the paper rebuilds every N
    /// insertions to keep trees balanced). Incremental maintenance makes
    /// this an amortized background concern, not a per-episode requirement.
    fn rebuild(&mut self);

    /// How many full rebuilds the index has performed (initial builds
    /// included). Lets callers assert the incremental path stays
    /// incremental — see `rust/tests/ann_recall.rs`.
    fn full_rebuilds(&self) -> usize {
        0
    }

    /// Approximate heap footprint, for the memory benchmarks.
    fn heap_bytes(&self) -> usize;
}

/// L2-normalize into a fresh Vec (zero vectors stay zero).
pub(crate) fn normalized(v: &[f32]) -> Vec<f32> {
    let n = dot(v, v).sqrt();
    if n < 1e-12 {
        return v.to_vec();
    }
    let inv = 1.0 / n;
    v.iter().map(|x| x * inv).collect()
}

/// Convert squared L2 distance between unit vectors to cosine similarity.
#[inline]
pub(crate) fn unit_dist_sq_to_cosine(d2: f32) -> f32 {
    1.0 - 0.5 * d2
}

// ---------------------------------------------------------------------------
// Exact linear index
// ---------------------------------------------------------------------------

/// Exact KNN by linear scan over normalized rows — O(N) per query.
/// This is the paper's "SAM linear" configuration and the ground truth the
/// approximate indexes are property-tested against.
///
/// Rows live in a [`RowStore`], so the scan can run over compact (bf16 /
/// int8) storage with decode fused into the distance kernel — the index's
/// bandwidth then tracks the memory's `--row-format`. Compaction costs a
/// little precision in the stored unit vectors (ranking stays within the
/// quantization error; see `rust/tests/ann_recall.rs`).
pub struct LinearIndex {
    dim: usize,
    /// Normalized row storage (row codec selected at construction).
    rows: RowStore,
    present: Vec<bool>,
    count: usize,
    /// Normalized-query scratch for `query_many_into` (flat, one dim-sized
    /// segment per query), reused across steps.
    qn_scratch: Vec<f32>,
    /// Normalized-row staging for compact-format inserts (empty for f32,
    /// which normalizes in place in the slot).
    norm_scratch: Vec<f32>,
}

impl LinearIndex {
    pub fn new(capacity: usize, dim: usize) -> LinearIndex {
        LinearIndex::with_format(capacity, dim, RowFormat::F32)
    }

    /// [`LinearIndex::new`] with an explicit row-storage codec.
    pub fn with_format(capacity: usize, dim: usize, fmt: RowFormat) -> LinearIndex {
        LinearIndex {
            dim,
            rows: RowStore::zeros(capacity, dim, fmt),
            present: vec![false; capacity],
            count: 0,
            qn_scratch: Vec::new(),
            norm_scratch: if fmt == RowFormat::F32 { Vec::new() } else { vec![0.0; dim] },
        }
    }

    /// Storage codec of the indexed rows.
    pub fn row_format(&self) -> RowFormat {
        self.rows.fmt()
    }
}

impl AnnIndex for LinearIndex {
    fn len(&self) -> usize {
        self.count
    }

    fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        if id >= self.present.len() {
            self.present.resize(id + 1, false);
            self.rows.grow(id + 1);
        }
        // Normalize without a fresh allocation: insert is the per-write ANN
        // sync (every sparse_write AND every backward revert). f32 rows
        // normalize in place in the slot; compact rows stage the unit
        // vector in the persistent `norm_scratch` and encode it.
        let n = dot(v, v).sqrt();
        if self.rows.fmt() == RowFormat::F32 {
            let slot = self.rows.row_mut(id);
            slot.copy_from_slice(v);
            if n >= 1e-12 {
                let inv = 1.0 / n;
                slot.iter_mut().for_each(|x| *x *= inv);
            }
        } else {
            let inv = if n >= 1e-12 { 1.0 / n } else { 1.0 };
            for (o, &x) in self.norm_scratch.iter_mut().zip(v) {
                *o = x * inv;
            }
            self.rows.set_row(id, &self.norm_scratch);
        }
        if !self.present[id] {
            self.present[id] = true;
            self.count += 1;
        }
    }

    fn remove(&mut self, id: usize) {
        if id < self.present.len() && self.present[id] {
            self.present[id] = false;
            self.count -= 1;
        }
    }

    fn update_row(&mut self, id: usize, v: &[f32]) {
        // Overwriting the slot is the whole update; skip the remove/insert
        // count churn of the default.
        self.insert(id, v);
    }

    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        metrics::ANN_QUERIES.inc();
        metrics::ANN_CANDIDATES.add(self.count as u64);
        let qn = normalized(q);
        // Max-heap on (negated) distance of current top-k via simple vec;
        // k is tiny (4-16) so insertion into a sorted vec is fastest.
        let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        for id in 0..self.present.len() {
            if !self.present[id] {
                continue;
            }
            let d2 = self.rows.dist_sq_to(id, &qn);
            if best.len() < k || d2 < best.last().unwrap().1 {
                let pos = best.partition_point(|&(_, bd)| bd <= d2);
                best.insert(pos, (id, d2));
                if best.len() > k {
                    best.pop();
                }
            }
        }
        best.into_iter()
            .map(|(id, d2)| (id, unit_dist_sq_to_cosine(d2)))
            .collect()
    }

    /// One pass over the data services every query: each memory row is read
    /// from cache once and scored against all H queries, instead of H full
    /// scans. Per-query results are bit-identical to sequential `query`
    /// calls (same comparisons in the same id order).
    fn query_many(&mut self, queries: &[&[f32]], k: usize) -> Vec<Vec<(usize, f32)>> {
        metrics::ANN_QUERIES.add(queries.len() as u64);
        metrics::ANN_CANDIDATES.add(self.count as u64 * queries.len() as u64);
        let qns: Vec<Vec<f32>> = queries.iter().map(|q| normalized(q)).collect();
        let mut bests: Vec<Vec<(usize, f32)>> =
            (0..queries.len()).map(|_| Vec::with_capacity(k + 1)).collect();
        for id in 0..self.present.len() {
            if !self.present[id] {
                continue;
            }
            for (qn, best) in qns.iter().zip(bests.iter_mut()) {
                let d2 = self.rows.dist_sq_to(id, qn);
                if best.len() < k || d2 < best.last().unwrap().1 {
                    let pos = best.partition_point(|&(_, bd)| bd <= d2);
                    best.insert(pos, (id, d2));
                    if best.len() > k {
                        best.pop();
                    }
                }
            }
        }
        bests
            .into_iter()
            .map(|best| {
                best.into_iter()
                    .map(|(id, d2)| (id, unit_dist_sq_to_cosine(d2)))
                    .collect()
            })
            .collect()
    }

    /// The shared-traversal `query_many` into reused buffers: per-query
    /// results are bit-identical to [`LinearIndex::query_many`] (same
    /// comparisons in the same id order), with zero allocations once the
    /// scratch and result capacities are warm.
    fn query_many_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        self.query_many_rank_into(queries, k, out);
        for best in out.iter_mut() {
            for e in best.iter_mut() {
                e.1 = unit_dist_sq_to_cosine(e.1);
            }
        }
    }

    /// The same shared traversal with results left in raw-d² form (the
    /// ranking the scan actually uses). This ordering — ascending d², ties
    /// by ascending id — is what the sharded merge reproduces globally.
    fn query_many_rank_into(
        &mut self,
        queries: &[Vec<f32>],
        k: usize,
        out: &mut Vec<Vec<(usize, f32)>>,
    ) {
        metrics::ANN_QUERIES.add(queries.len() as u64);
        metrics::ANN_CANDIDATES.add(self.count as u64 * queries.len() as u64);
        let dim = self.dim;
        self.qn_scratch.clear();
        for q in queries {
            assert_eq!(q.len(), dim);
            let n = dot(q, q).sqrt();
            let start = self.qn_scratch.len();
            self.qn_scratch.extend_from_slice(q);
            if n >= 1e-12 {
                let inv = 1.0 / n;
                self.qn_scratch[start..].iter_mut().for_each(|x| *x *= inv);
            }
        }
        while out.len() < queries.len() {
            out.push(Vec::new());
        }
        out.truncate(queries.len());
        for best in out.iter_mut() {
            best.clear();
            best.reserve(k + 1);
        }
        for id in 0..self.present.len() {
            if !self.present[id] {
                continue;
            }
            for (qi, best) in out.iter_mut().enumerate() {
                let qn = &self.qn_scratch[qi * dim..(qi + 1) * dim];
                let d2 = self.rows.dist_sq_to(id, qn);
                if best.len() < k || d2 < best.last().unwrap().1 {
                    let pos = best.partition_point(|&(_, bd)| bd <= d2);
                    best.insert(pos, (id, d2));
                    if best.len() > k {
                        best.pop();
                    }
                }
            }
        }
    }

    fn rebuild(&mut self) {}

    fn heap_bytes(&self) -> usize {
        self.rows.heap_bytes()
            + self.present.capacity()
            + self.qn_scratch.capacity() * 4
            + self.norm_scratch.capacity() * 4
    }
}

/// Construct an index of the given kind sized for `n` rows of width `dim`.
pub fn build_index(kind: AnnKind, n: usize, dim: usize, seed: u64) -> Box<dyn AnnIndex> {
    build_index_fmt(kind, n, dim, seed, RowFormat::F32)
}

/// [`build_index`] with a row-storage codec. Only [`LinearIndex`] honours
/// compact formats (its scan is the bandwidth-bound path row compaction
/// targets); the tree/hash/graph backends keep f32 internals regardless —
/// their footprint is dominated by structure, not row payloads.
pub fn build_index_fmt(
    kind: AnnKind,
    n: usize,
    dim: usize,
    seed: u64,
    fmt: RowFormat,
) -> Box<dyn AnnIndex> {
    match kind {
        AnnKind::Linear => Box::new(LinearIndex::with_format(n, dim, fmt)),
        AnnKind::KdForest => Box::new(KdForest::with_defaults(n, dim, seed)),
        AnnKind::Lsh => Box::new(LshIndex::with_defaults(n, dim, seed)),
        AnnKind::Hnsw => Box::new(HnswIndex::with_defaults(n, dim, seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::dist_sq;
    use crate::util::rng::Rng;

    #[test]
    fn linear_exact_top1() {
        let mut idx = LinearIndex::new(8, 3);
        idx.insert(0, &[1.0, 0.0, 0.0]);
        idx.insert(1, &[0.0, 1.0, 0.0]);
        idx.insert(2, &[0.7, 0.7, 0.0]);
        let r = idx.query(&[0.9, 0.1, 0.0], 2);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[1].0, 2);
        assert!(r[0].1 > r[1].1);
    }

    #[test]
    fn linear_compact_formats_rank_like_f32() {
        // Well-separated vectors: compact unit-row storage must preserve
        // the ranking, and the reported cosines must sit within the codec's
        // quantization error of the f32 scan.
        let mut rng = Rng::new(21);
        let data: Vec<Vec<f32>> = (0..48).map(|_| (0..16).map(|_| rng.normal()).collect()).collect();
        let queries: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.normal()).collect()).collect();
        let mut f32_idx = LinearIndex::new(48, 16);
        for (i, v) in data.iter().enumerate() {
            f32_idx.insert(i, v);
        }
        for (fmt, tol) in [(RowFormat::Bf16, 0.02), (RowFormat::Int8, 0.04)] {
            let mut idx = LinearIndex::with_format(48, 16, fmt);
            assert_eq!(idx.row_format(), fmt);
            for (i, v) in data.iter().enumerate() {
                idx.insert(i, v);
            }
            for q in &queries {
                let want = f32_idx.query(q, 4);
                let got = idx.query(q, 4);
                assert_eq!(got.len(), want.len());
                for (&(_, wc), &(_, gc)) in want.iter().zip(&got) {
                    assert!(
                        (wc - gc).abs() < tol,
                        "{}: cosine drifted {wc} vs {gc}",
                        fmt.name()
                    );
                }
            }
            // Growth past capacity must work for compact stores too.
            idx.insert(100, &data[0]);
            assert_eq!(idx.len(), 49);
            let top = idx.query(&data[0], 1);
            assert!(top[0].0 == 100 || top[0].0 == 0, "duplicate row must win: {top:?}");
        }
    }

    #[test]
    fn linear_remove_and_update() {
        let mut idx = LinearIndex::new(4, 2);
        idx.insert(0, &[1.0, 0.0]);
        idx.insert(1, &[0.0, 1.0]);
        idx.remove(0);
        let r = idx.query(&[1.0, 0.0], 1);
        assert_eq!(r[0].0, 1);
        idx.update(1, &[1.0, 0.0]);
        let r = idx.query(&[1.0, 0.0], 1);
        assert!((r[0].1 - 1.0).abs() < 1e-5);
    }

    #[test]
    fn cosine_from_unit_dist() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let a: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            let (an, bn) = (normalized(&a), normalized(&b));
            let cos = dot(&an, &bn);
            let d2 = dist_sq(&an, &bn);
            assert!((unit_dist_sq_to_cosine(d2) - cos).abs() < 1e-5);
        }
    }

    #[test]
    fn query_many_matches_sequential_queries() {
        let mut rng = Rng::new(5);
        let mut idx = LinearIndex::new(64, 8);
        for i in 0..64 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            idx.insert(i, &v);
        }
        let queries: Vec<Vec<f32>> =
            (0..5).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let batched = idx.query_many(&qrefs, 4);
        assert_eq!(batched.len(), queries.len());
        for (q, b) in queries.iter().zip(&batched) {
            assert_eq!(idx.query(q, 4), *b);
        }
    }

    #[test]
    fn query_many_into_matches_query_many() {
        let mut rng = Rng::new(6);
        let mut idx = LinearIndex::new(64, 8);
        for i in 0..64 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            idx.insert(i, &v);
        }
        let mut out = Vec::new();
        for round in 0..3 {
            let queries: Vec<Vec<f32>> =
                (0..4).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let want = idx.query_many(&qrefs, 4);
            idx.query_many_into(&queries, 4, &mut out);
            assert_eq!(want, out, "round {round} (buffer reuse must not leak state)");
        }
    }

    #[test]
    fn linear_rank_keys_are_raw_distances() {
        // Same ids in the same order as the cosine path, with keys equal to
        // the squared unit distance the scan compared — the property the
        // sharded merge depends on.
        let mut rng = Rng::new(9);
        let mut idx = LinearIndex::new(64, 8);
        for i in 0..64 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            idx.insert(i, &v);
        }
        let queries: Vec<Vec<f32>> =
            (0..4).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let mut cos = Vec::new();
        let mut rank = Vec::new();
        idx.query_many_into(&queries, 5, &mut cos);
        idx.query_many_rank_into(&queries, 5, &mut rank);
        assert_eq!(cos.len(), rank.len());
        for (c, r) in cos.iter().zip(&rank) {
            let c_ids: Vec<usize> = c.iter().map(|&(i, _)| i).collect();
            let r_ids: Vec<usize> = r.iter().map(|&(i, _)| i).collect();
            assert_eq!(c_ids, r_ids);
            for (&(_, cv), &(_, rv)) in c.iter().zip(r) {
                assert!(rv >= 0.0, "rank key must be a distance");
                assert_eq!(cv.to_bits(), unit_dist_sq_to_cosine(rv).to_bits());
            }
            // Keys ascend (best first), ties broken by ascending id.
            for w in r.windows(2) {
                assert!(
                    w[0].1 < w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                    "rank order violated: {w:?}"
                );
            }
        }
    }

    #[test]
    fn default_rank_keys_order_like_cosine() {
        // The trait default (negated cosine) must preserve the backend's
        // own ranking — checked through the KdForest, which does not
        // override it.
        let mut rng = Rng::new(12);
        let mut kd = KdForest::with_defaults(64, 8, 3);
        for i in 0..64 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            kd.insert(i, &v);
        }
        let queries: Vec<Vec<f32>> =
            (0..3).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let mut cos = Vec::new();
        let mut rank = Vec::new();
        kd.query_many_into(&queries, 4, &mut cos);
        kd.query_many_rank_into(&queries, 4, &mut rank);
        for (c, r) in cos.iter().zip(&rank) {
            assert_eq!(c.len(), r.len());
            for (&(ci, cv), &(ri, rv)) in c.iter().zip(r) {
                assert_eq!(ci, ri);
                assert_eq!((-cv).to_bits(), rv.to_bits());
            }
        }
    }

    #[test]
    fn linear_heap_bytes_counts_query_scratch() {
        // Regression: heap_bytes used to omit qn_scratch, so the sum-of-parts
        // heap identities undercounted after the first batched query.
        let mut rng = Rng::new(14);
        let mut idx = LinearIndex::new(32, 8);
        for i in 0..32 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
            idx.insert(i, &v);
        }
        let before = idx.heap_bytes();
        let queries: Vec<Vec<f32>> =
            (0..4).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let mut out = Vec::new();
        idx.query_many_rank_into(&queries, 4, &mut out);
        assert!(
            idx.heap_bytes() > before,
            "warm query scratch must show up in heap_bytes"
        );
        assert!(idx.heap_bytes() >= before + queries.len() * 8 * 4);
    }

    #[test]
    fn query_returns_sorted_by_similarity() {
        let mut rng = Rng::new(2);
        let mut idx = LinearIndex::new(64, 16);
        for i in 0..64 {
            let v: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
            idx.insert(i, &v);
        }
        let q: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let r = idx.query(&q, 8);
        assert_eq!(r.len(), 8);
        for w in r.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
